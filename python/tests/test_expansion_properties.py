"""Property tests for the series-expansion algebra (DESIGN.md §7
invariants, python side) — hypothesis sweeps over shapes, bit-widths,
scales and term counts.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref

SETTLE = dict(max_examples=25, deadline=None)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


@settings(**SETTLE)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    terms=st.integers(1, 4),
    seed=st.integers(0, 2**16),
    magnitude=st.sampled_from([1e-2, 1.0, 1e2]),
)
def test_invariant1_reconstruction_bound(bits, terms, seed, magnitude):
    """‖M − Σ sᵢM̃ᵢ‖∞ ≤ s_n/2 (+ float floor)."""
    m = rand((8, 16), seed, magnitude)
    planes, scales = ref.series_expand_ref(m, bits, terms)
    recon = ref.series_reconstruct_ref(planes, scales)
    err = float(jnp.max(jnp.abs(m - recon)))
    bound = float(scales[-1]) / 2 + 16 * np.finfo(np.float32).eps * magnitude
    assert err <= bound, (err, bound)


@settings(**SETTLE)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
def test_invariant2_scale_law_powers_of_two(bits, seed):
    """sᵢ = 2^X · sᵢ₊₁ exactly."""
    m = rand((4, 4), seed)
    _, scales = ref.series_expand_ref(m, bits, 4)
    s = np.asarray(scales, dtype=np.float64)
    for i in range(1, len(s)):
        assert s[i - 1] == s[i] * 2**bits


@settings(**SETTLE)
@given(
    k=st.integers(1, 3),
    t=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_invariant3_gemm_residual_bound(k, t, seed):
    """Expanded GEMM error ≤ analytic propagation of the two residuals."""
    bits = 4
    x = rand((4, 16), seed)
    w = rand((6, 16), seed + 1, 0.3)
    wp, ws = ref.series_expand_ref(w, bits, k)
    ap, as_ = ref.series_expand_ref(x, bits, t)
    y = ref.xint_gemm_ref(wp, ws, ap, as_)
    fp = x @ w.T
    # |x wᵀ − x̂ ŵᵀ| ≤ |x||w−ŵ| + |w̃||x−x̂| elementwise bound summed over K
    rw = float(ws[-1]) / 2
    ra = float(as_[-1]) / 2
    kdim = 16
    bound = kdim * (
        float(jnp.max(jnp.abs(x))) * rw
        + (float(jnp.max(jnp.abs(w))) + rw) * ra
    ) + 1e-4
    err = float(jnp.max(jnp.abs(fp - y)))
    assert err <= bound, (err, bound)


@settings(**SETTLE)
@given(seed=st.integers(0, 2**16))
def test_invariant4_additivity_of_expansions(seed):
    """Eq. 5/6 at the tensor level: recon(A) + recon(B) == recon over the
    sum when expanded jointly to convergence (linearity of the limit)."""
    a = rand((4, 8), seed)
    b = rand((4, 8), seed + 1)
    pa, sa = ref.series_expand_ref(a, 8, 4)
    pb, sb = ref.series_expand_ref(b, 8, 4)
    lhs = ref.series_reconstruct_ref(pa, sa) + ref.series_reconstruct_ref(pb, sb)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(a + b), atol=1e-4)


@settings(**SETTLE)
@given(bits=st.sampled_from([2, 4]), seed=st.integers(0, 2**16))
def test_invariant5_exponential_rate(bits, seed):
    """Residual after n terms ≤ scale₁ / 2^{X(n−1)} / 2."""
    m = rand((8, 8), seed)
    for n in (1, 2, 3):
        planes, scales = ref.series_expand_ref(m, bits, n)
        err = float(jnp.max(jnp.abs(m - ref.series_reconstruct_ref(planes, scales))))
        analytic = float(scales[0]) / 2 ** (bits * (n - 1)) / 2 + 1e-6
        assert err <= analytic, (n, err, analytic)


@settings(**SETTLE)
@given(seed=st.integers(0, 2**16), bits=st.sampled_from([2, 4, 8]))
def test_invariant6_parallel_equals_sequential(seed, bits):
    """§4 closed form == greedy residual recursion."""
    m = rand((64,), seed)
    planes, scales = ref.series_expand_ref(m, bits, 3)
    # In exact arithmetic the closed form equals the greedy recursion
    # elementwise. In f32 the closed form's quotient m/s_i grows as
    # 2^{X·i} and exhausts the mantissa (8-bit × 3 terms = 24 bits), and a
    # rounding tie at term i shifts term i+1 by a full 2^X — but the sum
    # TELESCOPES identically either way. So the robust statement of the
    # invariant is: the greedy recursion's reconstruction and the closed
    # form's reconstruction agree within the Theorem-1 bound.
    resid = np.asarray(m, dtype=np.float32)
    seq_recon = np.zeros_like(resid)
    for i in range(3):
        s = np.float32(scales[i])
        q = np.round(resid / s)
        seq_recon = seq_recon + q * s
        resid = (resid - q * s).astype(np.float32)
    closed_recon = np.asarray(ref.series_reconstruct_ref(planes, scales))
    bound = float(scales[-1]) + 32 * np.finfo(np.float32).eps * float(jnp.max(jnp.abs(m)))
    assert np.max(np.abs(seq_recon - closed_recon)) <= bound
    # and for shallow quotients (bits ≤ 4) the planes agree elementwise ±1
    if bits <= 4:
        resid2 = np.asarray(m, dtype=np.float32)
        for i in range(3):
            s = np.float32(scales[i])
            q = np.round(resid2 / s)
            assert np.max(np.abs(q - np.asarray(planes[i]))) <= 1.0
            resid2 = (resid2 - q * s).astype(np.float32)
