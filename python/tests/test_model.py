"""L2 model graphs: shapes, FP-vs-expanded numerics, AOT manifest."""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


def make_weights(seed=0):
    return dict(
        w1=rand((16, 32), seed, 0.3),
        b1=rand((16,), seed + 1, 0.1),
        w2=rand((10, 16), seed + 2, 0.3),
        b2=rand((10,), seed + 3, 0.1),
    )


def test_fp_mlp_shapes():
    w = make_weights()
    x = rand((4, 32), 9)
    (y,) = model.fp_mlp(x, w["w1"], w["b1"], w["w2"], w["b2"])
    assert y.shape == (4, 10)


def test_xint_mlp_converges_to_fp_with_terms():
    w = make_weights(5)
    x = rand((4, 32), 11)
    (fp,) = model.fp_mlp(x, w["w1"], w["b1"], w["w2"], w["b2"])
    errs = []
    for a_terms in (1, 3):
        w1p, w1s = model.expand_weights_host(w["w1"], bits=4, terms=2)
        w2p, w2s = model.expand_weights_host(w["w2"], bits=4, terms=2)
        (y,) = model.xint_mlp(
            x, w1p, w1s, w["b1"], w2p, w2s, w["b2"], bits=4, a_terms=a_terms
        )
        errs.append(float(jnp.linalg.norm(fp - y) / jnp.linalg.norm(fp)))
    assert errs[1] < errs[0], errs
    assert errs[1] < 0.05, f"3-term W4A4 should be close to FP: {errs}"


def test_basis_mlp_runs_and_single_term_matches_xint_t1():
    w = make_weights(7)
    x = rand((2, 32), 13)
    w1p, w1s = model.expand_weights_host(w["w1"], bits=4, terms=1)
    w2p, w2s = model.expand_weights_host(w["w2"], bits=4, terms=1)
    (yb,) = model.basis_mlp(x, w1p, w1s, w["b1"], w2p, w2s, w["b2"], bits=4)
    (yx,) = model.xint_mlp(x, w1p, w1s, w["b1"], w2p, w2s, w["b2"], bits=4, a_terms=1)
    np.testing.assert_allclose(np.asarray(yb), np.asarray(yx), rtol=1e-5, atol=1e-5)


def test_weight_expansion_reconstructs():
    w = rand((8, 8), 3)
    planes, scales = model.expand_weights_host(w, bits=4, terms=3)
    recon = ref.series_reconstruct_ref(planes, scales)
    err = float(jnp.max(jnp.abs(w - recon)))
    assert err <= float(scales[-1]) / 2 + 1e-6


def test_aot_artifacts_exist_with_manifest():
    # `make artifacts` must have produced the manifest next to this repo
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        # build them (slow path, e.g. fresh clone running pytest directly)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", art],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            check=True,
        )
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["artifacts"], "no artifacts listed"
    for name in manifest["artifacts"].values():
        path = os.path.join(art, name)
        assert os.path.exists(path), f"missing {name}"
        with open(path) as fh:
            head = fh.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_hlo_text_roundtrips_through_xla_parser():
    # the exact interchange contract the Rust runtime relies on
    from jax._src.lib import xla_client as xc

    x = jax.ShapeDtypeStruct((2, 4), jnp.float32)
    lowered = jax.jit(lambda a: (a * 2.0,)).lower(x)
    from compile.aot import to_hlo_text

    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # parse it back (the same entry point HloModuleProto::from_text uses)
    # a successful round-trip through the text parser is what the Rust
    # loader depends on; absence of exceptions is the contract
    assert "ROOT" in text
