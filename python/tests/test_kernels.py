"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/bit-widths/term counts; every property pins the
kernel to the `ref.py` oracle via assert_allclose and checks the paper's
invariants (integer planes, scale law, exponential convergence).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import expand, quantize, ref, xint_matmul

SETTLE = dict(max_examples=20, deadline=None)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# ---------------------------------------------------------------- expand


@settings(**SETTLE)
@given(
    rows=st.integers(1, 48),
    cols=st.integers(1, 64),
    bits=st.sampled_from([2, 3, 4, 8]),
    terms=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_expand_kernel_matches_ref(rows, cols, bits, terms, seed):
    m = rand((rows, cols), seed)
    planes, scales = expand.expand_with_scales(m, bits=bits, terms=terms)
    ref_planes, ref_scales = ref.series_expand_ref(m, bits, terms)
    np.testing.assert_allclose(np.asarray(scales), np.asarray(ref_scales), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(planes), np.asarray(ref_planes), atol=0)


@settings(**SETTLE)
@given(
    bits=st.sampled_from([2, 4, 8]),
    terms=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_expand_planes_are_bounded_integers(bits, terms, seed):
    m = rand((16, 32), seed, scale=3.0)
    planes, _ = expand.expand_with_scales(m, bits=bits, terms=terms)
    p = np.asarray(planes)
    assert np.all(p == np.round(p)), "planes must be integer-valued"
    assert np.max(np.abs(p)) <= 2 ** (bits - 1), "planes exceed INT(X) range"


@settings(**SETTLE)
@given(bits=st.sampled_from([2, 4]), seed=st.integers(0, 2**16))
def test_expand_reconstruction_converges_exponentially(bits, seed):
    m = rand((8, 24), seed)
    errs = []
    for terms in range(1, 5):
        planes, scales = expand.expand_with_scales(m, bits=bits, terms=terms)
        recon = ref.series_reconstruct_ref(planes, scales)
        errs.append(float(jnp.max(jnp.abs(m - recon))))
    for a, b in zip(errs, errs[1:]):
        # each INT(X) term shrinks the residual by ≥ 2^{X-1}
        assert b <= a / 2 ** (bits - 1) + 1e-7, errs


def test_expand_scale_law_is_exact():
    m = rand((4, 4), 7)
    _, scales = expand.expand_with_scales(m, bits=4, terms=4)
    s = np.asarray(scales)
    for i in range(1, len(s)):
        np.testing.assert_allclose(s[i - 1], s[i] * 16.0, rtol=1e-6)


def test_expand_zero_tensor():
    m = jnp.zeros((4, 8))
    planes, scales = expand.expand_with_scales(m, bits=4, terms=3)
    assert np.all(np.asarray(planes) == 0)
    assert np.all(np.asarray(scales) == 0)


# ------------------------------------------------------------- xint gemm


@settings(**SETTLE)
@given(
    k=st.integers(1, 3),
    t=st.integers(1, 4),
    n=st.integers(1, 16),
    o=st.integers(1, 16),
    kd=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_gemm_kernel_matches_ref(k, t, n, o, kd, seed):
    rng = np.random.default_rng(seed)
    w_planes = jnp.asarray(rng.integers(-8, 9, (k, o, kd)).astype(np.float32))
    a_planes = jnp.asarray(rng.integers(-8, 9, (t, n, kd)).astype(np.float32))
    w_scales = jnp.asarray(rng.uniform(0.01, 1.0, (k,)).astype(np.float32))
    a_scales = jnp.asarray(rng.uniform(0.01, 1.0, (t,)).astype(np.float32))
    got = xint_matmul.xint_gemm(w_planes, w_scales, a_planes, a_scales)
    want = ref.xint_gemm_ref(w_planes, w_scales, a_planes, a_scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-5)


@settings(**SETTLE)
@given(seed=st.integers(0, 2**16), bits=st.sampled_from([4, 8]))
def test_expanded_linear_converges_to_fp(seed, bits):
    x = rand((8, 32), seed)
    w = rand((12, 32), seed + 1, scale=0.3)
    fp = np.asarray(x @ w.T)
    errs = []
    for terms in (1, 3):
        y = ref.xint_linear_ref(x, w, bits, 2, terms)
        errs.append(np.linalg.norm(fp - np.asarray(y)) / np.linalg.norm(fp))
    assert errs[1] < errs[0], errs


def test_nsy_rank1_is_row_sum():
    m = rand((8, 16), 3)
    got = xint_matmul.nsy_rank1(m)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.sum(m, axis=1, keepdims=True)), rtol=1e-6
    )


# ------------------------------------------------------------- quantize


@settings(**SETTLE)
@given(
    rows=st.integers(1, 32),
    cols=st.integers(1, 64),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_quantize_kernel_matches_ref(rows, cols, bits, seed):
    x = rand((rows, cols), seed, scale=2.0)
    got = quantize.quantize_act_auto(x, bits=bits)
    want = ref.quantize_act_ref(x, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_quantize_error_bounded_by_step():
    x = rand((16, 16), 5, scale=1.5)
    bits = 4
    y = quantize.quantize_act_auto(x, bits=bits)
    step = float(jnp.max(jnp.abs(x))) / 2 ** (bits - 1)
    # one extra step of slack for the asymmetric clamp at +half-1
    assert float(jnp.max(jnp.abs(x - y))) <= step * 1.01


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_output_on_grid(bits):
    # exact idempotence doesn't hold (the +half-1 clamp can shrink the max
    # and thus the rescale), but outputs must lie on the scale grid
    x = rand((8, 8), 9)
    y = np.asarray(quantize.quantize_act_auto(x, bits=bits))
    step = float(jnp.max(jnp.abs(x))) / 2 ** (bits - 1)
    k = y / step
    np.testing.assert_allclose(k, np.round(k), atol=1e-4)
    # and a second pass moves values by at most one (new) step
    y2 = np.asarray(quantize.quantize_act_auto(jnp.asarray(y), bits=bits))
    assert np.max(np.abs(y - y2)) <= step * 1.01
