"""L1 Pallas kernel: the stacked xINT GEMM (Eq. 3).

`WA = Σ_{i,j} s_wi s_aj W̃_i Ã_j` re-associated for the MXU: the (i, j)
term grid is the two *outermost* Pallas grid axes, so each grid step
performs exactly one (bm, bk)×(bk, bn) tile matmul with a scalar scale
and accumulates into a VMEM scratch accumulator — the TPU analogue of
dispatching k·t independent low-bit matmuls to INT units (DESIGN.md §3,
Hardware-Adaptation).

Basis planes are integer-valued and bounded by 2^{X-1}, hence exactly
representable in bf16 for X ≤ 8; on a real TPU the same schedule feeds
the MXU int8 path. Under interpret=True we keep f32 for CPU numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(w_ref, a_ref, ws_ref, as_ref, out_ref, *, k_terms, t_terms):
    """Grid: (i=w_term, j=a_term). One scaled tile matmul per step."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    # zero the accumulator on the first term pair
    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0]  # (O, K) plane i
    a = a_ref[0]  # (N, K) plane j
    scale = ws_ref[i] * as_ref[j]
    # MXU-shaped contraction with f32 accumulation
    out_ref[...] += scale * jax.lax.dot_general(
        a, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def xint_gemm(w_planes, w_scales, a_planes, a_scales):
    """Expanded GEMM: w_planes (k, O, K), a_planes (t, N, K) → (N, O).

    The full plane pair is one VMEM tile here (models are small); for
    larger shapes the BlockSpecs gain an inner (m, n, k) tiling — the
    grid order keeps the accumulator resident either way.
    """
    k_terms, o, kdim = w_planes.shape
    t_terms, n, _ = a_planes.shape
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_terms=k_terms, t_terms=t_terms),
        grid=(k_terms, t_terms),
        in_specs=[
            pl.BlockSpec((1, o, kdim), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n, kdim), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((k_terms,), lambda i, j: (0,)),
            pl.BlockSpec((t_terms,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((n, o), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, o), jnp.float32),
        interpret=True,
    )(w_planes, a_planes, w_scales, a_scales)


def _nsy_kernel(m_ref, out_ref):
    """Rank-1 M_nsy product: row sums (the §4 `(M·1ᵀ)·1` trick, O(n²))."""
    out_ref[...] = jnp.sum(m_ref[...], axis=1, keepdims=True)


@jax.jit
def nsy_rank1(m):
    """Row-sum kernel used by the asymmetric zero-point terms. VPU-only."""
    r, c = m.shape
    return pl.pallas_call(
        _nsy_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((r, c), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((r, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), m.dtype),
        interpret=True,
    )(m)
