"""L1 Pallas kernel: residual series decomposition (Theorem 1).

The §4 parallel closed form makes every plane independent given the
scales, so the kernel parallelizes over the *term* axis: grid step `k`
computes `plane_k = round(M/s_k) - 2^X · round(M/s_{k-1})` for its VMEM
tile. On TPU each grid step is a VPU-only elementwise pass over a
(block_rows × 128) tile; no MXU involvement.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see DESIGN.md §6); the BlockSpec structure is still the
TPU schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expand_kernel(m_ref, scales_ref, out_ref, *, levels: float):
    """One (term, row-tile) grid step."""
    k = pl.program_id(0)
    m = m_ref[...]
    s_k = scales_ref[k]
    s_prev = jnp.where(k > 0, scales_ref[jnp.maximum(k - 1, 0)], 0.0)
    q_k = jnp.where(s_k > 0, jnp.round(m / jnp.maximum(s_k, 1e-30)), 0.0)
    q_prev = jnp.where(
        s_prev > 0, jnp.round(m / jnp.maximum(s_prev, 1e-30)), 0.0
    )
    out_ref[0, ...] = q_k - levels * q_prev


@functools.partial(jax.jit, static_argnames=("bits", "terms", "block_rows"))
def series_expand(m, scales, *, bits: int, terms: int, block_rows: int = 128):
    """Decompose `m` (R, C) into `terms` INT(bits) planes given the
    precomputed scale schedule (terms,). Returns planes (terms, R, C).

    VMEM budget per step: one (block_rows, C) input tile + one output
    tile ≈ 2·block_rows·C·4 B — 128×512 f32 tiles = 512 KiB, well under
    the 16 MiB VMEM envelope.
    """
    r, c = m.shape
    levels = float(2**bits)
    rows = min(block_rows, r)
    grid = (terms, pl.cdiv(r, rows))
    return pl.pallas_call(
        functools.partial(_expand_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, c), lambda k, i: (i, 0)),
            pl.BlockSpec((terms,), lambda k, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, rows, c), lambda k, i: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((terms, r, c), m.dtype),
        interpret=True,
    )(m, scales)


def expand_with_scales(m, *, bits: int, terms: int):
    """Convenience: compute the scale schedule then run the kernel."""
    from . import ref

    max_abs = jnp.max(jnp.abs(m))
    scales = jnp.array(ref.series_scales(max_abs, bits, terms), dtype=m.dtype)
    planes = series_expand(m, scales, bits=bits, terms=terms)
    return planes, scales
