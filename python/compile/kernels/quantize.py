"""L1 Pallas kernel: one-step activation fake-quantization.

Serve-time activation quantization for the plain-PTQ path and the 8-bit
first/last layers. Scalar scale comes in as an operand so the compiled
artifact is reusable across batches (scales are recomputed host-side or
by the expand kernel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, scale_ref, out_ref, *, half: float):
    x = x_ref[...]
    s = jnp.maximum(scale_ref[0], 1e-30)
    q = jnp.clip(jnp.round(x / s), -half, half - 1.0)
    out_ref[...] = q * s


@functools.partial(jax.jit, static_argnames=("bits", "block_rows"))
def quantize_act(x, scale, *, bits: int, block_rows: int = 128):
    """Fake-quantize x (R, C) at `bits` with a scalar scale (1,)."""
    r, c = x.shape
    rows = min(block_rows, r)
    return pl.pallas_call(
        functools.partial(_quant_kernel, half=float(2 ** (bits - 1))),
        grid=(pl.cdiv(r, rows),),
        in_specs=[
            pl.BlockSpec((rows, c), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=True,
    )(x, scale)


def quantize_act_auto(x, *, bits: int):
    """Compute the symmetric scale then quantize (matches ref oracle)."""
    half = 2.0 ** (bits - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / half
    return quantize_act(x, scale[None], bits=bits)
