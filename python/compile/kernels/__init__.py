from . import expand, quantize, ref, xint_matmul  # noqa: F401
