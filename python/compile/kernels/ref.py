"""Pure-jnp oracles for every Pallas kernel — the pytest ground truth.

These implement Theorem 1 (residual series expansion), Eq. 3 (expanded
GEMM) and the activation quantizer exactly as the paper states them, with
no kernel-level tiling tricks, so the Pallas implementations can be
validated by `assert_allclose`.
"""

import jax.numpy as jnp


def series_scales(max_abs, bits: int, terms: int):
    """Geometric scale schedule: scale_1 = max|M| / 2^{X-1},
    scale_{i+1} = scale_i / 2^X (Theorem 1's scale law)."""
    half = 2.0 ** (bits - 1)
    levels = 2.0**bits
    s1 = max_abs / half
    return [s1 / levels**i for i in range(terms)]


def series_expand_ref(m, bits: int, terms: int):
    """Reference Theorem-1 expansion (non-saturating symmetric,
    per-tensor). Returns (planes[terms, ...], scales[terms]).

    Uses the §4 parallel closed form
      plane_k = round(m / s_k) - 2^X * round(m / s_{k-1}).
    """
    max_abs = jnp.max(jnp.abs(m))
    scales = series_scales(max_abs, bits, terms)
    levels = 2.0**bits
    planes = []
    prev_q = jnp.zeros_like(m)
    for s in scales:
        q = jnp.where(s > 0, jnp.round(m / jnp.maximum(s, 1e-30)), 0.0)
        planes.append(q - levels * prev_q)
        prev_q = q
    return jnp.stack(planes), jnp.array(scales, dtype=m.dtype)


def series_reconstruct_ref(planes, scales):
    """Σ scale_i · plane_i."""
    return jnp.tensordot(scales, planes, axes=1)


def xint_gemm_ref(w_planes, w_scales, a_planes, a_scales):
    """Eq. 3: WA = Σ_{i,j} s_wi s_aj W̃_i Ã_j for
    w_planes (k, O, K), a_planes (t, N, K) → (N, O).

    The reference evaluates the k·t grid of integer matmuls explicitly.
    """
    k = w_planes.shape[0]
    t = a_planes.shape[0]
    n, o = a_planes.shape[1], w_planes.shape[1]
    out = jnp.zeros((n, o), dtype=jnp.float32)
    for i in range(k):
        for j in range(t):
            out = out + w_scales[i] * a_scales[j] * (a_planes[j] @ w_planes[i].T)
    return out


def quantize_act_ref(x, bits: int):
    """One-step symmetric fake quantization (the runtime activation path
    of plain PTQ; the serve-time quantizer artifact mirrors this)."""
    half = 2.0 ** (bits - 1)
    max_abs = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    scale = max_abs / half
    q = jnp.clip(jnp.round(x / scale), -half, half - 1)
    return q * scale


def xint_linear_ref(x, w, bits: int, w_terms: int, a_terms: int):
    """Full expanded linear layer y = x Wᵀ via Theorem 1 + Eq. 3."""
    w_planes, w_scales = series_expand_ref(w, bits, w_terms)
    a_planes, a_scales = series_expand_ref(x, bits, a_terms)
    return xint_gemm_ref(w_planes, w_scales, a_planes, a_scales)
