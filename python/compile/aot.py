"""AOT lowering: JAX → HLO **text** artifacts for the Rust PJRT runtime.

HLO text (not `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all shape-monomorphic, lowered with return_tuple=True):

  fp_mlp_b{B}.hlo.txt        — FP reference MLP forward
  xint_mlp_b{B}_w{X}t{T}.hlo.txt — layer-sync expanded MLP (Eq. 4)
  basis_mlp_b{B}_w{X}.hlo.txt    — one Theorem-2 basis slice
  quantize_act_b{B}_x{X}.hlo.txt — activation quantizer
  xint_gemm_k{K}t{T}.hlo.txt     — standalone expanded GEMM (perf bench)

Run: `python -m compile.aot --out-dir ../artifacts` (from python/).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import quantize, xint_matmul

# canonical MLP geometry shared with the Rust coordinator (runtime reads
# the manifest, so changing these here propagates)
DIN, HIDDEN, CLASSES = 256, 64, 10
BATCHES = (1, 8, 32)
BITS = 4
W_TERMS = 2
A_TERMS = 3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args):
    return jax.jit(fn).lower(*example_args)


def write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text)} chars)")
    return name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    f32 = jnp.float32
    manifest = {
        "din": DIN,
        "hidden": HIDDEN,
        "classes": CLASSES,
        "bits": BITS,
        "w_terms": W_TERMS,
        "a_terms": A_TERMS,
        "batches": list(BATCHES),
        "artifacts": {},
    }

    for b in BATCHES:
        # FP reference
        x = jax.ShapeDtypeStruct((b, DIN), f32)
        w1 = jax.ShapeDtypeStruct((HIDDEN, DIN), f32)
        b1 = jax.ShapeDtypeStruct((HIDDEN,), f32)
        w2 = jax.ShapeDtypeStruct((CLASSES, HIDDEN), f32)
        b2 = jax.ShapeDtypeStruct((CLASSES,), f32)
        lowered = lower_fn(model.fp_mlp, (x, w1, b1, w2, b2))
        manifest["artifacts"][f"fp_mlp_b{b}"] = write(
            args.out_dir, f"fp_mlp_b{b}.hlo.txt", to_hlo_text(lowered)
        )

        # layer-sync expanded MLP
        shapes = model.mlp_shapes(b, DIN, HIDDEN, CLASSES, W_TERMS)
        fn = functools.partial(model.xint_mlp, bits=BITS, a_terms=A_TERMS)
        lowered = lower_fn(fn, tuple(shapes.values()))
        manifest["artifacts"][f"xint_mlp_b{b}"] = write(
            args.out_dir, f"xint_mlp_b{b}_w{BITS}t{A_TERMS}.hlo.txt", to_hlo_text(lowered)
        )

        # one basis slice (single plane per layer)
        basis_shapes = model.mlp_shapes(b, DIN, HIDDEN, CLASSES, 1)
        fn = functools.partial(model.basis_mlp, bits=BITS)
        lowered = lower_fn(fn, tuple(basis_shapes.values()))
        manifest["artifacts"][f"basis_mlp_b{b}"] = write(
            args.out_dir, f"basis_mlp_b{b}_w{BITS}.hlo.txt", to_hlo_text(lowered)
        )

        # activation quantizer
        fn = functools.partial(quantize.quantize_act, bits=8)
        lowered = lower_fn(
            fn, (jax.ShapeDtypeStruct((b, DIN), f32), jax.ShapeDtypeStruct((1,), f32))
        )
        manifest["artifacts"][f"quantize_act_b{b}"] = write(
            args.out_dir, f"quantize_act_b{b}_x8.hlo.txt", to_hlo_text(lowered)
        )

    # standalone expanded GEMM for the perf bench (k=2, t=3, 64×256×64)
    k, t, n, o, kd = W_TERMS, A_TERMS, 64, 64, 256
    lowered = lower_fn(
        xint_matmul.xint_gemm,
        (
            jax.ShapeDtypeStruct((k, o, kd), f32),
            jax.ShapeDtypeStruct((k,), f32),
            jax.ShapeDtypeStruct((t, n, kd), f32),
            jax.ShapeDtypeStruct((t,), f32),
        ),
    )
    manifest["artifacts"]["xint_gemm"] = write(
        args.out_dir, f"xint_gemm_k{k}t{t}.hlo.txt", to_hlo_text(lowered)
    )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
