"""L2: JAX model graphs built on the L1 kernels.

Two families are lowered to HLO artifacts:

* `fp_mlp` / `xint_mlp` — a 2-layer MLP classifier whose hidden matmuls
  run through the Pallas xINT GEMM (Eq. 3/4). Weights arrive pre-expanded
  (planes + scales) from the Rust coordinator; activations are expanded
  in-graph by the Pallas expand kernel.
* `basis_mlp` — ONE basis-model slice `model_{i,j}` of Theorem 2: same
  topology, but the weight input is a single INT plane and the activation
  expansion index is baked in. The Rust coordinator launches t·k of these
  in parallel and AbelianAdd-reduces their outputs.

All functions are shape-monomorphic at lowering time (AOT), so `aot.py`
exports one artifact per (batch, config) variant.
"""

import jax
import jax.numpy as jnp

from .kernels import expand, ref, xint_matmul


def fp_mlp(x, w1, b1, w2, b2):
    """Reference FP MLP: x (N, D) → logits (N, C)."""
    h = jnp.maximum(x @ w1.T + b1, 0.0)
    return (h @ w2.T + b2,)


def _xint_linear(x, w_planes, w_scales, *, bits: int, a_terms: int):
    """Expanded linear layer: activations expanded in-graph (Pallas),
    weights pre-expanded host-side."""
    a_planes, a_scales = expand.expand_with_scales(x, bits=bits, terms=a_terms)
    return xint_matmul.xint_gemm(w_planes, w_scales, a_planes, a_scales)


def xint_mlp(x, w1_planes, w1_scales, b1, w2_planes, w2_scales, b2, *, bits: int, a_terms: int):
    """Series-expanded MLP (layer-sync mode, Eq. 4 per layer)."""
    h = _xint_linear(x, w1_planes, w1_scales, bits=bits, a_terms=a_terms)
    h = jnp.maximum(h + b1, 0.0)
    y = _xint_linear(h, w2_planes, w2_scales, bits=bits, a_terms=a_terms)
    return (y + b2,)


def basis_mlp(x, w1_plane, w1_scale, b1, w2_plane, w2_scale, b2, *, bits: int):
    """One Theorem-2 basis model `model_i`: every layer uses a single INT
    weight plane (term i); activations quantized at one step in-graph.
    Non-matmul pieces (bias, ReLU) are carried whole — the coordinator
    divides them by the basis count via AbelianMul before reduction.
    """
    a_planes, a_scales = expand.expand_with_scales(x, bits=bits, terms=1)
    h = xint_matmul.xint_gemm(w1_plane, w1_scale, a_planes, a_scales)
    h = jnp.maximum(h + b1, 0.0)
    a2_planes, a2_scales = expand.expand_with_scales(h, bits=bits, terms=1)
    y = xint_matmul.xint_gemm(w2_plane, w2_scale, a2_planes, a2_scales)
    return (y + b2,)


def expand_weights_host(w, *, bits: int, terms: int):
    """Host-side Theorem-1 weight expansion used when exporting weights
    alongside artifacts (mirrors the Rust ExpandedWeight)."""
    planes, scales = ref.series_expand_ref(jnp.asarray(w), bits, terms)
    return planes, scales


def mlp_shapes(batch: int, din: int, hidden: int, classes: int, w_terms: int):
    """ShapeDtypeStructs for AOT lowering of the xint_mlp entry point."""
    f32 = jnp.float32
    return dict(
        x=jax.ShapeDtypeStruct((batch, din), f32),
        w1_planes=jax.ShapeDtypeStruct((w_terms, hidden, din), f32),
        w1_scales=jax.ShapeDtypeStruct((w_terms,), f32),
        b1=jax.ShapeDtypeStruct((hidden,), f32),
        w2_planes=jax.ShapeDtypeStruct((w_terms, classes, hidden), f32),
        w2_scales=jax.ShapeDtypeStruct((w_terms,), f32),
        b2=jax.ShapeDtypeStruct((classes,), f32),
    )
