# Build-time compile package: JAX/Pallas kernels + AOT lowering.
# Never imported at serving time — the Rust binary consumes only the
# HLO-text artifacts this package emits.
