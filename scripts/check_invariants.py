#!/usr/bin/env python3
"""Repo-invariant lint: structural rules `cargo clippy` cannot express.

Scans non-test Rust code under rust/src/ (test regions — everything from
the first `#[cfg(test)]` / `#[cfg(all(test, ...))]` line to EOF, per repo
convention — and rust/vendor/ are exempt) and enforces:

  sync-shim         std::sync::atomic / std::thread are imported only by
                    util/sync.rs (the loom shim). One unshimmed atomic
                    silently escapes every loom model.
  no-println        println!/eprintln! only in the CLI (main.rs) and the
                    logger sink; library code logs through `log`.
  unwrap-ratchet    .unwrap()/.expect( counts on the serve/coordinator
                    hot path may only go down, never up, per file
                    (baseline: scripts/invariants_allowlist.json;
                    refresh a legitimate reduction with --write-baseline).
  blocking-io       socket-facing code (files referencing std::net) may
                    not call .read_exact(/.write_all( outside the
                    blocking-client module serve/protocol.rs — one
                    blocking call on the reactor thread stalls every
                    connection it owns.

Two former rules moved to the token-level analyzer (`fp-xint analyze`,
see ANALYSIS.md) and are NOT enforced here anymore: `ordering-comment`
(now the atomics pass, which also checks acquire/release pairing the
regex version never could) and `spankind-append` (now cross-checked
against the wire-constant registry in the protocol pass).

Exit 0 when clean; exit 1 with `file:line: [rule] message` per finding.
`--self-test` runs every rule against known-good and known-bad samples
and fails if any rule has lost its teeth.
"""

import argparse
import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "rust" / "src"
ALLOWLIST_PATH = Path(__file__).resolve().parent / "invariants_allowlist.json"

TEST_CUT_RE = re.compile(r"^#\[cfg\(test\)\]|^#\[cfg\(all\(test")
SYNC_RE = re.compile(r"std::sync::atomic|std::thread")
PRINTLN_RE = re.compile(r"(?<![\w!])e?println!")
UNWRAP_RE = re.compile(r"\.unwrap\(\)|\.expect\(")
BLOCKING_IO_RE = re.compile(r"\.read_exact\(|\.write_all\(")
NET_RE = re.compile(r"std::net")

SYNC_SHIM_FILE = "util/sync.rs"
# the one sanctioned home for blocking socket IO: the protocol module's
# clients (tests, CLI, the closed-loop loadgen) block by design
BLOCKING_IO_EXEMPT = "serve/protocol.rs"
PRINTLN_ALLOWED = {"main.rs", "util/logger.rs"}
RATCHET_DIRS = ("serve/", "coordinator/")


def non_test_region(lines):
    """Line count before the file's trailing test region."""
    for i, line in enumerate(lines):
        if TEST_CUT_RE.match(line):
            return i
    return len(lines)


def is_comment(line):
    return line.lstrip().startswith("//")


def check_sync_shim(rel, lines, cut):
    if rel == SYNC_SHIM_FILE:
        return []
    out = []
    for i, line in enumerate(lines[:cut]):
        if is_comment(line):
            continue
        if SYNC_RE.search(line):
            out.append((i + 1, "sync-shim",
                        "std::sync::atomic / std::thread outside util/sync.rs "
                        "(use crate::util::sync so loom models cover it)"))
    return out


def check_println(rel, lines, cut):
    if rel in PRINTLN_ALLOWED:
        return []
    out = []
    for i, line in enumerate(lines[:cut]):
        if is_comment(line):
            continue
        if PRINTLN_RE.search(line):
            out.append((i + 1, "no-println",
                        "println!/eprintln! outside the CLI and logger sink "
                        "(library code logs via the `log` facade)"))
    return out


def unwrap_count(lines, cut):
    return sum(len(UNWRAP_RE.findall(line))
               for line in lines[:cut] if not is_comment(line))


def check_unwrap_ratchet(rel, lines, cut, baseline):
    if not rel.startswith(RATCHET_DIRS):
        return []
    n = unwrap_count(lines, cut)
    allowed = baseline.get(rel, 0)
    if n > allowed:
        return [(1, "unwrap-ratchet",
                 f"{n} unwrap()/expect() on the hot path, baseline allows "
                 f"{allowed} — handle the error or shrink the count")]
    if n < allowed:
        print(f"note: {rel} is below its unwrap baseline ({n} < {allowed}); "
              f"run --write-baseline to ratchet down", file=sys.stderr)
    return []


def check_blocking_io(rel, lines, cut):
    if rel == BLOCKING_IO_EXEMPT:
        return []
    body = lines[:cut]
    if not any(NET_RE.search(line) for line in body if not is_comment(line)):
        return []
    out = []
    for i, line in enumerate(body):
        if is_comment(line):
            continue
        if BLOCKING_IO_RE.search(line):
            out.append((i + 1, "blocking-io",
                        "blocking read_exact/write_all in socket-facing code "
                        "(the reactor is nonblocking; blocking clients live in "
                        f"{BLOCKING_IO_EXEMPT})"))
    return out


def scan(baseline):
    findings = []
    for path in sorted(SRC.rglob("*.rs")):
        rel = path.relative_to(SRC).as_posix()
        lines = path.read_text().splitlines()
        cut = non_test_region(lines)
        for lineno, rule, msg in (
            check_sync_shim(rel, lines, cut)
            + check_println(rel, lines, cut)
            + check_unwrap_ratchet(rel, lines, cut, baseline)
            + check_blocking_io(rel, lines, cut)
        ):
            findings.append((f"rust/src/{rel}", lineno, rule, msg))
    return findings


def write_baseline():
    baseline = {}
    for path in sorted(SRC.rglob("*.rs")):
        rel = path.relative_to(SRC).as_posix()
        if not rel.startswith(RATCHET_DIRS):
            continue
        lines = path.read_text().splitlines()
        n = unwrap_count(lines, non_test_region(lines))
        if n:
            baseline[rel] = n
    ALLOWLIST_PATH.write_text(json.dumps(baseline, indent=2) + "\n")
    print(f"wrote {ALLOWLIST_PATH.relative_to(ROOT)} ({len(baseline)} files)")


# ---------------------------------------------------------------- self-test

GOOD_ATOMIC = """\
use crate::util::sync::atomic::{AtomicU64, Ordering};
fn f(c: &AtomicU64) -> u64 {
    // ordering: Relaxed — lone statistic.
    c.load(Ordering::Relaxed)
}
"""

BAD_SYNC = "use std::sync::atomic::AtomicU64;\n"
BAD_THREAD = "fn f() { std::thread::sleep(std::time::Duration::from_secs(1)); }\n"
TEST_GATED_SYNC = "#[cfg(test)]\nmod tests {\n    use std::sync::atomic::AtomicU64;\n}\n"
BAD_PRINTLN = 'fn f() { println!("x"); }\n'
BAD_EPRINTLN = 'fn f() { eprintln!("x"); }\n'
UNWRAPPY = "fn f(x: Option<u32>) -> u32 { x.unwrap() + x.expect(\"y\") }\n"
BAD_BLOCKING = (
    "use std::net::TcpStream;\n"
    'fn f(s: &mut TcpStream) { s.write_all(b"x").unwrap(); }\n'
)
BLOCKING_NO_NET = (
    "use std::fs::File;\n"
    "fn f(mut f: File, buf: &mut [u8]) { let _ = f.read_exact(buf); }\n"
)
TEST_GATED_BLOCKING = (
    "use std::net::TcpStream;\n"
    "#[cfg(test)]\n"
    'mod tests { fn f(s: &mut std::net::TcpStream) { s.write_all(b"x").unwrap(); } }\n'
)


def self_test():
    cases = [
        # (description, rule fn over split lines, expect finding rules)
        ("clean atomic passes", lambda ls: check_sync_shim("a.rs", ls, len(ls)),
         GOOD_ATOMIC, []),
        ("std::sync::atomic caught", lambda ls: check_sync_shim("a.rs", ls, len(ls)),
         BAD_SYNC, ["sync-shim"]),
        ("std::thread caught", lambda ls: check_sync_shim("a.rs", ls, len(ls)),
         BAD_THREAD, ["sync-shim"]),
        ("test region exempt", lambda ls: check_sync_shim("a.rs", ls, non_test_region(ls)),
         TEST_GATED_SYNC, []),
        ("shim file exempt", lambda ls: check_sync_shim(SYNC_SHIM_FILE, ls, len(ls)),
         BAD_SYNC, []),
        ("println caught", lambda ls: check_println("a.rs", ls, len(ls)),
         BAD_PRINTLN, ["no-println"]),
        ("eprintln caught", lambda ls: check_println("a.rs", ls, len(ls)),
         BAD_EPRINTLN, ["no-println"]),
        ("cli println allowed", lambda ls: check_println("main.rs", ls, len(ls)),
         BAD_PRINTLN, []),
        ("ratchet holds at baseline",
         lambda ls: check_unwrap_ratchet("serve/a.rs", ls, len(ls), {"serve/a.rs": 2}),
         UNWRAPPY, []),
        ("ratchet catches growth",
         lambda ls: check_unwrap_ratchet("serve/a.rs", ls, len(ls), {"serve/a.rs": 1}),
         UNWRAPPY, ["unwrap-ratchet"]),
        ("ratchet scoped to hot path",
         lambda ls: check_unwrap_ratchet("tensor/a.rs", ls, len(ls), {}),
         UNWRAPPY, []),
        ("blocking io caught", lambda ls: check_blocking_io("serve/server.rs", ls, len(ls)),
         BAD_BLOCKING, ["blocking-io"]),
        ("protocol module exempt",
         lambda ls: check_blocking_io(BLOCKING_IO_EXEMPT, ls, len(ls)),
         BAD_BLOCKING, []),
        ("non-socket files out of scope",
         lambda ls: check_blocking_io("tensor/io.rs", ls, len(ls)),
         BLOCKING_NO_NET, []),
        ("test region blocking exempt",
         lambda ls: check_blocking_io("serve/server.rs", ls, non_test_region(ls)),
         TEST_GATED_BLOCKING, []),
    ]
    failed = 0
    for desc, fn, text, expect in cases:
        got = [rule for (_, rule, _) in fn(text.splitlines())]
        if got != expect:
            print(f"self-test FAIL: {desc}: expected {expect}, got {got}")
            failed += 1
    if failed:
        print(f"self-test: {failed}/{len(cases)} cases failed")
        return 1
    print(f"self-test: {len(cases)} cases ok")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--self-test", action="store_true",
                    help="exercise every rule against crafted samples")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the unwrap-ratchet allowlist from the tree")
    args = ap.parse_args()

    if args.self_test:
        sys.exit(self_test())
    if args.write_baseline:
        write_baseline()
        return

    baseline = {}
    if ALLOWLIST_PATH.exists():
        baseline = json.loads(ALLOWLIST_PATH.read_text())
    findings = scan(baseline)
    for path, lineno, rule, msg in findings:
        print(f"{path}:{lineno}: [{rule}] {msg}")
    if findings:
        print(f"{len(findings)} invariant violation(s)")
        sys.exit(1)
    print("invariants ok")


if __name__ == "__main__":
    main()
