#!/usr/bin/env python3
"""Repo-invariant lint: structural rules `cargo clippy` cannot express.

Scans non-test Rust code under rust/src/ (test regions — everything from
the first `#[cfg(test)]` / `#[cfg(all(test, ...))]` line to EOF, per repo
convention — and rust/vendor/ are exempt) and enforces:

  sync-shim         std::sync::atomic / std::thread are imported only by
                    util/sync.rs (the loom shim). One unshimmed atomic
                    silently escapes every loom model.
  no-println        println!/eprintln! only in the CLI (main.rs) and the
                    logger sink; library code logs through `log`.
  ordering-comment  every Ordering::{Relaxed,Acquire,Release,AcqRel,
                    SeqCst} choice carries a `// ordering:` rationale on
                    the same line or within the 8 preceding lines.
  unwrap-ratchet    .unwrap()/.expect( counts on the serve/coordinator
                    hot path may only go down, never up, per file
                    (baseline: scripts/invariants_allowlist.json;
                    refresh a legitimate reduction with --write-baseline).
  spankind-append   the SpanKind numbering is wire format (packed into
                    ring slots and exported): pinned variants keep their
                    names and discriminants; new ones append.
  blocking-io       socket-facing code (files referencing std::net) may
                    not call .read_exact(/.write_all( outside the
                    blocking-client module serve/protocol.rs — one
                    blocking call on the reactor thread stalls every
                    connection it owns.

Exit 0 when clean; exit 1 with `file:line: [rule] message` per finding.
`--self-test` runs every rule against known-good and known-bad samples
and fails if any rule has lost its teeth.
"""

import argparse
import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "rust" / "src"
ALLOWLIST_PATH = Path(__file__).resolve().parent / "invariants_allowlist.json"

TEST_CUT_RE = re.compile(r"^#\[cfg\(test\)\]|^#\[cfg\(all\(test")
SYNC_RE = re.compile(r"std::sync::atomic|std::thread")
PRINTLN_RE = re.compile(r"(?<![\w!])e?println!")
ORDERING_RE = re.compile(r"Ordering::(Relaxed|Acquire|Release|AcqRel|SeqCst)")
ORDERING_COMMENT = "// ordering:"
ORDERING_WINDOW = 8
UNWRAP_RE = re.compile(r"\.unwrap\(\)|\.expect\(")
BLOCKING_IO_RE = re.compile(r"\.read_exact\(|\.write_all\(")
NET_RE = re.compile(r"std::net")

SYNC_SHIM_FILE = "util/sync.rs"
# the one sanctioned home for blocking socket IO: the protocol module's
# clients (tests, CLI, the closed-loop loadgen) block by design
BLOCKING_IO_EXEMPT = "serve/protocol.rs"
PRINTLN_ALLOWED = {"main.rs", "util/logger.rs"}
RATCHET_DIRS = ("serve/", "coordinator/")

SPANKIND_FILE = "obs/recorder.rs"
# The wire-stable prefix of the SpanKind numbering. Appending here (with
# the next discriminant) when a variant is added IS the review gate —
# renaming or renumbering an existing entry is the bug this rule exists
# to catch.
SPANKIND_PINNED = [
    ("Request", 0),
    ("Decode", 1),
    ("Admission", 2),
    ("QueueWait", 3),
    ("BatchForm", 4),
    ("Schedule", 5),
    ("WorkerTerm", 6),
    ("Reduce", 7),
    ("Reply", 8),
    ("LayerGrid", 9),
    ("Accept", 10),
    ("Write", 11),
    ("Refine", 12),
]
SPANKIND_VARIANT_RE = re.compile(r"^\s*(\w+)\s*=\s*(\d+)\s*,")


def non_test_region(lines):
    """Line count before the file's trailing test region."""
    for i, line in enumerate(lines):
        if TEST_CUT_RE.match(line):
            return i
    return len(lines)


def is_comment(line):
    return line.lstrip().startswith("//")


def check_sync_shim(rel, lines, cut):
    if rel == SYNC_SHIM_FILE:
        return []
    out = []
    for i, line in enumerate(lines[:cut]):
        if is_comment(line):
            continue
        if SYNC_RE.search(line):
            out.append((i + 1, "sync-shim",
                        "std::sync::atomic / std::thread outside util/sync.rs "
                        "(use crate::util::sync so loom models cover it)"))
    return out


def check_println(rel, lines, cut):
    if rel in PRINTLN_ALLOWED:
        return []
    out = []
    for i, line in enumerate(lines[:cut]):
        if is_comment(line):
            continue
        if PRINTLN_RE.search(line):
            out.append((i + 1, "no-println",
                        "println!/eprintln! outside the CLI and logger sink "
                        "(library code logs via the `log` facade)"))
    return out


def check_ordering_comments(rel, lines, cut):
    out = []
    for i, line in enumerate(lines[:cut]):
        if is_comment(line) or not ORDERING_RE.search(line):
            continue
        window = lines[max(0, i - ORDERING_WINDOW):i + 1]
        if not any(ORDERING_COMMENT in w for w in window):
            out.append((i + 1, "ordering-comment",
                        f"memory-ordering choice without a '{ORDERING_COMMENT}' "
                        f"rationale within {ORDERING_WINDOW} lines"))
    return out


def unwrap_count(lines, cut):
    return sum(len(UNWRAP_RE.findall(line))
               for line in lines[:cut] if not is_comment(line))


def check_unwrap_ratchet(rel, lines, cut, baseline):
    if not rel.startswith(RATCHET_DIRS):
        return []
    n = unwrap_count(lines, cut)
    allowed = baseline.get(rel, 0)
    if n > allowed:
        return [(1, "unwrap-ratchet",
                 f"{n} unwrap()/expect() on the hot path, baseline allows "
                 f"{allowed} — handle the error or shrink the count")]
    if n < allowed:
        print(f"note: {rel} is below its unwrap baseline ({n} < {allowed}); "
              f"run --write-baseline to ratchet down", file=sys.stderr)
    return []


def check_blocking_io(rel, lines, cut):
    if rel == BLOCKING_IO_EXEMPT:
        return []
    body = lines[:cut]
    if not any(NET_RE.search(line) for line in body if not is_comment(line)):
        return []
    out = []
    for i, line in enumerate(body):
        if is_comment(line):
            continue
        if BLOCKING_IO_RE.search(line):
            out.append((i + 1, "blocking-io",
                        "blocking read_exact/write_all in socket-facing code "
                        "(the reactor is nonblocking; blocking clients live in "
                        f"{BLOCKING_IO_EXEMPT})"))
    return out


def parse_spankind(lines):
    variants, in_enum = [], False
    for line in lines:
        if re.match(r"^pub enum SpanKind\b", line):
            in_enum = True
            continue
        if in_enum:
            if line.startswith("}"):
                break
            m = SPANKIND_VARIANT_RE.match(line)
            if m:
                variants.append((m.group(1), int(m.group(2))))
    return variants


def check_spankind(lines):
    variants = parse_spankind(lines)
    if not variants:
        return [(1, "spankind-append", "could not parse the SpanKind enum")]
    out = []
    for idx, (name, disc) in enumerate(SPANKIND_PINNED):
        if idx >= len(variants):
            out.append((1, "spankind-append",
                        f"pinned variant {name} = {disc} was removed"))
        elif variants[idx] != (name, disc):
            out.append((1, "spankind-append",
                        f"pinned variant {name} = {disc} became "
                        f"{variants[idx][0]} = {variants[idx][1]} — the "
                        f"numbering is wire format; append instead"))
    for idx in range(len(SPANKIND_PINNED), len(variants)):
        name, disc = variants[idx]
        if disc != idx:
            out.append((1, "spankind-append",
                        f"appended variant {name} must take the next "
                        f"discriminant {idx}, not {disc}"))
        else:
            print(f"note: SpanKind gained {name} = {disc}; pin it in "
                  f"SPANKIND_PINNED of this script", file=sys.stderr)
    return out


def scan(baseline):
    findings = []
    for path in sorted(SRC.rglob("*.rs")):
        rel = path.relative_to(SRC).as_posix()
        lines = path.read_text().splitlines()
        cut = non_test_region(lines)
        for lineno, rule, msg in (
            check_sync_shim(rel, lines, cut)
            + check_println(rel, lines, cut)
            + check_ordering_comments(rel, lines, cut)
            + check_unwrap_ratchet(rel, lines, cut, baseline)
            + check_blocking_io(rel, lines, cut)
            + (check_spankind(lines) if rel == SPANKIND_FILE else [])
        ):
            findings.append((f"rust/src/{rel}", lineno, rule, msg))
    return findings


def write_baseline():
    baseline = {}
    for path in sorted(SRC.rglob("*.rs")):
        rel = path.relative_to(SRC).as_posix()
        if not rel.startswith(RATCHET_DIRS):
            continue
        lines = path.read_text().splitlines()
        n = unwrap_count(lines, non_test_region(lines))
        if n:
            baseline[rel] = n
    ALLOWLIST_PATH.write_text(json.dumps(baseline, indent=2) + "\n")
    print(f"wrote {ALLOWLIST_PATH.relative_to(ROOT)} ({len(baseline)} files)")


# ---------------------------------------------------------------- self-test

GOOD_ATOMIC = """\
use crate::util::sync::atomic::{AtomicU64, Ordering};
fn f(c: &AtomicU64) -> u64 {
    // ordering: Relaxed — lone statistic.
    c.load(Ordering::Relaxed)
}
"""

BAD_SYNC = "use std::sync::atomic::AtomicU64;\n"
BAD_THREAD = "fn f() { std::thread::sleep(std::time::Duration::from_secs(1)); }\n"
TEST_GATED_SYNC = "#[cfg(test)]\nmod tests {\n    use std::sync::atomic::AtomicU64;\n}\n"
BAD_PRINTLN = 'fn f() { println!("x"); }\n'
BAD_EPRINTLN = 'fn f() { eprintln!("x"); }\n'
BAD_ORDERING = """\
use crate::util::sync::atomic::{AtomicU64, Ordering};
fn f(c: &AtomicU64) -> u64 {
    c.load(Ordering::Acquire)
}
"""
FAR_COMMENT_ORDERING = (
    "// ordering: Acquire — too far away to count.\n"
    + "\n" * 9
    + "fn f(c: &AtomicU64) -> u64 { c.load(Ordering::Acquire) }\n"
)
CMP_ORDERING = "fn f(a: u32, b: u32) -> bool { a.cmp(&b) == std::cmp::Ordering::Equal }\n"
UNWRAPPY = "fn f(x: Option<u32>) -> u32 { x.unwrap() + x.expect(\"y\") }\n"
SPANKIND_OK = (
    "pub enum SpanKind {\n"
    + "".join(f"    {n} = {d},\n" for n, d in SPANKIND_PINNED)
    + "}\n"
)
SPANKIND_APPENDED = (
    "pub enum SpanKind {\n"
    + "".join(f"    {n} = {d},\n" for n, d in SPANKIND_PINNED)
    + f"    NewStage = {len(SPANKIND_PINNED)},\n"
    + "}\n"
)
SPANKIND_RENUMBERED = SPANKIND_OK.replace("Reduce = 7", "Reduce = 11")
SPANKIND_RENAMED = SPANKIND_OK.replace("Decode = 1", "Parse = 1")
BAD_BLOCKING = (
    "use std::net::TcpStream;\n"
    'fn f(s: &mut TcpStream) { s.write_all(b"x").unwrap(); }\n'
)
BLOCKING_NO_NET = (
    "use std::fs::File;\n"
    "fn f(mut f: File, buf: &mut [u8]) { let _ = f.read_exact(buf); }\n"
)
TEST_GATED_BLOCKING = (
    "use std::net::TcpStream;\n"
    "#[cfg(test)]\n"
    'mod tests { fn f(s: &mut std::net::TcpStream) { s.write_all(b"x").unwrap(); } }\n'
)


def self_test():
    cases = [
        # (description, rule fn over split lines, expect finding rules)
        ("clean atomic passes", lambda ls: check_sync_shim("a.rs", ls, len(ls))
         + check_ordering_comments("a.rs", ls, len(ls)), GOOD_ATOMIC, []),
        ("std::sync::atomic caught", lambda ls: check_sync_shim("a.rs", ls, len(ls)),
         BAD_SYNC, ["sync-shim"]),
        ("std::thread caught", lambda ls: check_sync_shim("a.rs", ls, len(ls)),
         BAD_THREAD, ["sync-shim"]),
        ("test region exempt", lambda ls: check_sync_shim("a.rs", ls, non_test_region(ls)),
         TEST_GATED_SYNC, []),
        ("shim file exempt", lambda ls: check_sync_shim(SYNC_SHIM_FILE, ls, len(ls)),
         BAD_SYNC, []),
        ("println caught", lambda ls: check_println("a.rs", ls, len(ls)),
         BAD_PRINTLN, ["no-println"]),
        ("eprintln caught", lambda ls: check_println("a.rs", ls, len(ls)),
         BAD_EPRINTLN, ["no-println"]),
        ("cli println allowed", lambda ls: check_println("main.rs", ls, len(ls)),
         BAD_PRINTLN, []),
        ("bare Ordering caught", lambda ls: check_ordering_comments("a.rs", ls, len(ls)),
         BAD_ORDERING, ["ordering-comment"]),
        ("comment past window caught",
         lambda ls: check_ordering_comments("a.rs", ls, len(ls)),
         FAR_COMMENT_ORDERING, ["ordering-comment"]),
        ("cmp::Ordering ignored", lambda ls: check_ordering_comments("a.rs", ls, len(ls)),
         CMP_ORDERING, []),
        ("ratchet holds at baseline",
         lambda ls: check_unwrap_ratchet("serve/a.rs", ls, len(ls), {"serve/a.rs": 2}),
         UNWRAPPY, []),
        ("ratchet catches growth",
         lambda ls: check_unwrap_ratchet("serve/a.rs", ls, len(ls), {"serve/a.rs": 1}),
         UNWRAPPY, ["unwrap-ratchet"]),
        ("ratchet scoped to hot path",
         lambda ls: check_unwrap_ratchet("tensor/a.rs", ls, len(ls), {}),
         UNWRAPPY, []),
        ("blocking io caught", lambda ls: check_blocking_io("serve/server.rs", ls, len(ls)),
         BAD_BLOCKING, ["blocking-io"]),
        ("protocol module exempt",
         lambda ls: check_blocking_io(BLOCKING_IO_EXEMPT, ls, len(ls)),
         BAD_BLOCKING, []),
        ("non-socket files out of scope",
         lambda ls: check_blocking_io("tensor/io.rs", ls, len(ls)),
         BLOCKING_NO_NET, []),
        ("test region blocking exempt",
         lambda ls: check_blocking_io("serve/server.rs", ls, non_test_region(ls)),
         TEST_GATED_BLOCKING, []),
        ("spankind snapshot passes", lambda ls: check_spankind(ls), SPANKIND_OK, []),
        ("spankind append allowed", lambda ls: check_spankind(ls), SPANKIND_APPENDED, []),
        ("spankind renumber caught", lambda ls: check_spankind(ls),
         SPANKIND_RENUMBERED, ["spankind-append"]),
        ("spankind rename caught", lambda ls: check_spankind(ls),
         SPANKIND_RENAMED, ["spankind-append"]),
    ]
    failed = 0
    for desc, fn, text, expect in cases:
        got = [rule for (_, rule, _) in fn(text.splitlines())]
        if got != expect:
            print(f"self-test FAIL: {desc}: expected {expect}, got {got}")
            failed += 1
    if failed:
        print(f"self-test: {failed}/{len(cases)} cases failed")
        return 1
    print(f"self-test: {len(cases)} cases ok")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--self-test", action="store_true",
                    help="exercise every rule against crafted samples")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the unwrap-ratchet allowlist from the tree")
    args = ap.parse_args()

    if args.self_test:
        sys.exit(self_test())
    if args.write_baseline:
        write_baseline()
        return

    baseline = {}
    if ALLOWLIST_PATH.exists():
        baseline = json.loads(ALLOWLIST_PATH.read_text())
    findings = scan(baseline)
    for path, lineno, rule, msg in findings:
        print(f"{path}:{lineno}: [{rule}] {msg}")
    if findings:
        print(f"{len(findings)} invariant violation(s)")
        sys.exit(1)
    print("invariants ok")


if __name__ == "__main__":
    main()
