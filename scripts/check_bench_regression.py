#!/usr/bin/env python3
"""Cross-PR benchmark regression check over the BENCH_*.json files.

Two kinds of gates:

1. Absolute gates — invariants that must hold on every run regardless of
   any baseline (e.g. the per-tier batcher must keep Exact p99 within 2×
   of its unloaded p99 while a Throughput flood saturates its own queue).

2. Baseline gates — compare the current run against the JSONs committed
   under ``benchmarks/baseline/``. Latency-like metrics may not regress
   by more than their tolerance factor; count-like metrics may not drop
   below their tolerance fraction of the baseline. When no baseline has
   been committed yet (or a key is missing), the gate is skipped with a
   note — refresh the baseline (from ``rust/``, the cargo root) with:

       BENCH_JSON_DIR=../benchmarks/baseline cargo bench --bench perf_qos
       BENCH_JSON_DIR=../benchmarks/baseline cargo bench --bench perf_coordinator

CI noise note: hosted runners are noisy, so tolerances are deliberately
loose — this gate exists to catch step-function regressions (a 2-10×
latency cliff, a collapse in completions), not 10% drift.
"""

import argparse
import json
import pathlib
import sys


def lookup(doc, dotted):
    """Walk a dotted path through nested dicts; None when absent."""
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


# (file, dotted path, predicate description, check)
# The design target for the flood scenario is 2x (see perf_qos). The CI
# gate allows 5x: the ratio compares two separate short traces on a
# shared runner, where ordinary noisy-neighbor stalls can eat a 1.5x
# margin. The gate exists only to catch the FIFO-style head-of-line
# cliff, which measures an order of magnitude; the committed-baseline
# gates (below) are the tight trend check.
ABSOLUTE_GATES = [
    (
        "BENCH_qos.json",
        "flood.wdrr_exact_p99_ratio",
        "Exact p99 under a Throughput flood avoids the head-of-line cliff (WDRR)",
        lambda v: v <= 5.0,
    ),
    # Per-tier pressure isolation (PR 5): the flood-isolation scenario
    # runs the Throughput flood WITH a controller attached. The served
    # Balanced precision is a deterministic quantity (every reply serves
    # the tier's calibrated budget unless ITS OWN loop steps, and its
    # queue can never cross the watermark at the bench's offered load),
    # so the delta gates at exactly zero. The p99 ratio gate mirrors the
    # wdrr 5x noise allowance above.
    (
        "BENCH_qos.json",
        "isolation.balanced_terms_delta",
        "a Throughput flood leaves Balanced's served terms bit-for-bit unmoved",
        lambda v: v == 0,
    ),
    (
        "BENCH_qos.json",
        "isolation.balanced_grid_delta",
        "a Throughput flood leaves Balanced's served grid spend unmoved",
        lambda v: v == 0,
    ),
    (
        "BENCH_qos.json",
        "isolation.balanced_degrade_events",
        "the flood never steps the bystander tier's own pressure",
        lambda v: v == 0,
    ),
    (
        "BENCH_qos.json",
        "isolation.thpt_degrade_events",
        "the flooded tier's own pressure ramps while its queue saturates",
        lambda v: v >= 1,
    ),
    (
        "BENCH_qos.json",
        "isolation.thpt_drained_pressure",
        "the flooded tier's pressure fully recovers once its queue drains",
        lambda v: v == 0,
    ),
    (
        "BENCH_qos.json",
        "isolation.balanced_p99_ratio",
        "Balanced p99 under a Throughput flood stays within noise of unloaded (<= 5x)",
        lambda v: v <= 5.0,
    ),
    # Trace-plane contract (PR 6): the flight recorder sits on the hot
    # path of every request, so its overhead gates absolutely. The bench
    # interleaves recorder-off/on rounds and compares min-over-rounds
    # Exact p99, which cancels runner drift; 1.10 allows residual noise
    # while catching any real per-span cost (a lock or allocation on the
    # span path measures well past 10%).
    (
        "BENCH_qos.json",
        "tracing.exact_p99_inflation",
        "flight-recorder spans keep Exact p99 within 10% of the untraced run",
        lambda v: v <= 1.10,
    ),
    # Reactor serving plane (connscale): the epoll reactor replaced the
    # thread-per-connection server, so its closed-loop latency gates
    # against an in-bench thread-per-conn baseline — both sides are
    # min-over-3-interleaved-rounds p99s in the same process, so runner
    # drift cancels and 1.10 catches a real per-request reactor cost.
    # The open-loop scenario must actually reach connection scale (the
    # whole point of the rewrite), and streamed BestEffort first frames
    # must land strictly ahead of the full reply at the tail — for every
    # request first <= full by construction, so ratio >= 1.0 means
    # progressive refinement degenerated into a single burst.
    (
        "BENCH_qos.json",
        "connscale.open_loop_conns",
        "the open-loop harness drives at least 10k concurrent connections",
        lambda v: v >= 10_000,
    ),
    (
        "BENCH_qos.json",
        "connscale.exact_p99_ratio",
        "reactor closed-loop Exact p99 within 10% of the thread-per-conn baseline",
        lambda v: v <= 1.10,
    ),
    (
        "BENCH_qos.json",
        "connscale.be_first_frame_p99_ratio",
        "streamed BestEffort first-frame p99 lands ahead of the full-reply p99",
        lambda v: v < 1.0,
    ),
    # Term-budget contract (perf_budget): bit-identity and the grid-term
    # cut are deterministic, so they gate absolutely on every run. The
    # 1.5x wall-clock floor lives in MEASURED_FLOOR_GATES below: it arms
    # only once a baseline measurement from the CI runner class has been
    # committed (gating an absolute wall-clock number that has never
    # been measured on that hardware could brick CI repo-wide).
    (
        "BENCH_budget.json",
        "exact_bit_identical",
        "Exact tier is bit-identical to the pre-budget forward",
        lambda v: v == 1,
    ),
    (
        "BENCH_budget.json",
        "grid_cut_ratio",
        "BestEffort executes at most half the full grid's INT GEMMs (deterministic)",
        lambda v: v >= 2.0,
    ),
    # Planned-vs-uniform contract (deterministic: seeded model + probes,
    # no timing): the sensitivity-planned allocation must track the full
    # forward at least as closely as the uniform budget at an equal grid
    # ceiling. Small slack (0.95) because the greedy planner optimizes
    # the per-layer residual sum, a proxy for output max-diff.
    (
        "BENCH_budget.json",
        "planned.improvement",
        "planned allocation is no worse than uniform at equal grid spend",
        lambda v: v >= 0.95,
    ),
    # Packed-kernel contract (perf_gemm): bit-identity between the
    # scalar grid and the packed SIMD / row-parallel kernel is
    # deterministic and gates absolutely. The speedups are ratios of two
    # min-of-iterations timings in the same process, so runner drift
    # cancels; the ISSUE targets (>= 5x single-thread from i8 packing +
    # maddubs, >= 8x with row-parallel lanes on the 4-vCPU runner class)
    # gate at the largest bench shape (256x256x1024, k*t = 6 GEMMs),
    # where the kernel's advantage is fully amortized.
    (
        "BENCH_gemm.json",
        "bit_identical",
        "packed SIMD and row-parallel kernels are bit-identical to the scalar grid",
        lambda v: v == 1,
    ),
    (
        "BENCH_gemm.json",
        "largest.packed_speedup",
        "packed single-thread kernel >= 5x over the scalar grid at the largest shape",
        lambda v: v >= 5.0,
    ),
    (
        "BENCH_gemm.json",
        "largest.parallel_speedup",
        "row-parallel kernel >= 8x over the scalar grid at the largest shape",
        lambda v: v >= 8.0,
    ),
]

# (file, dotted path, predicate description, check) — absolute floors on
# measured quantities, armed only when the committed baseline contains
# the same key (i.e. the quantity has been observed on this hardware
# class at least once). The bench measures the speedup as an
# adjacent-pair p50 ratio (full vs BestEffort back to back), so runner
# drift largely cancels and the floor is stable once proven reachable.
MEASURED_FLOOR_GATES = [
    (
        "BENCH_budget.json",
        "besteffort_speedup",
        "BestEffort layer budget yields >= 1.5x replication-mode speedup",
        lambda v: v >= 1.5,
    ),
]

# (file, dotted path, kind, tolerance)
#   kind "latency": current <= baseline * tolerance
#   kind "count":   current >= baseline * tolerance
BASELINE_GATES = [
    ("BENCH_qos.json", "flood.wdrr_exact_p99_ms", "latency", 1.5),
    ("BENCH_qos.json", "spike.qos_p99_ms", "latency", 1.5),
    ("BENCH_qos.json", "spike.qos_completed", "count", 0.8),
    # term-budget trend: the BestEffort replication speedup may not
    # collapse relative to the recorded baseline, and the full-grid
    # forward may not cliff
    ("BENCH_budget.json", "besteffort_speedup", "count", 0.8),
    ("BENCH_budget.json", "full_forward_ms", "latency", 2.0),
    # packed-kernel trend: the wall-clock of the packed path may not
    # cliff, and the parallel advantage may not collapse
    ("BENCH_gemm.json", "largest.packed_ms", "latency", 2.0),
    ("BENCH_gemm.json", "largest.parallel_speedup", "count", 0.8),
]


def dotted_paths(doc, prefix=""):
    """All dotted key paths through nested dicts (lists are leaves)."""
    paths = set()
    if isinstance(doc, dict):
        for key, value in doc.items():
            path = f"{prefix}.{key}" if prefix else key
            paths.add(path)
            paths |= dotted_paths(value, path)
    return paths


def check_schema_drift(baseline_dir, current_dir, fname, failures):
    """A committed baseline whose keys the current bench no longer emits
    is stale — fail loudly naming the file instead of silently skipping
    its gates (the old behavior: a schema change quietly disarmed every
    baseline gate for that file)."""
    try:
        base = json.loads((baseline_dir / fname).read_text())
        cur = json.loads((current_dir / fname).read_text())
    except (OSError, json.JSONDecodeError):
        return  # missing/unparseable files are reported by the gates
    stale = sorted(dotted_paths(base) - dotted_paths(cur))
    if stale:
        failures.append(
            f"{baseline_dir / fname}: baseline schema drift — keys {stale} are no "
            "longer emitted by the current bench; the committed baseline is stale, "
            "re-record it via the record-baseline workflow"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True, help="dir with committed BENCH_*.json")
    ap.add_argument("--current", required=True, help="dir with this run's BENCH_*.json")
    args = ap.parse_args()
    baseline_dir = pathlib.Path(args.baseline)
    current_dir = pathlib.Path(args.current)

    failures = []

    def load(directory, name):
        path = directory / name
        if not path.exists():
            return None
        try:
            return json.loads(path.read_text())
        except json.JSONDecodeError as e:
            failures.append(f"{path}: unparseable JSON ({e})")
            return None

    for fname, path, desc, check in ABSOLUTE_GATES:
        doc = load(current_dir, fname)
        if doc is None:
            failures.append(f"{fname}: missing from current run (absolute gate '{desc}')")
            continue
        value = lookup(doc, path)
        if value is None:
            failures.append(f"{fname}:{path}: key missing (absolute gate '{desc}')")
        elif not check(value):
            failures.append(f"{fname}:{path} = {value}: FAILED '{desc}'")
        else:
            print(f"ok  [absolute] {fname}:{path} = {value} ({desc})")

    if not baseline_dir.is_dir() or not any(baseline_dir.glob("BENCH_*.json")):
        print(
            f"note: no baseline committed under {baseline_dir} — skipping "
            "baseline gates (see benchmarks/baseline/README.md to record one)"
        )
    else:
        # stale-baseline detection before any gate runs: schema drift in a
        # committed baseline must fail, not silently disarm its gates
        for fname in sorted(p.name for p in baseline_dir.glob("BENCH_*.json")):
            check_schema_drift(baseline_dir, current_dir, fname, failures)
        for fname, path, desc, check in MEASURED_FLOOR_GATES:
            base_doc = load(baseline_dir, fname)
            cur_doc = load(current_dir, fname)
            if base_doc is None or lookup(base_doc, path) is None:
                print(f"skip [floor] {fname}:{path}: not yet measured in the baseline")
                continue
            value = None if cur_doc is None else lookup(cur_doc, path)
            if value is None:
                failures.append(f"{fname}:{path}: key missing (floor gate '{desc}')")
            elif not check(value):
                failures.append(f"{fname}:{path} = {value}: FAILED '{desc}'")
            else:
                print(f"ok  [floor] {fname}:{path} = {value} ({desc})")
        for fname, path, kind, tol in BASELINE_GATES:
            base_doc = load(baseline_dir, fname)
            cur_doc = load(current_dir, fname)
            if base_doc is None or cur_doc is None:
                print(f"skip [baseline] {fname}:{path}: file missing on one side")
                continue
            base, cur = lookup(base_doc, path), lookup(cur_doc, path)
            if base is None or cur is None:
                print(f"skip [baseline] {fname}:{path}: key missing on one side")
                continue
            if kind == "latency" and cur > base * tol:
                failures.append(
                    f"{fname}:{path}: {cur:.3f} vs baseline {base:.3f} "
                    f"(regressed past {tol}x tolerance)"
                )
            elif kind == "count" and cur < base * tol:
                failures.append(
                    f"{fname}:{path}: {cur:.3f} vs baseline {base:.3f} "
                    f"(dropped below {tol}x tolerance)"
                )
            else:
                print(f"ok  [baseline] {fname}:{path}: {cur:.3f} (baseline {base:.3f})")

    if failures:
        print("\nbenchmark regression check FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbenchmark regression check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
