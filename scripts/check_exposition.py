#!/usr/bin/env python3
"""Lint the serving plane's Prometheus-style text exposition.

Checks, per scrape file:

1. Exactly one ``# TYPE`` (and at most one ``# HELP``) per metric
   family; histogram children (``_bucket``/``_sum``/``_count``) fold
   into their base family.
2. No duplicate series (same metric name + same label set).
3. Every value parses as a float (``NaN``/``+Inf``/``-Inf`` included).
4. Every series belongs to a family that declared a ``# TYPE``.
5. Histogram sanity: per label set, ``le`` buckets are cumulative
   (non-decreasing) and the ``+Inf`` bucket equals ``_count``.

Usage: check_exposition.py <exposition.txt>
"""

import re
import sys

HISTOGRAM_SUFFIXES = ("_bucket", "_sum", "_count")
SERIES_RE = re.compile(r"^([A-Za-z_:][A-Za-z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")
LE_RE = re.compile(r'le="([^"]*)"')


def base_family(name, histogram_families):
    for suffix in HISTOGRAM_SUFFIXES:
        stem = name[: -len(suffix)] if name.endswith(suffix) else None
        if stem and stem in histogram_families:
            return stem
    return name


def strip_le(labels):
    """Label set without the ``le`` pair — the histogram series key."""
    inner = labels[1:-1] if labels else ""
    pairs = [p for p in inner.split(",") if p and not p.startswith("le=")]
    return ",".join(pairs)


def lint(text):
    failures = []
    types = {}
    helps = set()
    series_seen = set()
    histogram_families = set()
    # (family, labels-without-le) -> {"buckets": [(le, value)], "count": float}
    histograms = {}
    n_series = 0

    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                failures.append(f"line {lineno}: malformed TYPE line: {line!r}")
                continue
            fam, kind = parts[2], parts[3]
            if fam in types:
                failures.append(f"line {lineno}: duplicate # TYPE for family {fam}")
            types[fam] = kind
            if kind == "histogram":
                histogram_families.add(fam)
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 4:
                failures.append(f"line {lineno}: malformed HELP line: {line!r}")
                continue
            fam = parts[2]
            if fam in helps:
                failures.append(f"line {lineno}: duplicate # HELP for family {fam}")
            helps.add(fam)
            continue
        if line.startswith("#"):
            continue

        m = SERIES_RE.match(line)
        if not m:
            failures.append(f"line {lineno}: unparseable series line: {line!r}")
            continue
        name, labels, raw = m.group(1), m.group(2) or "", m.group(3)
        n_series += 1
        key = (name, labels)
        if key in series_seen:
            failures.append(f"line {lineno}: duplicate series {name}{labels}")
        series_seen.add(key)
        fam = base_family(name, histogram_families)
        if fam not in types:
            failures.append(f"line {lineno}: series {name} has no # TYPE (family {fam})")
        try:
            value = float(raw)
        except ValueError:
            failures.append(f"line {lineno}: unparseable value {raw!r} for {name}")
            continue
        if fam in histogram_families:
            hist = histograms.setdefault((fam, strip_le(labels)), {"buckets": [], "count": None})
            if name.endswith("_bucket"):
                le = LE_RE.search(labels)
                if le is None:
                    failures.append(f"line {lineno}: bucket series without le label: {line!r}")
                else:
                    hist["buckets"].append((le.group(1), value))
            elif name.endswith("_count"):
                hist["count"] = value

    for (fam, labels), hist in sorted(histograms.items()):
        where = f"{fam}{{{labels}}}"
        values = [v for _, v in hist["buckets"]]
        if any(later < earlier for earlier, later in zip(values, values[1:])):
            failures.append(f"{where}: bucket counts are not cumulative: {values}")
        inf = [v for le, v in hist["buckets"] if le == "+Inf"]
        if not inf:
            failures.append(f"{where}: no le=\"+Inf\" bucket")
        elif hist["count"] is not None and inf[0] != hist["count"]:
            failures.append(f"{where}: +Inf bucket {inf[0]} != _count {hist['count']}")

    if n_series == 0:
        failures.append("no series found — empty or unreadable exposition")
    return failures, len(types), n_series


def main(path):
    try:
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
    except OSError as e:
        print(f"cannot read {path}: {e}", file=sys.stderr)
        return 1
    failures, n_families, n_series = lint(text)
    if failures:
        print(f"exposition lint FAILED for {path}:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"exposition lint passed: {n_families} families, {n_series} series")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print("usage: check_exposition.py <exposition.txt>", file=sys.stderr)
        sys.exit(2)
    sys.exit(main(sys.argv[1]))
