//! Perf — runtime term budgets in replication mode: the same layer-sync
//! quantized model served at every tier's layer-granularity
//! [`TermBudget`]. The Exact tier must be bit-identical to the legacy
//! full-grid forward; the BestEffort tier must run a real speedup by
//! executing fewer (i, j) INT GEMM terms, not by skipping layers.
//!
//!     cargo bench --bench perf_budget
//!
//! Emits `BENCH_budget.json` (per-tier latency / grid terms / rel err +
//! the BestEffort speedup and the Exact bit-identity flag) so the
//! regression gate can hold the budget contract across PRs. The gated
//! speedup is measured as an *adjacent* full-vs-budget pair of p50s
//! (back-to-back on the same core, so runner drift cancels), and the
//! grid-term cut is gated deterministically.

use fp_xint::bench_support::write_bench_json;
use fp_xint::models::quantized::quantize_model;
use fp_xint::models::zoo;
use fp_xint::qos::{QosConfig, TermController, Tier};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::util::json::Json;
use fp_xint::util::{logger, BenchTimer, Table};
use fp_xint::xint::layer::LayerPolicy;
use fp_xint::xint::TermBudget;

fn main() {
    logger::init(false);
    let timer = BenchTimer::new(2, 10);
    let mut rng = Rng::seed(77);
    let probe = Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng);
    let mut m = zoo::mini_resnet_a(10, 78);
    let _ = m.forward_train(&probe); // settle BN stats before folding
    let q = quantize_model(&m, LayerPolicy::new(4, 4)); // k=2, t=4 interior
    let x = Tensor::randn(&[8, 1, 16, 16], 1.0, &mut rng);

    // Exact contract: the budgeted stack with a full budget reproduces
    // the legacy forward bit for bit (shared natural-order grid path)
    let legacy = q.forward(&x);
    let (full_y, full_stats) = q.forward_with(&x, &TermBudget::full());
    let exact_bit_identical = legacy.data() == full_y.data();

    // tier ladder → layer budgets via the controller (uncalibrated
    // defaults; replication mode = single whole-model worker)
    let ctl = TermController::new(QosConfig::new(1));
    let full_time = timer.run(|| q.forward_with(&x, &TermBudget::full()));

    let mut table = Table::new(
        "perf — replication-mode forward under per-tier layer budgets (mini_resnet_a W4A4)",
        &["tier", "budget (w×a)", "grid terms", "forward (ms)", "speedup", "rel err"],
    );
    let mut tier_json: Vec<Json> = Vec::new();
    let mut besteffort_grid = full_stats.grid_terms;
    for tier in Tier::ALL {
        let budget = ctl.layer_budget_for(tier);
        let (y, stats) = q.forward_with(&x, &budget);
        let s = timer.run(|| q.forward_with(&x, &budget));
        let speedup = full_time.p50 / s.p50;
        let rel = legacy.sub(&y).norm() / legacy.norm().max(1e-12);
        if tier == Tier::BestEffort {
            besteffort_grid = stats.grid_terms;
        }
        table.row_str(&[
            tier.name(),
            &budget.to_string(),
            &stats.grid_terms.to_string(),
            &format!("{:.3}", s.p50 * 1e3),
            &format!("{speedup:.2}×"),
            &format!("{rel:.2e}"),
        ]);
        tier_json.push(Json::obj([
            ("tier", Json::str(tier.name())),
            ("grid_terms", Json::num(stats.grid_terms as f64)),
            ("forward_ms", Json::num(s.p50 * 1e3)),
            ("speedup", Json::num(speedup)),
            ("rel_err", Json::num(rel as f64)),
        ]));
    }
    table.print();

    // the gated speedup: an adjacent full/BestEffort pair, measured
    // back to back so shared-runner drift hits both sides equally
    let be_budget = ctl.layer_budget_for(Tier::BestEffort);
    let full_adj = timer.run(|| q.forward_with(&x, &TermBudget::full()));
    let be_adj = timer.run(|| q.forward_with(&x, &be_budget));
    let besteffort_speedup = full_adj.p50 / be_adj.p50;

    println!(
        "\nfull grid: {} GEMM terms over {} expanded layers; exact bit-identical: {}",
        full_stats.grid_terms, full_stats.layers, exact_bit_identical
    );
    println!(
        "besteffort: {} GEMM terms (full: {}), adjacent-pair speedup {besteffort_speedup:.2}× \
         (target ≥ 1.5×)",
        besteffort_grid, full_stats.grid_terms
    );

    let json = Json::obj([
        ("bench", Json::str("budget")),
        ("model", Json::str("mini_resnet_a_w4a4")),
        ("full_forward_ms", Json::num(full_adj.p50 * 1e3)),
        ("full_grid_terms", Json::num(full_stats.grid_terms as f64)),
        ("exact_bit_identical", Json::num(if exact_bit_identical { 1.0 } else { 0.0 })),
        ("besteffort_speedup", Json::num(besteffort_speedup)),
        ("besteffort_grid_terms", Json::num(besteffort_grid as f64)),
        // deterministic compute-cut ratio (independent of runner noise)
        (
            "grid_cut_ratio",
            Json::num(full_stats.grid_terms as f64 / (besteffort_grid as f64).max(1.0)),
        ),
        ("tiers", Json::Arr(tier_json)),
    ]);
    match write_bench_json("budget", &json) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nBENCH json write failed: {e}"),
    }
    println!(
        "\ntarget: the Exact tier is bit-identical to the pre-budget forward;\n\
         BestEffort cuts the executed (i, j) grid (k·t → 1) for a ≥ 1.5×\n\
         replication-mode speedup — precision-for-latency at layer granularity."
    );
}
