//! Perf — runtime budget plans in replication mode: the same layer-sync
//! quantized model served at every tier's [`BudgetPlan`]. The Exact
//! tier must be bit-identical to the legacy full-grid forward; the
//! BestEffort tier must run a real speedup by executing fewer (i, j)
//! INT GEMM terms, not by skipping layers; and the sensitivity-planned
//! allocation must beat the uniform budget on output max-diff at an
//! equal total grid-term count (the BudgetPlan PR's headline claim).
//!
//!     cargo bench --bench perf_budget
//!
//! Emits `BENCH_budget.json` (per-tier latency / grid terms / rel err +
//! the BestEffort speedup, the Exact bit-identity flag, and the
//! planned-vs-uniform comparison) so the regression gate can hold the
//! budget contract across PRs. The gated speedup is measured as an
//! *adjacent* full-vs-budget pair of p50s (back-to-back on the same
//! core, so runner drift cancels); the grid-term cut and the
//! planned-vs-uniform max-diff comparison are deterministic.

use fp_xint::bench_support::write_bench_json;
use fp_xint::datasets::SynthImg;
use fp_xint::models::quantized::quantize_model;
use fp_xint::models::zoo;
use fp_xint::qos::{QosConfig, TermController, Tier};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::train::{train_classifier, TrainConfig};
use fp_xint::util::json::Json;
use fp_xint::util::{logger, BenchTimer, Table};
use fp_xint::xint::layer::LayerPolicy;
use fp_xint::xint::planner::{BudgetPlanner, LayerGridProfile};
use fp_xint::xint::{BudgetPlan, ExpansionMonitor, TermBudget};

fn main() {
    logger::init(false);
    let timer = BenchTimer::new(2, 10);
    let mut rng = Rng::seed(77);
    // briefly trained zoo model: trained activations have heterogeneous
    // per-layer scales, which is what per-layer planning exploits
    let data = SynthImg::new(10, 1, 16, 0.15, 79);
    let mut m = zoo::mini_resnet_a(10, 78);
    let tcfg = TrainConfig { steps: 60, batch: 16, lr: 0.05, log_every: 1000 };
    train_classifier(&mut m, &data, &tcfg);
    let q = quantize_model(&m, LayerPolicy::new(4, 4)); // k=2, t=4 interior
    let x = Tensor::randn(&[8, 1, 16, 16], 1.0, &mut rng);

    // Exact contract: the budgeted stack with a full plan reproduces
    // the legacy forward bit for bit (shared natural-order grid path)
    let legacy = q.forward(&x);
    let (full_y, full_stats) = q.forward_with(&x, &BudgetPlan::full());
    let exact_bit_identical = legacy.data() == full_y.data();

    // tier ladder → uniform layer budgets via the controller
    // (uncalibrated defaults; replication mode = single whole-model
    // worker, plans fall back to uniform without layer calibration)
    let ctl = TermController::new(QosConfig::new(1));
    let full_time = timer.run(|| q.forward_with(&x, &BudgetPlan::full()));

    let mut table = Table::new(
        "perf — replication-mode forward under per-tier layer budgets (mini_resnet_a W4A4)",
        &["tier", "plan", "grid terms", "forward (ms)", "speedup", "rel err"],
    );
    let mut tier_json: Vec<Json> = Vec::new();
    let mut besteffort_grid = full_stats.grid_terms;
    for tier in Tier::ALL {
        let plan = ctl.plan_for(tier);
        let (y, stats) = q.forward_with(&x, &plan);
        let s = timer.run(|| q.forward_with(&x, &plan));
        let speedup = full_time.p50 / s.p50;
        let rel = legacy.sub(&y).norm() / legacy.norm().max(1e-12);
        if tier == Tier::BestEffort {
            besteffort_grid = stats.grid_terms;
        }
        table.row_str(&[
            tier.name(),
            &plan.to_string(),
            &stats.grid_terms.to_string(),
            &format!("{:.3}", s.p50 * 1e3),
            &format!("{speedup:.2}×"),
            &format!("{rel:.2e}"),
        ]);
        tier_json.push(Json::obj([
            ("tier", Json::str(tier.name())),
            ("grid_terms", Json::num(stats.grid_terms as f64)),
            ("forward_ms", Json::num(s.p50 * 1e3)),
            ("speedup", Json::num(speedup)),
            ("rel_err", Json::num(rel as f64)),
        ]));
    }
    table.print();

    // the gated speedup: an adjacent full/BestEffort pair, measured
    // back to back so shared-runner drift hits both sides equally
    let be_plan = ctl.plan_for(Tier::BestEffort);
    let full_adj = timer.run(|| q.forward_with(&x, &BudgetPlan::full()));
    let be_adj = timer.run(|| q.forward_with(&x, &be_plan));
    let besteffort_speedup = full_adj.p50 / be_adj.p50;

    // ---- planned vs uniform at an equal total grid-term count ----
    // profile each layer's convergence curve on calibration batches,
    // then give the sensitivity planner exactly the grid ceiling the
    // uniform 2-term budget spends and compare output max-diff
    let mut mon = ExpansionMonitor::new();
    for which in 0..3u64 {
        let probe = data.batch(8, 10 + which).x;
        q.observe_layers(&probe, &mut mon).expect("one config per layer series");
    }
    let profiles = q.grid_profiles(&mon);
    let uniform_cap = 2usize;
    // the ceiling is the uniform budget's EXACT spend (both axes
    // clamped per layer), so the planner redistributes the same total
    // the uniform baseline actually executes — never more
    let ceiling = BudgetPlanner::grid_cost(&profiles, uniform_cap, uniform_cap);
    let uniform_plan = BudgetPlan::uniform(TermBudget::new(uniform_cap, uniform_cap));
    // cap the planner's weight axis like the uniform budget does, so
    // each activation term costs what the baseline would pay for it
    let capped: Vec<LayerGridProfile> = profiles
        .iter()
        .map(|p| {
            let mut p = p.clone();
            if !p.exempt {
                p.w_terms = p.w_terms.min(uniform_cap).max(1);
            }
            p
        })
        .collect();
    let planned = BudgetPlanner::new(ceiling).plan(&capped);
    let (y_uniform, s_uniform) = q.forward_with(&x, &uniform_plan);
    let (y_planned, s_planned) = q.forward_with(&x, &planned);
    let scale = legacy.max_abs().max(1e-12);
    let uniform_max_diff = legacy.sub(&y_uniform).max_abs() / scale;
    let planned_max_diff = legacy.sub(&y_planned).max_abs() / scale;
    // max-diff improvement of planning at equal spend (> 1 = planned
    // is closer to the full forward than uniform)
    let improvement = uniform_max_diff as f64 / (planned_max_diff as f64).max(1e-12);

    let mut ptable = Table::new(
        "planned vs uniform allocation (equal grid ceiling, vs full forward)",
        &["allocation", "ceiling", "grid terms", "max diff"],
    );
    ptable.row_str(&[
        "uniform",
        &ceiling.to_string(),
        &s_uniform.grid_terms.to_string(),
        &format!("{uniform_max_diff:.3e}"),
    ]);
    ptable.row_str(&[
        &planned.to_string(),
        &planned.total_grid_terms().unwrap_or(0).to_string(),
        &s_planned.grid_terms.to_string(),
        &format!("{planned_max_diff:.3e}"),
    ]);
    ptable.print();

    println!(
        "\nfull grid: {} GEMM terms over {} expanded layers; exact bit-identical: {}",
        full_stats.grid_terms, full_stats.layers, exact_bit_identical
    );
    println!(
        "besteffort: {} GEMM terms (full: {}), adjacent-pair speedup {besteffort_speedup:.2}× \
         (target ≥ 1.5×)",
        besteffort_grid, full_stats.grid_terms
    );
    println!(
        "planned vs uniform at ceiling {ceiling}: max diff {planned_max_diff:.3e} vs \
         {uniform_max_diff:.3e} ({improvement:.2}× better; target ≥ 1×)"
    );

    let json = Json::obj([
        ("bench", Json::str("budget")),
        ("model", Json::str("mini_resnet_a_w4a4")),
        ("full_forward_ms", Json::num(full_adj.p50 * 1e3)),
        ("full_grid_terms", Json::num(full_stats.grid_terms as f64)),
        ("exact_bit_identical", Json::num(if exact_bit_identical { 1.0 } else { 0.0 })),
        ("besteffort_speedup", Json::num(besteffort_speedup)),
        ("besteffort_grid_terms", Json::num(besteffort_grid as f64)),
        // deterministic compute-cut ratio (independent of runner noise)
        (
            "grid_cut_ratio",
            Json::num(full_stats.grid_terms as f64 / (besteffort_grid as f64).max(1.0)),
        ),
        // planned-vs-uniform comparison (deterministic: seeded model,
        // seeded probes, no timing involved)
        (
            "planned",
            Json::obj([
                ("ceiling", Json::num(ceiling as f64)),
                ("uniform_grid_terms", Json::num(s_uniform.grid_terms as f64)),
                ("planned_grid_terms", Json::num(s_planned.grid_terms as f64)),
                ("uniform_max_diff", Json::num(uniform_max_diff as f64)),
                ("planned_max_diff", Json::num(planned_max_diff as f64)),
                ("improvement", Json::num(improvement)),
            ]),
        ),
        ("tiers", Json::Arr(tier_json)),
    ]);
    match write_bench_json("budget", &json) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nBENCH json write failed: {e}"),
    }
    println!(
        "\ntarget: the Exact tier is bit-identical to the pre-budget forward;\n\
         BestEffort cuts the executed (i, j) grid (k·t → 1) for a ≥ 1.5×\n\
         replication-mode speedup; and at an equal grid ceiling the\n\
         sensitivity-planned allocation tracks the full forward at least\n\
         as closely as the uniform budget — per-layer precision where it\n\
         buys the most."
    );
}
