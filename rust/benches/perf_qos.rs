//! Perf — QoS control plane: truncated-series serving cost, per-tier
//! latency under mixed traffic, and the degraded-mode scenario (queue
//! pressure lowers term budgets instead of shedding).
//!
//!     cargo bench --bench perf_qos
//!
//! Emits `BENCH_qos.json` (per-tier throughput/p50/p99 + spike sheds)
//! so the perf trajectory is machine-trackable across PRs.

use fp_xint::bench_support::write_bench_json;
use fp_xint::coordinator::{
    BatcherConfig, Coordinator, ExpansionScheduler, ServicePolicy, WorkerPool,
};
use fp_xint::datasets::RequestTrace;
use fp_xint::obs::TraceRecorder;
use fp_xint::qos::{QosConfig, TermController, Tier, NUM_TIERS};
use fp_xint::serve::loadgen::{run_open_loop, run_trace_mix, LoadReport, OpenLoopConfig};
use fp_xint::serve::protocol::{client_infer_tier, encode_response, read_u32, read_u64, STREAM_FLAG};
use fp_xint::serve::serve_tcp;
use fp_xint::serve::workers::{mlp_basis_factory_with, BiasPlacement, MlpWeights};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::util::json::Json;
use fp_xint::util::stats::Summary;
use fp_xint::util::sync::atomic::{AtomicBool, Ordering};
use fp_xint::util::sync::{thread, Mutex};
use fp_xint::util::{logger, BenchTimer, Table};
use fp_xint::xint::{BitSpec, ExpandConfig, ExpansionMonitor};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

const TERMS: usize = 8;
const BITS: u32 = 4;
const DIN: usize = 256;

fn weights(seed: u64) -> MlpWeights {
    let mut rng = Rng::seed(seed);
    MlpWeights {
        w1: Tensor::randn(&[128, DIN], 0.3, &mut rng),
        b1: Tensor::randn(&[128], 0.1, &mut rng),
        w2: Tensor::randn(&[10, 128], 0.3, &mut rng),
        b2: Tensor::randn(&[10], 0.1, &mut rng),
    }
}

fn calibrated_controller(anytime: bool) -> Arc<TermController> {
    calibrated_with(QosConfig::new(TERMS).with_anytime(anytime))
}

fn calibrated_with(qcfg: QosConfig) -> Arc<TermController> {
    let mut mon = ExpansionMonitor::new();
    let cfg = ExpandConfig::symmetric(BitSpec::int(BITS), TERMS);
    let mut rng = Rng::seed(11);
    for _ in 0..4 {
        mon.observe(&Tensor::randn(&[32, DIN], 1.0, &mut rng), &cfg)
            .expect("one config per monitor series");
    }
    let ctl = TermController::new(qcfg);
    ctl.calibrate(&mon);
    Arc::new(ctl)
}

fn qos_coordinator(
    w: &MlpWeights,
    cfg: BatcherConfig,
    controller: Option<Arc<TermController>>,
) -> Arc<Coordinator> {
    let pool =
        WorkerPool::new(TERMS, mlp_basis_factory_with(w, BITS, TERMS, BiasPlacement::FirstTerm));
    let mut sched = ExpansionScheduler::new(pool);
    if let Some(c) = controller {
        sched = sched.with_controller(c);
    }
    Arc::new(Coordinator::new(cfg, sched))
}

fn traced_coordinator(
    w: &MlpWeights,
    cfg: BatcherConfig,
    rec: Arc<TraceRecorder>,
) -> Arc<Coordinator> {
    let pool =
        WorkerPool::new(TERMS, mlp_basis_factory_with(w, BITS, TERMS, BiasPlacement::FirstTerm));
    Arc::new(Coordinator::new(cfg, ExpansionScheduler::new(pool).with_recorder(rec)))
}

/// Minimal blocking thread-per-connection v3 server — the architecture
/// the epoll reactor replaced, kept here as the closed-loop latency
/// baseline for the connscale scenario.
fn baseline_thread_per_conn(
    coord: Arc<Coordinator>,
) -> (std::net::SocketAddr, Arc<AtomicBool>, thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind baseline listener");
    let addr = listener.local_addr().expect("baseline local addr");
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let h = thread::spawn(move || {
        for conn in listener.incoming() {
            // ordering: SeqCst — lone on/off stop flag, no protocol.
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(mut s) = conn else { continue };
            let coord = coord.clone();
            thread::spawn(move || loop {
                let Ok(n) = read_u32(&mut s) else { break };
                let Ok(d) = read_u32(&mut s) else { break };
                let Ok(word) = read_u32(&mut s) else { break };
                let Ok(trace_id) = read_u64(&mut s) else { break };
                let mut buf = vec![0u8; (n as usize) * (d as usize) * 4];
                if s.read_exact(&mut buf).is_err() {
                    break;
                }
                let data: Vec<f32> = buf
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let x = Tensor::from_vec(&[n as usize, d as usize], data);
                let tier = Tier::from_u32(word & !STREAM_FLAG).unwrap_or(Tier::Exact);
                let Ok(rx) = coord.submit_tier_traced(x, tier, trace_id) else { break };
                let Ok(resp) = rx.recv() else { break };
                if resp.error.is_some()
                    || s.write_all(&encode_response(resp.trace_id, &resp.logits)).is_err()
                {
                    break;
                }
            });
        }
    });
    (addr, stop, h)
}

/// Closed-loop p99 over `threads × reqs` blocking Exact requests.
fn closed_loop_p99(addr: std::net::SocketAddr, x: &Tensor, threads: usize, reqs: usize) -> f64 {
    let lat = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let lat = lat.clone();
            let x = x.clone();
            thread::spawn(move || {
                for _ in 0..reqs {
                    let t = Instant::now();
                    if client_infer_tier(addr, &x, Tier::Exact).is_ok() {
                        let mut v = lat.lock().unwrap_or_else(|p| p.into_inner());
                        v.push(t.elapsed().as_secs_f64());
                    }
                }
            })
        })
        .collect();
    for h in handles {
        let _ = h.join();
    }
    let v = lat.lock().unwrap_or_else(|p| p.into_inner());
    Summary::of(&v).p99
}

fn tier_row(table: &mut Table, rep: &LoadReport, tier: Tier, coord: &Coordinator) {
    let Some(t) = rep.per_tier.iter().find(|t| t.tier == tier) else { return };
    table.row_str(&[
        tier.name(),
        &t.completed.to_string(),
        &format!("{:.2}", t.latency.p50 * 1e3),
        &format!("{:.2}", t.latency.p99 * 1e3),
        &format!("{:.2}", coord.metrics.tier_mean_terms(tier)),
        &format!("{:.2e}", coord.metrics.tier_est_loss(tier)),
    ]);
}

fn main() {
    logger::init(false);
    let timer = BenchTimer::new(3, 20);
    let w = weights(41);
    let mut rng = Rng::seed(42);
    let x = Tensor::randn(&[16, DIN], 1.0, &mut rng);

    // (a) truncated-reduction cost: the first n workers of the pool
    let pool =
        WorkerPool::new(TERMS, mlp_basis_factory_with(&w, BITS, TERMS, BiasPlacement::FirstTerm));
    let sched = ExpansionScheduler::new(pool);
    let mut t1 = Table::new(
        "perf — truncated prefix reduction (8 basis workers available)",
        &["terms", "forward (ms)", "vs full"],
    );
    let full = timer.run(|| sched.forward(x.clone()).unwrap());
    for &n in &[1usize, 2, 4, 8] {
        let r = timer.run(|| sched.forward_truncated(x.clone(), n).unwrap());
        t1.row_str(&[
            &n.to_string(),
            &format!("{:.3}", r.mean * 1e3),
            &format!("{:.2}×", full.mean / r.mean),
        ]);
    }
    t1.print();
    sched.shutdown();

    // (b) mixed-tier serving with the controller calibrated from the
    // §5.3 monitor: per-tier latency / terms / estimated loss
    let ctl = calibrated_controller(false);
    let snap = ctl.snapshot();
    println!("\ncalibrated budgets (terms per tier): {:?}", snap.budgets);
    let coord = qos_coordinator(&w, BatcherConfig::uniform(16, 500, 256), Some(ctl.clone()));
    let mix = [
        (Tier::Exact, 0.25),
        (Tier::Balanced, 0.25),
        (Tier::Throughput, 0.25),
        (Tier::BestEffort, 0.25),
    ];
    let trace = RequestTrace::new(300.0, 87);
    let rep = run_trace_mix(&coord, &trace, 1.0, DIN, 1.0, &mix);
    let mut t2 = Table::new(
        "perf — mixed-tier traffic (300 rps Poisson, calibrated controller)",
        &["tier", "completed", "p50 (ms)", "p99 (ms)", "mean terms", "est loss"],
    );
    for tier in Tier::ALL {
        tier_row(&mut t2, &rep, tier, &coord);
    }
    t2.print();
    println!("aggregate: {rep}");
    let mixed_json: Vec<Json> = Tier::ALL
        .iter()
        .filter_map(|&tier| {
            let t = rep.per_tier.iter().find(|t| t.tier == tier)?;
            Some(Json::obj([
                ("tier", Json::str(tier.name())),
                ("completed", Json::num(t.completed as f64)),
                ("p50_ms", Json::num(t.latency.p50 * 1e3)),
                ("p99_ms", Json::num(t.latency.p99 * 1e3)),
                ("mean_terms", Json::num(coord.metrics.tier_mean_terms(tier))),
                ("est_loss", Json::num(coord.metrics.tier_est_loss(tier))),
            ]))
        })
        .collect();

    // (c) degraded mode: a load spike against the seed batcher config
    // (small queue, no controller → sheds) vs the same queue with the
    // controller (precision degrades, availability holds)
    let spike_cfg = BatcherConfig::uniform(16, 500, 32);
    let spike_mix = [
        (Tier::Balanced, 0.4),
        (Tier::Throughput, 0.3),
        (Tier::BestEffort, 0.3),
    ];
    let spike = RequestTrace::new(700.0, 88);
    let seed_coord = qos_coordinator(&w, spike_cfg, None);
    let seed_rep = run_trace_mix(&seed_coord, &spike, 1.0, DIN, 1.0, &spike_mix);
    let ctl2 = calibrated_controller(false);
    let qos_coord = qos_coordinator(&w, spike_cfg, Some(ctl2.clone()));
    let qos_rep = run_trace_mix(&qos_coord, &spike, 1.0, DIN, 1.0, &spike_mix);
    let mut t3 = Table::new(
        "perf — 700 rps spike, queue_cap 32: shed-on-full vs degrade-precision",
        &["config", "offered", "completed", "shed", "p99 (ms)", "mean terms (BE)"],
    );
    t3.row_str(&[
        "seed (no controller)",
        &seed_rep.offered.to_string(),
        &seed_rep.completed.to_string(),
        &seed_rep.shed.to_string(),
        &format!("{:.2}", seed_rep.latency.p99 * 1e3),
        &format!("{:.2}", seed_coord.metrics.tier_mean_terms(Tier::BestEffort)),
    ]);
    t3.row_str(&[
        "qos controller",
        &qos_rep.offered.to_string(),
        &qos_rep.completed.to_string(),
        &qos_rep.shed.to_string(),
        &format!("{:.2}", qos_rep.latency.p99 * 1e3),
        &format!("{:.2}", qos_coord.metrics.tier_mean_terms(Tier::BestEffort)),
    ]);
    t3.print();
    let s2 = ctl2.snapshot();
    println!(
        "controller pressure after spike: {:?} (degrades {}, restores {})",
        s2.pressures, s2.degrade_events, s2.restore_events
    );

    // (d) mixed-tier flood (the per-tier-queue tentpole scenario): a
    // Throughput flood saturates its own small queue while a light
    // Exact stream rides alongside. WDRR must keep Exact p99 within 2×
    // of its unloaded p99; PR 1's single-FIFO service order
    // (ServicePolicy::FifoArrival) is run on the same traffic as the
    // baseline, where the flood drags Exact heads with it.
    // 2 s traces: the CI gate keys on the Exact slice's p99, so keep
    // enough samples (~130 at 8% of 800 rps) that one scheduler stall
    // does not define the quantile
    let light = RequestTrace::new(60.0, 90);
    let unloaded_coord = qos_coordinator(&w, BatcherConfig::uniform(16, 500, 256), None);
    let unloaded_rep =
        run_trace_mix(&unloaded_coord, &light, 2.0, DIN, 1.0, &[(Tier::Exact, 1.0)]);
    let unloaded_p99 = unloaded_rep.latency.p99.max(1e-9);

    let flood_mix = [(Tier::Exact, 0.08), (Tier::Throughput, 0.92)];
    let flood = RequestTrace::new(800.0, 91);
    let flood_cfg =
        BatcherConfig::uniform(16, 500, 256).with_queue_cap(Tier::Throughput, 32);
    let mut t4 = Table::new(
        "perf — Throughput flood (800 rps, thpt queue_cap 32) vs light Exact stream",
        &["policy", "exact p99 (ms)", "vs unloaded", "thpt shed", "thpt p99 (ms)"],
    );
    let mut flood_json: Vec<(&'static str, Json)> = vec![
        ("offered_rps", Json::num(800.0)),
        ("thpt_queue_cap", Json::num(32.0)),
        ("unloaded_exact_p99_ms", Json::num(unloaded_p99 * 1e3)),
    ];
    type FloodKeys = (&'static str, &'static str, &'static str);
    let runs: [(&'static str, ServicePolicy, FloodKeys); 2] = [
        (
            "wdrr",
            ServicePolicy::WeightedFair,
            ("wdrr_exact_p99_ms", "wdrr_exact_p99_ratio", "wdrr_thpt_shed"),
        ),
        (
            "fifo (PR 1)",
            ServicePolicy::FifoArrival,
            ("fifo_exact_p99_ms", "fifo_exact_p99_ratio", "fifo_thpt_shed"),
        ),
    ];
    for (name, policy, (key_p99, key_ratio, key_shed)) in runs {
        let coord = qos_coordinator(&w, flood_cfg.with_policy(policy), None);
        let rep = run_trace_mix(&coord, &flood, 2.0, DIN, 1.0, &flood_mix);
        let exact =
            rep.per_tier.iter().find(|t| t.tier == Tier::Exact).expect("exact slice");
        let thpt = rep
            .per_tier
            .iter()
            .find(|t| t.tier == Tier::Throughput)
            .expect("thpt slice");
        let ratio = exact.latency.p99 / unloaded_p99;
        t4.row_str(&[
            name,
            &format!("{:.2}", exact.latency.p99 * 1e3),
            &format!("{ratio:.2}×"),
            &thpt.shed.to_string(),
            &format!("{:.2}", thpt.latency.p99 * 1e3),
        ]);
        flood_json.push((key_p99, Json::num(exact.latency.p99 * 1e3)));
        flood_json.push((key_ratio, Json::num(ratio)));
        flood_json.push((key_shed, Json::num(thpt.shed as f64)));
    }
    t4.print();

    // (e) flood isolation — the per-tier pressure contract: the same
    // Throughput flood, now WITH a controller attached. Throughput's
    // own pressure must ramp (its cap-32 queue saturates) and fully
    // recover once a light drain empties it, while Balanced's served
    // terms stay bit-for-bit at its calibrated budget and its p99
    // holds. Latency SLOs are disabled here so queue occupancy — the
    // exact channel the old global-scalar loop coupled across tiers —
    // is the only pressure input (the SLO channel is pinned
    // deterministically in integration_qos/controller tests); with the
    // pre-PR-5 hottest-queue loop, the flood's full queue would have
    // degraded every non-Exact tier.
    let iso_cfg = {
        let mut q = QosConfig::new(TERMS);
        q.slo_targets = [0.0; NUM_TIERS];
        q
    };
    let iso_light = RequestTrace::new(60.0, 92);
    let iso_light_cfg = BatcherConfig::uniform(16, 500, 256);
    let unloaded_iso = qos_coordinator(&w, iso_light_cfg, Some(calibrated_with(iso_cfg)));
    let bal_only = [(Tier::Balanced, 1.0)];
    let unl_rep = run_trace_mix(&unloaded_iso, &iso_light, 1.5, DIN, 1.0, &bal_only);
    let unl_bal =
        unl_rep.per_tier.iter().find(|t| t.tier == Tier::Balanced).expect("balanced slice");

    let iso_ctl = calibrated_with(iso_cfg);
    let iso_coord = qos_coordinator(&w, flood_cfg, Some(iso_ctl.clone()));
    let iso_mix = [(Tier::Balanced, 0.08), (Tier::Throughput, 0.92)];
    let iso_trace = RequestTrace::new(800.0, 93);
    let iso_rep = run_trace_mix(&iso_coord, &iso_trace, 2.0, DIN, 1.0, &iso_mix);
    let peak = iso_ctl.snapshot();
    let iso_bal =
        iso_rep.per_tier.iter().find(|t| t.tier == Tier::Balanced).expect("balanced slice");
    // drain: light Throughput-only traffic on the same coordinator
    let iso_drain = RequestTrace::new(40.0, 94);
    let thpt_only = [(Tier::Throughput, 1.0)];
    let _ = run_trace_mix(&iso_coord, &iso_drain, 1.5, DIN, 1.0, &thpt_only);
    let drained = iso_ctl.snapshot();
    let ti = Tier::Throughput.idx();
    let bi = Tier::Balanced.idx();
    let terms_delta = (iso_bal.mean_terms - unl_bal.mean_terms).abs();
    let grid_delta = (iso_bal.mean_grid_terms - unl_bal.mean_grid_terms).abs();
    let bal_ratio = iso_bal.latency.p99 / unl_bal.latency.p99.max(1e-9);
    let mut t5 = Table::new(
        "perf — flood isolation (800 rps Throughput flood vs Balanced bystander)",
        &["metric", "unloaded", "flooded"],
    );
    t5.row_str(&[
        "balanced mean terms",
        &format!("{:.3}", unl_bal.mean_terms),
        &format!("{:.3}", iso_bal.mean_terms),
    ]);
    t5.row_str(&[
        "balanced p99 (ms)",
        &format!("{:.2}", unl_bal.latency.p99 * 1e3),
        &format!("{:.2}", iso_bal.latency.p99 * 1e3),
    ]);
    t5.row_str(&[
        "thpt pressure (peak snap/drained)",
        "-",
        &format!("{}/{}", peak.pressures[ti], drained.pressures[ti]),
    ]);
    t5.print();
    let deg = drained.tier_degrade_events;
    println!(
        "flood isolation: thpt degrades {} restores {} | balanced degrades {}",
        deg[ti], drained.tier_restore_events[ti], deg[bi]
    );
    let isolation_json = Json::obj([
        ("offered_rps", Json::num(800.0)),
        ("thpt_queue_cap", Json::num(32.0)),
        ("unloaded_balanced_mean_terms", Json::num(unl_bal.mean_terms)),
        ("flood_balanced_mean_terms", Json::num(iso_bal.mean_terms)),
        ("balanced_terms_delta", Json::num(terms_delta)),
        ("balanced_grid_delta", Json::num(grid_delta)),
        ("balanced_p99_ratio", Json::num(bal_ratio)),
        ("balanced_degrade_events", Json::num(drained.tier_degrade_events[bi] as f64)),
        ("thpt_degrade_events", Json::num(drained.tier_degrade_events[ti] as f64)),
        ("thpt_drained_pressure", Json::num(drained.pressures[ti] as f64)),
    ]);

    // (f) tracing overhead — the flight-recorder contract: a span on
    // every request must not move the latency needle. The same Exact
    // stream runs with the recorder off and on, interleaved over three
    // rounds so host drift hits both sides evenly; the CI gate keys on
    // the min-over-rounds p99 ratio (min absorbs scheduler noise).
    let trace_load = RequestTrace::new(200.0, 95);
    let exact_only = [(Tier::Exact, 1.0)];
    let mut p99_off = f64::INFINITY;
    let mut p99_on = f64::INFINITY;
    let mut spans_recorded = 0u64;
    for _ in 0..3 {
        let off = qos_coordinator(&w, BatcherConfig::uniform(16, 500, 256), None);
        let off_rep = run_trace_mix(&off, &trace_load, 1.0, DIN, 1.0, &exact_only);
        p99_off = p99_off.min(off_rep.latency.p99);
        let rec = Arc::new(TraceRecorder::default());
        let on = traced_coordinator(&w, BatcherConfig::uniform(16, 500, 256), rec.clone());
        let on_rep = run_trace_mix(&on, &trace_load, 1.0, DIN, 1.0, &exact_only);
        p99_on = p99_on.min(on_rep.latency.p99);
        spans_recorded = rec.recorded();
    }
    let inflation = p99_on / p99_off.max(1e-9);
    let mut t6 = Table::new(
        "perf — flight recorder overhead (200 rps Exact, min p99 over 3 rounds)",
        &["recorder", "exact p99 (ms)"],
    );
    t6.row_str(&["off", &format!("{:.2}", p99_off * 1e3)]);
    t6.row_str(&["on", &format!("{:.2}", p99_on * 1e3)]);
    t6.print();
    println!("tracing: exact p99 inflation {inflation:.3}× ({spans_recorded} spans/round)");

    // (g) connection scale — the reactor serving plane. Two checks:
    // closed-loop Exact p99 through the reactor must stay within 10% of
    // a thread-per-connection baseline (the architecture it replaced),
    // interleaved over three rounds with min-over-rounds on both sides;
    // and an open-loop Poisson load spread over 10.5k nonblocking
    // connections must complete with streamed BestEffort first-frame
    // p99 strictly below the full-reply p99 (progressive refinement
    // pays off at the tail, not just on average).
    let xq = Tensor::randn(&[1, DIN], 1.0, &mut rng);
    let mut base_p99 = f64::INFINITY;
    let mut reactor_p99 = f64::INFINITY;
    for _ in 0..3 {
        let bcoord = qos_coordinator(&w, BatcherConfig::uniform(16, 500, 1024), None);
        let (baddr, bstop, bh) = baseline_thread_per_conn(bcoord);
        base_p99 = base_p99.min(closed_loop_p99(baddr, &xq, 8, 40));
        // ordering: SeqCst — lone stop flag for the accept loop.
        bstop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(baddr); // unblock the accept loop
        let _ = bh.join();

        let rcoord = qos_coordinator(&w, BatcherConfig::uniform(16, 500, 1024), None);
        let rhandle = serve_tcp("127.0.0.1:0", rcoord).expect("reactor server");
        reactor_p99 = reactor_p99.min(closed_loop_p99(rhandle.addr, &xq, 8, 40));
        rhandle.stop();
    }
    let exact_ratio = reactor_p99 / base_p99.max(1e-9);

    let ol_coord = qos_coordinator(&w, BatcherConfig::uniform(16, 500, 4096), None);
    let ol_handle = serve_tcp("127.0.0.1:0", ol_coord).expect("reactor server");
    let ol_cfg = OpenLoopConfig {
        connections: 10_500,
        rate_rps: 2000.0,
        duration_s: 2.0,
        tier: Tier::BestEffort,
        stream: true,
        din: DIN,
        seed: 97,
        drain_s: 20.0,
    };
    let ol = run_open_loop(ol_handle.addr, &ol_cfg).expect("open-loop run");
    ol_handle.stop();
    let ff_ratio = ol.first_frame_latency.p99 / ol.full_latency.p99.max(1e-9);
    let mut t7 = Table::new(
        "perf — connection scale (reactor vs thread-per-conn, 10.5k open-loop conns)",
        &["metric", "value"],
    );
    t7.row_str(&["baseline exact p99 (ms)", &format!("{:.2}", base_p99 * 1e3)]);
    t7.row_str(&["reactor exact p99 (ms)", &format!("{:.2}", reactor_p99 * 1e3)]);
    t7.row_str(&["reactor/baseline p99", &format!("{exact_ratio:.3}×")]);
    t7.row_str(&["open-loop connections", &ol.connections.to_string()]);
    t7.row_str(&["open-loop completed", &format!("{}/{}", ol.completed, ol.offered)]);
    t7.row_str(&["BE first-frame p99 (ms)", &format!("{:.2}", ol.first_frame_latency.p99 * 1e3)]);
    t7.row_str(&["BE full-reply p99 (ms)", &format!("{:.2}", ol.full_latency.p99 * 1e3)]);
    t7.row_str(&["first/full p99", &format!("{ff_ratio:.3}×")]);
    t7.print();
    println!("connscale open loop: {ol}");
    let connscale_json = Json::obj([
        ("closed_loop_clients", Json::num(8.0)),
        ("baseline_exact_p99_ms", Json::num(base_p99 * 1e3)),
        ("reactor_exact_p99_ms", Json::num(reactor_p99 * 1e3)),
        ("exact_p99_ratio", Json::num(exact_ratio)),
        ("open_loop_conns", Json::num(ol.connections as f64)),
        ("open_loop_offered", Json::num(ol.offered as f64)),
        ("open_loop_completed", Json::num(ol.completed as f64)),
        ("open_loop_timed_out", Json::num(ol.timed_out as f64)),
        ("be_first_frame_p99_ms", Json::num(ol.first_frame_latency.p99 * 1e3)),
        ("be_full_p99_ms", Json::num(ol.full_latency.p99 * 1e3)),
        ("be_first_frame_p99_ratio", Json::num(ff_ratio)),
    ]);

    let json = Json::obj([
        ("bench", Json::str("qos")),
        ("mixed_tier", Json::Arr(mixed_json)),
        ("flood", Json::obj(flood_json)),
        ("isolation", isolation_json),
        ("connscale", connscale_json),
        (
            "spike",
            Json::obj([
                ("offered_rps", Json::num(700.0)),
                ("queue_cap", Json::num(32.0)),
                ("seed_shed", Json::num(seed_rep.shed as f64)),
                ("seed_completed", Json::num(seed_rep.completed as f64)),
                ("qos_shed", Json::num(qos_rep.shed as f64)),
                ("qos_completed", Json::num(qos_rep.completed as f64)),
                ("qos_p99_ms", Json::num(qos_rep.latency.p99 * 1e3)),
                ("seed_p99_ms", Json::num(seed_rep.latency.p99 * 1e3)),
            ]),
        ),
        (
            "tracing",
            Json::obj([
                ("offered_rps", Json::num(200.0)),
                ("rounds", Json::num(3.0)),
                ("off_exact_p99_ms", Json::num(p99_off * 1e3)),
                ("on_exact_p99_ms", Json::num(p99_on * 1e3)),
                ("exact_p99_inflation", Json::num(inflation)),
                ("spans_recorded", Json::num(spans_recorded as f64)),
            ]),
        ),
    ]);
    match write_bench_json("qos", &json) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nBENCH json write failed: {e}"),
    }
    println!(
        "\ntarget: truncated reduction cost falls with the term budget;\n\
         under the spike the controller completes more requests (fewer\n\
         sheds) than the seed config by degrading precision, not availability;\n\
         under the Throughput flood the WDRR per-tier queues keep Exact p99\n\
         within 2× of unloaded while the flood sheds against its own cap\n\
         (the fifo row shows PR 1's head-of-line behavior for contrast);\n\
         and with the per-tier controller attached, the flood degrades ONLY\n\
         Throughput — Balanced's served terms are bit-identical to the\n\
         unloaded run and Throughput's pressure drains back to zero;\n\
         the flight recorder, armed on every request, keeps Exact\n\
         p99 within 10% of the untraced run; and the epoll reactor holds\n\
         closed-loop Exact p99 within 10% of thread-per-conn while serving\n\
         an open-loop load across 10.5k connections with streamed first\n\
         frames landing ahead of the full reply at the tail."
    );
}
