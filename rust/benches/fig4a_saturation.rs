//! Figure 4a — saturation ablation: Laplace-clipped vs non-saturating
//! basis quantizers across four models.
//!
//! Substitution note: at W4A4 the synthetic substrate saturates (both
//! variants reach FP), so the W2A2 panel on the harder dataset carries
//! the discriminative comparison — same ablation as the paper's.
//!
//!     cargo bench --bench fig4a_saturation

use fp_xint::bench_support as bs;
use fp_xint::datasets::accuracy;
use fp_xint::models::quantized;
use fp_xint::util::{logger, Table};
use fp_xint::xint::layer::LayerPolicy;
use fp_xint::xint::quantizer::Clip;

fn main() {
    logger::init(false);
    let suite = bs::suite();
    let picks = [suite[0], suite[2], suite[4], suite[5]];
    let data = bs::bench_data_hard();
    let val = data.batch(512, 2);

    for (w, a) in [(4u32, 4u32), (2, 2)] {
        let mut t = Table::new(
            &format!("Figure 4a — saturation ablation (W{w}A{a}, hard dataset)"),
            &["Model", "no clip (non-sat)", "Laplace clip (sat)", "Full Prec."],
        );
        for (paper, tag, build) in picks {
            let (m, fp) = bs::trained_hard(tag, build);
            let acc_of = |clip: Clip| {
                let q = quantized::quantize_model(
                    &m,
                    LayerPolicy::new(w, a).with_clip(clip).with_terms(2, 2),
                );
                accuracy(&q.forward(&val.x), &val.y) * 100.0
            };
            t.row_str(&[
                paper,
                &bs::pct(acc_of(Clip::None)),
                &bs::pct(acc_of(Clip::Laplace)),
                &bs::pct(fp),
            ]);
        }
        t.print();
        println!();
    }
    println!("expected shape (paper): Laplace clip ≥ no-clip; both near FP at W4A4.");
    bs::shape_note();
}
