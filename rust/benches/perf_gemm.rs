//! Perf — the expanded GEMM hot path (§5.2 speed discussion + §Perf).
//!
//! Measures: FP32 GEMM vs the integer expanded GEMM (i32 accumulation)
//! at matched arithmetic, the k·t cost scaling of Eq. 3, the packed
//! SIMD / row-parallel grid kernel vs the scalar grid (the tentpole —
//! emits `BENCH_gemm.json` with the CI-gated speedups), the rank-1
//! M_nsy fast path vs dense, and (when artifacts exist) the
//! PJRT-compiled Pallas xint_gemm kernel.
//!
//!     cargo bench --bench perf_gemm

use std::sync::Arc;

use fp_xint::bench_support::write_bench_json;
use fp_xint::tensor::{matmul_a_bt, IntTensor, Rng, Tensor};
use fp_xint::util::json::Json;
use fp_xint::util::{logger, BenchTimer, Table};
use fp_xint::xint::gemm::{int_gemm_a_bt, int_gemm_scaled_into, xint_linear_forward, ExpandedWeight};
use fp_xint::xint::kernel::{self, GridRun, KernelPool, PackedPlane};
use fp_xint::xint::{BitSpec, ExpandConfig};

fn gflops(m: usize, n: usize, k: usize, secs: f64) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64 / secs / 1e9
}

fn main() {
    logger::init(false);
    let timer = BenchTimer::new(3, 10);
    let mut rng = Rng::seed(404);

    // --- FP32 vs INT GEMM at matched shape
    let mut t = Table::new(
        "perf — GEMM kernels (single thread)",
        &["shape (m×n×k)", "kernel", "time (ms)", "GFLOP/s", "vs FP32"],
    );
    for &(m, n, k) in &[(64usize, 64usize, 256usize), (128, 128, 512), (256, 256, 1024)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[n, k], 1.0, &mut rng);
        let fp = timer.run(|| matmul_a_bt(&a, &b));
        let ai = IntTensor::from_vec(&[m, k], (0..m * k).map(|_| rng.below(15) as i32 - 7).collect());
        let bi = IntTensor::from_vec(&[n, k], (0..n * k).map(|_| rng.below(15) as i32 - 7).collect());
        let int = timer.run(|| int_gemm_a_bt(&ai, &bi));
        let shape = format!("{m}×{n}×{k}");
        t.row_str(&[
            &shape,
            "fp32",
            &format!("{:.3}", fp.mean * 1e3),
            &format!("{:.2}", gflops(m, n, k, fp.mean)),
            "1.00×",
        ]);
        t.row_str(&[
            &shape,
            "int32-acc",
            &format!("{:.3}", int.mean * 1e3),
            &format!("{:.2}", gflops(m, n, k, int.mean)),
            &format!("{:.2}×", fp.mean / int.mean),
        ]);
    }
    t.print();

    // --- Eq. 3 cost scaling: expanded forward vs k·t
    let mut t2 = Table::new(
        "perf — expanded linear forward (Eq. 3), 64×256 → 64",
        &["(k, t)", "time (ms)", "per-term (ms)", "vs FP32 linear"],
    );
    let x = Tensor::randn(&[64, 256], 1.0, &mut rng);
    let w_raw = Tensor::randn(&[64, 256], 0.3, &mut rng);
    let fp = timer.run(|| matmul_a_bt(&x, &w_raw));
    for &(k, tt) in &[(1usize, 1usize), (2, 2), (2, 4), (3, 4)] {
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::weights(BitSpec::int(4), k));
        let acfg = ExpandConfig::activations(BitSpec::int(4), tt);
        let s = timer.run(|| xint_linear_forward(&x, &w, &acfg));
        t2.row_str(&[
            &format!("({k}, {tt})"),
            &format!("{:.3}", s.mean * 1e3),
            &format!("{:.3}", s.mean * 1e3 / (k * tt) as f64),
            &format!("{:.2}×", s.mean / fp.mean),
        ]);
    }
    t2.print();

    // --- packed SIMD + row-parallel grid kernel vs the scalar grid
    // (tentpole). k=2 weight × t=3 activation planes — the serving-shaped
    // grid. Weights pack outside the timed region (load-time in serving);
    // activations pack inside it (once per layer call, amortized over all
    // six grid cells), so "packed" charges the real request-path cost.
    let kern = kernel::active_kernel();
    let lanes = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let pool = KernelPool::new(lanes.saturating_sub(1));
    let pairs: Vec<(usize, usize)> =
        (0..2usize).flat_map(|i| (0..3usize).map(move |j| (i, j))).collect();
    let mut t4 = Table::new(
        &format!("perf — Eq. 3 grid kernel (k=2, t=3, int4 planes, {} lanes)", lanes),
        &["shape (m×n×k)", "scalar (ms)", "packed (ms)", "parallel (ms)", "packed", "parallel"],
    );
    let mut bit_identical = true;
    let mut shapes_json: Vec<Json> = Vec::new();
    let mut largest = Json::Null;
    for &(m, n, k) in &[(64usize, 64usize, 256usize), (128, 128, 512), (256, 256, 1024)] {
        let w_int: Vec<IntTensor> = (0..2)
            .map(|_| {
                IntTensor::from_vec(&[n, k], (0..n * k).map(|_| rng.below(15) as i32 - 7).collect())
            })
            .collect();
        let a_int: Vec<IntTensor> = (0..3)
            .map(|_| {
                IntTensor::from_vec(&[m, k], (0..m * k).map(|_| rng.below(15) as i32 - 7).collect())
            })
            .collect();
        let w_scales: Vec<Vec<f32>> =
            (0..2).map(|_| (0..n).map(|_| rng.uniform(0.01, 1.0)).collect()).collect();
        let a_scales: Vec<f32> = (0..3).map(|_| rng.uniform(0.01, 1.0)).collect();
        // baseline: the pre-packing kernel — six scalar int GEMMs
        let scalar = timer.run(|| {
            let mut y = vec![0.0f32; m * n];
            for &(i, j) in &pairs {
                int_gemm_scaled_into(&a_int[j], &w_int[i], &w_scales[i], a_scales[j], &mut y);
            }
            y
        });
        let wp: Vec<Arc<PackedPlane>> =
            w_int.iter().map(|p| Arc::new(PackedPlane::pack(p).unwrap())).collect();
        let ws: Vec<Arc<Vec<f32>>> = w_scales.iter().map(|s| Arc::new(s.clone())).collect();
        let mk_run = || {
            let ap: Vec<Arc<PackedPlane>> =
                a_int.iter().map(|p| Arc::new(PackedPlane::pack(p).unwrap())).collect();
            GridRun::new(wp.clone(), ws.clone(), ap, a_scales.clone(), pairs.clone())
        };
        let packed = timer.run(|| {
            let run = mk_run();
            let mut y = vec![0.0f32; m * n];
            kernel::execute(&run, kern, &mut y);
            y
        });
        let parallel = timer.run(|| {
            let run = Arc::new(mk_run());
            let mut y = vec![0.0f32; m * n];
            kernel::execute_parallel_with(&pool, &run, kern, &mut y);
            y
        });
        // pin all three routes bit-identical before trusting the timings
        let mut y_ref = vec![0.0f32; m * n];
        for &(i, j) in &pairs {
            int_gemm_scaled_into(&a_int[j], &w_int[i], &w_scales[i], a_scales[j], &mut y_ref);
        }
        let run = Arc::new(mk_run());
        let mut y_packed = vec![0.0f32; m * n];
        kernel::execute(&run, kern, &mut y_packed);
        let mut y_par = vec![0.0f32; m * n];
        kernel::execute_parallel_with(&pool, &run, kern, &mut y_par);
        if y_packed != y_ref || y_par != y_ref {
            bit_identical = false;
            log::error!("kernel output diverged from scalar at {m}x{n}x{k}");
        }
        let shape = format!("{m}×{n}×{k}");
        let (s_ms, p_ms, r_ms) = (scalar.min * 1e3, packed.min * 1e3, parallel.min * 1e3);
        t4.row_str(&[
            &shape,
            &format!("{s_ms:.3}"),
            &format!("{p_ms:.3}"),
            &format!("{r_ms:.3}"),
            &format!("{:.2}×", s_ms / p_ms),
            &format!("{:.2}×", s_ms / r_ms),
        ]);
        let entry = Json::obj([
            ("shape", Json::str(&shape)),
            ("scalar_ms", Json::num(s_ms)),
            ("packed_ms", Json::num(p_ms)),
            ("parallel_ms", Json::num(r_ms)),
            ("packed_speedup", Json::num(s_ms / p_ms)),
            ("parallel_speedup", Json::num(s_ms / r_ms)),
        ]);
        largest = entry.clone();
        shapes_json.push(entry);
    }
    t4.print();
    let json = Json::obj([
        ("bench", Json::str("gemm_kernels")),
        ("kernel", Json::str(kern.name())),
        ("lanes", Json::num(lanes as f64)),
        ("bit_identical", Json::num(if bit_identical { 1.0 } else { 0.0 })),
        ("largest", largest),
        ("shapes", Json::Arr(shapes_json)),
    ]);
    match write_bench_json("gemm", &json) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => log::error!("BENCH_gemm.json write failed: {e}"),
    }
    pool.shutdown();

    // --- rank-1 M_nsy path vs dense multiplication (the §4 O(n²) claim)
    let mut t3 = Table::new(
        "perf — M_nsy rank-1 trick (row sums) vs dense ones-matmul",
        &["n", "dense (ms)", "rank-1 (ms)", "speedup"],
    );
    for &n in &[128usize, 256, 512] {
        let m = Tensor::randn(&[n, n], 1.0, &mut rng);
        let ones = Tensor::full(&[n, n], 1.0);
        let dense = timer.run(|| matmul_a_bt(&m, &ones));
        let rank1 = timer.run(|| {
            // (M·1ᵀ)·1: row sums broadcast — O(n²)
            let mut sums = vec![0.0f32; n];
            for i in 0..n {
                sums[i] = m.row(i).iter().sum();
            }
            sums
        });
        t3.row_str(&[
            &n.to_string(),
            &format!("{:.3}", dense.mean * 1e3),
            &format!("{:.4}", rank1.mean * 1e3),
            &format!("{:.0}×", dense.mean / rank1.mean),
        ]);
    }
    t3.print();

    // --- PJRT Pallas kernel (artifact) timing, if built
    let dir = fp_xint::runtime::Runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = fp_xint::runtime::Runtime::cpu(&dir).expect("runtime");
        if let Ok(exec) = rt.load_key("xint_gemm") {
            // shapes fixed at lowering: k=2, t=3, (64,256)x(64,256)
            let wp = Tensor::randn(&[2, 64, 256], 1.0, &mut rng).map(|v| v.round());
            let ws = Tensor::vec1(&[0.1, 0.00625]);
            let ap = Tensor::randn(&[3, 64, 256], 1.0, &mut rng).map(|v| v.round());
            let as_ = Tensor::vec1(&[0.2, 0.0125, 0.00078125]);
            let s = timer.run(|| exec.run1(&[wp.clone(), ws.clone(), ap.clone(), as_.clone()]).unwrap());
            println!(
                "PJRT pallas xint_gemm (k=2,t=3, 64×64×256): {:.3} ms/call ({:.2} GFLOP/s eff)",
                s.mean * 1e3,
                gflops(64, 64, 256, s.mean) * 6.0
            );
        }
    } else {
        println!("(run `make artifacts` to include the PJRT pallas kernel timing)");
    }
    println!(
        "\ntarget (§Perf): int32-acc ≥ FP32 at matched shape (stand-in for the\n\
         paper's 4× INT8 claim); expanded (k,t) cost ≈ k·t × single-term cost."
    );
}
