//! Perf — the expanded GEMM hot path (§5.2 speed discussion + §Perf).
//!
//! Measures: FP32 GEMM vs the integer expanded GEMM (i32 accumulation)
//! at matched arithmetic, the k·t cost scaling of Eq. 3, the rank-1
//! M_nsy fast path vs dense, and (when artifacts exist) the PJRT-compiled
//! Pallas xint_gemm kernel.
//!
//!     cargo bench --bench perf_gemm

use fp_xint::tensor::{matmul_a_bt, IntTensor, Rng, Tensor};
use fp_xint::util::{logger, BenchTimer, Table};
use fp_xint::xint::gemm::{int_gemm_a_bt, xint_linear_forward, ExpandedWeight};
use fp_xint::xint::{BitSpec, ExpandConfig};

fn gflops(m: usize, n: usize, k: usize, secs: f64) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64 / secs / 1e9
}

fn main() {
    logger::init(false);
    let timer = BenchTimer::new(3, 10);
    let mut rng = Rng::seed(404);

    // --- FP32 vs INT GEMM at matched shape
    let mut t = Table::new(
        "perf — GEMM kernels (single thread)",
        &["shape (m×n×k)", "kernel", "time (ms)", "GFLOP/s", "vs FP32"],
    );
    for &(m, n, k) in &[(64usize, 64usize, 256usize), (128, 128, 512), (256, 256, 1024)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[n, k], 1.0, &mut rng);
        let fp = timer.run(|| matmul_a_bt(&a, &b));
        let ai = IntTensor::from_vec(&[m, k], (0..m * k).map(|_| rng.below(15) as i32 - 7).collect());
        let bi = IntTensor::from_vec(&[n, k], (0..n * k).map(|_| rng.below(15) as i32 - 7).collect());
        let int = timer.run(|| int_gemm_a_bt(&ai, &bi));
        let shape = format!("{m}×{n}×{k}");
        t.row_str(&[
            &shape,
            "fp32",
            &format!("{:.3}", fp.mean * 1e3),
            &format!("{:.2}", gflops(m, n, k, fp.mean)),
            "1.00×",
        ]);
        t.row_str(&[
            &shape,
            "int32-acc",
            &format!("{:.3}", int.mean * 1e3),
            &format!("{:.2}", gflops(m, n, k, int.mean)),
            &format!("{:.2}×", fp.mean / int.mean),
        ]);
    }
    t.print();

    // --- Eq. 3 cost scaling: expanded forward vs k·t
    let mut t2 = Table::new(
        "perf — expanded linear forward (Eq. 3), 64×256 → 64",
        &["(k, t)", "time (ms)", "per-term (ms)", "vs FP32 linear"],
    );
    let x = Tensor::randn(&[64, 256], 1.0, &mut rng);
    let w_raw = Tensor::randn(&[64, 256], 0.3, &mut rng);
    let fp = timer.run(|| matmul_a_bt(&x, &w_raw));
    for &(k, tt) in &[(1usize, 1usize), (2, 2), (2, 4), (3, 4)] {
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::weights(BitSpec::int(4), k));
        let acfg = ExpandConfig::activations(BitSpec::int(4), tt);
        let s = timer.run(|| xint_linear_forward(&x, &w, &acfg));
        t2.row_str(&[
            &format!("({k}, {tt})"),
            &format!("{:.3}", s.mean * 1e3),
            &format!("{:.3}", s.mean * 1e3 / (k * tt) as f64),
            &format!("{:.2}×", s.mean / fp.mean),
        ]);
    }
    t2.print();

    // --- rank-1 M_nsy path vs dense multiplication (the §4 O(n²) claim)
    let mut t3 = Table::new(
        "perf — M_nsy rank-1 trick (row sums) vs dense ones-matmul",
        &["n", "dense (ms)", "rank-1 (ms)", "speedup"],
    );
    for &n in &[128usize, 256, 512] {
        let m = Tensor::randn(&[n, n], 1.0, &mut rng);
        let ones = Tensor::full(&[n, n], 1.0);
        let dense = timer.run(|| matmul_a_bt(&m, &ones));
        let rank1 = timer.run(|| {
            // (M·1ᵀ)·1: row sums broadcast — O(n²)
            let mut sums = vec![0.0f32; n];
            for i in 0..n {
                sums[i] = m.row(i).iter().sum();
            }
            sums
        });
        t3.row_str(&[
            &n.to_string(),
            &format!("{:.3}", dense.mean * 1e3),
            &format!("{:.4}", rank1.mean * 1e3),
            &format!("{:.0}×", dense.mean / rank1.mean),
        ]);
    }
    t3.print();

    // --- PJRT Pallas kernel (artifact) timing, if built
    let dir = fp_xint::runtime::Runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = fp_xint::runtime::Runtime::cpu(&dir).expect("runtime");
        if let Ok(exec) = rt.load_key("xint_gemm") {
            // shapes fixed at lowering: k=2, t=3, (64,256)x(64,256)
            let wp = Tensor::randn(&[2, 64, 256], 1.0, &mut rng).map(|v| v.round());
            let ws = Tensor::vec1(&[0.1, 0.00625]);
            let ap = Tensor::randn(&[3, 64, 256], 1.0, &mut rng).map(|v| v.round());
            let as_ = Tensor::vec1(&[0.2, 0.0125, 0.00078125]);
            let s = timer.run(|| exec.run1(&[wp.clone(), ws.clone(), ap.clone(), as_.clone()]).unwrap());
            println!(
                "PJRT pallas xint_gemm (k=2,t=3, 64×64×256): {:.3} ms/call ({:.2} GFLOP/s eff)",
                s.mean * 1e3,
                gflops(64, 64, 256, s.mean) * 6.0
            );
        }
    } else {
        println!("(run `make artifacts` to include the PJRT pallas kernel timing)");
    }
    println!(
        "\ntarget (§Perf): int32-acc ≥ FP32 at matched shape (stand-in for the\n\
         paper's 4× INT8 claim); expanded (k,t) cost ≈ k·t × single-term cost."
    );
}
