//! Table 3 — method cost comparison: accuracy / model size / training
//! data / runtime / calibration for the ResNet-18 and MobileNetV2
//! stand-ins, including the 2/Mix(2/4/8) mixed-precision row.
//!
//!     cargo bench --bench table3_method_cost

use fp_xint::baselines::PtqMethod;
use fp_xint::bench_support as bs;
use fp_xint::models::{quantized, Model};
use fp_xint::util::{logger, timer::time_once, Table};
use fp_xint::xint::layer::LayerPolicy;
use fp_xint::xint::mixed::{LayerInfo, MixedPlanner, MIX_BITS};
use fp_xint::xint::model_size_bytes;

fn size_str(bytes: usize) -> String {
    format!("{:.2}M", bytes as f64 / 1e6)
}

fn mixed_row(model: &Model, fp_name: &str) -> (f64, usize, f64) {
    // per-layer sensitivity: whole-model output error when ALL layers run
    // at each activation width (coarse but monotone proxy shared by all
    // layers; the planner needs only relative order)
    let data = bs::bench_data();
    let calib = data.batch(32, 3).x;
    let mut folded = model.clone();
    folded.fold_bn();
    let y_fp = folded.forward(&calib);
    let t0 = std::time::Instant::now();
    let global_err: Vec<f64> = MIX_BITS
        .iter()
        .map(|&b| {
            let q = quantized::quantize_model(model, LayerPolicy::new(2, b).with_terms(1, 1));
            (y_fp.sub(&q.forward(&calib)).norm() / y_fp.norm()) as f64
        })
        .collect();
    // params per layer via a visit
    let mut params = Vec::new();
    let mut m2 = model.clone();
    m2.fold_bn();
    collect_layer_params(&m2.layers, &mut params);
    let infos: Vec<LayerInfo> = params
        .iter()
        .enumerate()
        .map(|(i, &p)| LayerInfo {
            name: format!("{fp_name}-l{i}"),
            params: p,
            sensitivity: global_err.clone(),
        })
        .collect();
    let total_params: usize = params.iter().sum();
    let budget = model_size_bytes(total_params, 2) + model_size_bytes(total_params, 4) / 2;
    let plan = MixedPlanner { w_bits: 2, budget_bytes: budget }.plan(&infos);
    // evaluate at per-model granularity: use the median activation width
    let mut widths: Vec<u32> = plan.layers.iter().map(|l| l.2).collect();
    widths.sort();
    let a_bits = widths[widths.len() / 2];
    let acc = bs::ours_acc_terms(model, 2, a_bits, 2, 4);
    let size = plan.size_bytes(&params);
    let dt = t0.elapsed().as_secs_f64();
    (acc, size, dt)
}

fn collect_layer_params(layers: &[fp_xint::models::Layer], out: &mut Vec<usize>) {
    use fp_xint::models::Layer;
    for l in layers {
        match l {
            Layer::Conv(_) | Layer::Linear(_) => out.push(l.params()),
            Layer::Residual(m, s) => {
                collect_layer_params(m, out);
                collect_layer_params(s, out);
            }
            Layer::Branches(bs_) => {
                for b in bs_ {
                    collect_layer_params(b, out);
                }
            }
            _ => {}
        }
    }
}

fn main() {
    logger::init(false);
    let mut blocks: Vec<(&str, &str, fn() -> Model)> = vec![bs::suite()[0]];
    let mn = bs::mobilenet();
    blocks.push(mn);

    for (paper_name, tag, build) in blocks {
        let (model, fp_acc) = bs::trained(tag, build);
        let params = model.params();
        let mut t = Table::new(
            &format!("Table 3 — {paper_name} (FP {:.2}%)", fp_acc),
            &["Method", "Bits (W/A)", "Accuracy", "Model Size", "Train Data", "Runtime", "Calib/FT"],
        );
        // representative baselines with their cost profile
        let reps: Vec<(Box<dyn PtqMethod>, &str, &str)> = vec![
            (Box::new(fp_xint::baselines::Rtn), "0", "0 (data-free)"),
            (Box::new(fp_xint::baselines::AdaQuant::default()), "0", "32 samples"),
            (Box::new(fp_xint::baselines::Lapq::default()), "0", "32 samples"),
        ];
        for (method, train_data, calib) in reps {
            let (acc, dt) = time_once(|| bs::baseline_acc(&model, method.as_ref(), 4, 4));
            t.row_str(&[
                method.name(),
                "4/4",
                &bs::pct(acc),
                &size_str(model_size_bytes(params, 4)),
                train_data,
                &format!("{dt:.2}s"),
                calib,
            ]);
        }
        // ours 4/4
        let (acc, dt) = time_once(|| bs::ours_acc(&model, 4, 4));
        t.row_str(&[
            "Ours",
            "4/4",
            &bs::pct(acc),
            &size_str(model_size_bytes(params, 4)),
            "0",
            &format!("{dt:.2}s"),
            "0, w/o FT",
        ]);
        // ours mixed 2/Mix(2/4/8)
        let (acc, size, dt) = mixed_row(&model, paper_name);
        t.row_str(&[
            "Ours",
            "2/Mix(2/4/8)",
            &bs::pct(acc),
            &size_str(size),
            "0",
            &format!("{dt:.2}s"),
            "0, w/o FT",
        ]);
        t.print();
        println!();
    }
    bs::shape_note();
}
