//! Table 2 — ResNet-18 stand-in across W3A3 / W2A4 / W4A2 / W8A8 /
//! W32A32 plus per-setting quantization wall time, vs baselines.
//!
//!     cargo bench --bench table2_bit_settings

use fp_xint::bench_support as bs;
use fp_xint::models::quantized;
use fp_xint::util::{logger, timer::time_once, Table};
use fp_xint::xint::layer::LayerPolicy;

fn main() {
    logger::init(false);
    let (model, fp_acc) = {
        let s = bs::suite();
        let (_, tag, build) = s[0];
        bs::trained(tag, build)
    };
    let settings: [(&str, Option<(u32, u32)>); 5] = [
        ("W3A3", Some((3, 3))),
        ("W2A4", Some((2, 4))),
        ("W4A2", Some((4, 2))),
        ("W8A8", Some((8, 8))),
        ("W32A32", None),
    ];

    let mut t = Table::new(
        "Table 2 — MiniResNet-A (ResNet-18 stand-in) across bit settings",
        &["Method", "W3A3", "W2A4", "W4A2", "W8A8", "W32A32"],
    );
    // baselines (AdaQuant as the paper's representative row)
    for method in [&fp_xint::baselines::AdaQuant::default() as &dyn fp_xint::baselines::PtqMethod]
    {
        let mut row = vec![method.name().to_string()];
        for (_, bits) in &settings {
            match bits {
                Some((w, a)) => row.push(bs::pct(bs::baseline_acc(&model, method, *w, *a))),
                None => row.push(bs::pct(fp_acc)),
            }
        }
        t.row(&row);
    }
    let mut row = vec!["Ours (series)".to_string()];
    for (_, bits) in &settings {
        match bits {
            Some((w, a)) => row.push(bs::pct(bs::ours_acc(&model, *w, *a))),
            None => row.push(bs::pct(fp_acc)),
        }
    }
    t.row(&row);
    // quantization wall time per setting (the paper's Quant-Time row)
    let mut row = vec!["Quant-Time".to_string()];
    for (_, bits) in &settings {
        match bits {
            Some((w, a)) => {
                let policy = LayerPolicy::new(*w, *a);
                let (_, dt) = time_once(|| quantized::quantize_model(&model, policy));
                row.push(format!("{dt:.3}s"));
            }
            None => row.push("-".to_string()),
        }
    }
    t.row(&row);
    t.print();
    bs::shape_note();
}
