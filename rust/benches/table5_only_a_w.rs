//! Table 5 — ablation: expand only activations (onlyA) vs only weights
//! (onlyW) vs both, ResNet-18/50 stand-ins.
//!
//! Substitution note: the paper runs this at INT4 on ImageNet; the
//! synthetic substrate saturates at INT4 (FP ≈ 99–100%), so the
//! discriminative panel here is INT3/INT2 on the harder dataset — same
//! ablation, same expected ordering (onlyA > onlyW; both best).
//!
//!     cargo bench --bench table5_only_a_w

use fp_xint::bench_support as bs;
use fp_xint::util::{logger, Table};

fn main() {
    logger::init(false);
    let suite = bs::suite();
    let picks = [suite[0], suite[2]]; // ResNet-18, ResNet-50 stand-ins
    let data = bs::bench_data_hard();

    for bits in [4u32, 3, 2] {
        let mut t = Table::new(
            &format!("Table 5 — INT{bits} expansion ablation (hard dataset)"),
            &["Model", "onlyA (k=1,t=4)", "onlyW (k=2,t=1)", "Ours (k=2,t=4)", "Full Prec."],
        );
        for (paper, tag, build) in picks {
            let (m, fp) = bs::trained_hard(tag, build);
            t.row_str(&[
                paper,
                &bs::pct(bs::ours_acc_on(&data, &m, bits, bits, 1, 4)),
                &bs::pct(bs::ours_acc_on(&data, &m, bits, bits, 2, 1)),
                &bs::pct(bs::ours_acc_on(&data, &m, bits, bits, 2, 4)),
                &bs::pct(fp),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "expected shape (paper, INT4): onlyA > onlyW; both together best —\n\
         activation expansion matters more than weight expansion."
    );
    bs::shape_note();
}
