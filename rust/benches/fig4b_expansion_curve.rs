//! Figure 4b — accuracy and max activation difference vs the number of
//! expansion terms (ResNet-50 stand-in on the hard dataset), plus the
//! §5.4 ensemble control.
//!
//!     cargo bench --bench fig4b_expansion_curve

use fp_xint::baselines::IntEnsemble;
use fp_xint::bench_support as bs;
use fp_xint::util::{logger, Table};
use fp_xint::xint::{BitSpec, ExpandConfig, ExpansionMonitor};

fn main() {
    logger::init(false);
    let suite = bs::suite();
    let (paper, tag, build) = suite[2]; // ResNet-50 stand-in
    let (model, fp) = bs::trained_hard(tag, build);
    let data = bs::bench_data_hard();

    // blue line: max |x − recon_t(x)| on real activations (the input batch)
    let mut monitor = ExpansionMonitor::new();
    let probe = data.batch(32, 3).x;
    monitor
        .observe(&probe, &ExpandConfig::activations(BitSpec::int(2), 8))
        .expect("one config per monitor series");

    // INT2 activations make the term count bite (INT4 saturates at t=2
    // on this substrate; the paper's INT4/ImageNet curve peaks at t=4)
    let mut t = Table::new(
        &format!("Figure 4b — {paper} (FP {:.2}%), W2A2 expansion count", fp),
        &["expansions", "top-1 %", "max act diff (INT2 terms)"],
    );
    for terms in 1..=6 {
        let acc = bs::ours_acc_on(&data, &model, 2, 2, 2.min(terms), terms);
        t.row_str(&[
            &terms.to_string(),
            &bs::pct(acc),
            &format!("{:.2e}", monitor.max_diff()[terms - 1]),
        ]);
    }
    t.print();
    match monitor.optimal_terms(1e-4) {
        Some(n) => println!(
            "auto-stop rule (diff < 1e-4): optimal expansions = {n} at INT2 \
             (each INT2 term buys 4×; the paper's INT4 terms buy 16× and stop at 4)"
        ),
        None => println!("auto-stop rule not reached in 8 INT2 terms"),
    }

    // §5.4 control: ensemble of INT models does not converge
    let calib = data.batch(64, 4).x;
    let mut t2 = Table::new(
        "§5.4 — ensemble vs series (relative output error vs FP, INT3 weights)",
        &["members/terms", "ensemble err", "series err"],
    );
    for k in [1usize, 2, 4, 6] {
        let (ens, ser) = IntEnsemble::new(k.max(1), 7).versus_series(&model, 3, &calib);
        t2.row_str(&[&k.to_string(), &format!("{ens:.4}"), &format!("{ser:.4}")]);
    }
    t2.print();
    bs::shape_note();
}
