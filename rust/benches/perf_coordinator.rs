//! Perf — coordinator throughput/latency (§5.2 "parallelism hides the
//! expansion cost" + §Perf L3 targets).
//!
//! Sweeps: (a) worker parallelism for t basis models — parallel AllReduce
//! vs serial execution; (b) batching policy vs offered load.
//!
//!     cargo bench --bench perf_coordinator

use fp_xint::bench_support::write_bench_json;
use fp_xint::coordinator::{
    BasisWorker, BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool,
};
use fp_xint::datasets::RequestTrace;
use fp_xint::serve::loadgen::{run_trace, LoadReport};
use fp_xint::util::json::Json;
use fp_xint::serve::workers::{mlp_basis_factory, MlpWeights};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::util::{logger, BenchTimer, Table};
use std::sync::Arc;

fn weights(seed: u64) -> MlpWeights {
    let mut rng = Rng::seed(seed);
    MlpWeights {
        w1: Tensor::randn(&[64, 256], 0.3, &mut rng),
        b1: Tensor::randn(&[64], 0.1, &mut rng),
        w2: Tensor::randn(&[10, 64], 0.3, &mut rng),
        b2: Tensor::randn(&[10], 0.1, &mut rng),
    }
}

fn load_row(rate: f64, max_batch: usize, rep: &LoadReport) -> Json {
    Json::obj([
        ("offered_rps", Json::num(rate)),
        ("max_batch", Json::num(max_batch as f64)),
        ("throughput_rps", Json::num(rep.throughput_rps)),
        ("p50_ms", Json::num(rep.latency.p50 * 1e3)),
        ("p99_ms", Json::num(rep.latency.p99 * 1e3)),
        ("shed", Json::num(rep.shed as f64)),
        ("offered", Json::num(rep.offered as f64)),
    ])
}

fn main() {
    logger::init(false);
    let timer = BenchTimer::new(3, 20);
    let w = weights(31);
    let mut rng = Rng::seed(7);
    let x = Tensor::randn(&[32, 256], 1.0, &mut rng);

    // (a) parallel AllReduce vs serial basis execution.
    // On a multi-core host the CPU-bound panel shows near-t× speedup; on
    // this box (see printed host parallelism) compute cannot overlap, so the second
    // panel models each basis model as a fixed-service-time device (the
    // paper's deployment: one INT model per accelerator) — sleeps overlap
    // regardless of cores, isolating the coordinator's scheduling overlap.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host parallelism: {cores} core(s)\n");
    let mut t = Table::new(
        "perf — t basis models, CPU-bound slices: parallel vs serial",
        &["t", "serial (ms)", "parallel (ms)", "speedup", "ideal (cores-bound)"],
    );
    for &terms in &[2usize, 4, 8] {
        let factory = mlp_basis_factory(&w, 4, terms);
        // serial: run each slice in sequence on this thread
        let mut slices: Vec<Box<dyn BasisWorker>> = (0..terms).map(|i| factory(i)).collect();
        let serial = timer.run(|| {
            let mut acc: Option<Tensor> = None;
            for s in slices.iter_mut() {
                let y = s.run(&x).unwrap();
                acc = Some(match acc {
                    Some(a) => a.add(&y),
                    None => y,
                });
            }
            acc.unwrap()
        });
        // parallel: pool broadcast + tree reduce
        let pool = WorkerPool::new(terms, factory.clone());
        let sched = ExpansionScheduler::new(pool);
        let par = timer.run(|| sched.forward(x.clone()).unwrap());
        t.row_str(&[
            &terms.to_string(),
            &format!("{:.3}", serial.mean * 1e3),
            &format!("{:.3}", par.mean * 1e3),
            &format!("{:.2}×", serial.mean / par.mean),
            &format!("{}×", terms.min(cores)),
        ]);
        sched.shutdown();
    }
    t.print();

    // (a') simulated-device panel: each basis model = 2 ms service time
    struct Device(std::time::Duration);
    impl BasisWorker for Device {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            std::thread::sleep(self.0);
            Ok(x.clone())
        }
    }
    let mut t1b = Table::new(
        "perf — t simulated devices (2 ms service): coordinator overlap",
        &["t", "serial (ms)", "parallel (ms)", "speedup", "ideal"],
    );
    for &terms in &[2usize, 4, 8] {
        let dt = std::time::Duration::from_millis(2);
        let serial = timer.run(|| {
            for _ in 0..terms {
                std::thread::sleep(dt);
            }
        });
        let pool = WorkerPool::new(
            terms,
            Arc::new(move |_| Box::new(Device(dt)) as Box<dyn BasisWorker>),
        );
        let sched = ExpansionScheduler::new(pool);
        let par = timer.run(|| sched.forward(x.clone()).unwrap());
        t1b.row_str(&[
            &terms.to_string(),
            &format!("{:.3}", serial.mean * 1e3),
            &format!("{:.3}", par.mean * 1e3),
            &format!("{:.2}×", serial.mean / par.mean),
            &format!("{terms}×"),
        ]);
        sched.shutdown();
    }
    t1b.print();

    // (b) batching policy vs offered load
    let mut t2 = Table::new(
        "perf — coordinator under Poisson load (4 basis workers)",
        &["offered rps", "max_batch", "thpt (rps)", "p50 (ms)", "p99 (ms)", "shed %"],
    );
    let mut json_rows = Vec::new();
    for &rate in &[100.0f64, 400.0, 1200.0] {
        for &(mb, mw) in &[(1usize, 50u64), (32, 1_000)] {
            let pool = WorkerPool::new(4, mlp_basis_factory(&w, 4, 4));
            let coord = Arc::new(Coordinator::new(
                BatcherConfig::uniform(mb, mw, 256),
                ExpansionScheduler::new(pool),
            ));
            let trace = RequestTrace::new(rate, 87);
            let rep = run_trace(&coord, &trace, 1.0, 256, 1.0);
            t2.row_str(&[
                &format!("{rate:.0}"),
                &mb.to_string(),
                &format!("{:.1}", rep.throughput_rps),
                &format!("{:.2}", rep.latency.p50 * 1e3),
                &format!("{:.2}", rep.latency.p99 * 1e3),
                &format!("{:.1}", rep.shed as f64 / rep.offered.max(1) as f64 * 100.0),
            ]);
            json_rows.push(load_row(rate, mb, &rep));
        }
    }
    t2.print();
    let json = Json::obj([("bench", Json::str("coordinator")), ("load_sweep", Json::Arr(json_rows))]);
    match write_bench_json("coordinator", &json) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nBENCH json write failed: {e}"),
    }
    println!(
        "\ntarget (§Perf): parallel ≥ 1.3× serial at t·k = 8 on ≥8 cores;\n\
         batching raises throughput at high load at bounded p99 cost."
    );
}
