//! Design-choice ablations (DESIGN.md §6) — the decisions the paper
//! leaves implicit, quantified:
//!
//!   (1) per-channel vs per-tensor weight ranges
//!   (2) asymmetric vs symmetric activation zero points
//!   (3) the §4 weight-term bound (k=2) vs k=1 / k=3
//!   (4) layer-sync (Eq. 4) vs model-parallel (Theorem 2 slices)
//!
//!     cargo bench --bench ablation_design

use fp_xint::bench_support as bs;
use fp_xint::datasets::accuracy;
use fp_xint::models::{basis, quantized};
use fp_xint::util::{logger, Table};
use fp_xint::xint::expansion::ExpandConfig;
use fp_xint::xint::layer::LayerPolicy;
use fp_xint::xint::quantizer::{Clip, Symmetry};
use fp_xint::xint::{BitSpec, SeriesExpansion};

fn main() {
    logger::init(false);
    let suite = bs::suite();
    let (_, tag, build) = suite[0];
    let (model, fp) = bs::trained_hard(tag, build);
    let data = bs::bench_data_hard();
    let val = data.batch(512, 2);

    // (1)+(2): range granularity on reconstruction error of real weights
    let mut folded = model.clone();
    folded.fold_bn();
    let mut t1 = Table::new(
        "ablation 1/2 — weight range granularity (recon ‖err‖∞ of first conv, INT4 1 term)",
        &["variant", "max abs err"],
    );
    let w = {
        let mut found = None;
        for l in &folded.layers {
            if let fp_xint::models::Layer::Conv(c) = l {
                found = Some(c.w.reshape(&[c.w.dims()[0], c.w.numel() / c.w.dims()[0]]));
                break;
            }
        }
        found.expect("conv")
    };
    for (name, axis, sym) in [
        ("per-tensor symmetric", None, Symmetry::Symmetric),
        ("per-channel symmetric", Some(0), Symmetry::Symmetric),
        ("per-channel asymmetric", Some(0), Symmetry::Asymmetric),
    ] {
        let cfg = ExpandConfig {
            bits: BitSpec::int(4),
            terms: 1,
            symmetry: sym,
            clip: Clip::None,
            channel_axis: axis,
        };
        let e = SeriesExpansion::expand(&w, &cfg);
        t1.row_str(&[name, &format!("{:.5}", w.sub(&e.reconstruct()).max_abs())]);
    }
    t1.print();

    // (3): the §4 k bound
    let mut t3 = Table::new(
        &format!("ablation 3 — weight terms k at W4A4 (t=4 fixed, FP {:.2})", fp),
        &["k", "top-1 %"],
    );
    for k in 1..=3 {
        t3.row_str(&[&k.to_string(), &bs::pct(bs::ours_acc_on(&data, &model, 4, 4, k, 4))]);
    }
    t3.print();
    println!("§4 prediction: k=2 captures the weight side; k=3 adds nothing.\n");

    // (4): layer-sync vs model-parallel
    let mut t4 = Table::new(
        "ablation 4 — execution mode at 8-bit (the Theorem-2 interchange gap)",
        &["mode", "terms", "top-1 %"],
    );
    let probe = data.batch(32, 3).x;
    for terms in [2usize, 4] {
        let q = quantized::quantize_model(
            &model,
            LayerPolicy::new(8, 8).with_terms(2, terms),
        );
        t4.row_str(&[
            "layer-sync (Eq. 4)",
            &terms.to_string(),
            &bs::pct(accuracy(&q.forward(&val.x), &val.y) * 100.0),
        ]);
        let mut slices = basis::basis_slices(&model, 8, terms);
        basis::calibrate_slices(&mut slices, &probe, 8);
        let y = basis::forward_reduced(&slices, &val.x);
        t4.row_str(&[
            "model-parallel (Thm 2)",
            &terms.to_string(),
            &bs::pct(accuracy(&y, &val.y) * 100.0),
        ]);
    }
    t4.print();
    println!(
        "layer-sync is exact; the diagonal model-parallel slices drop (i≠j)\n\
         cross terms, so their gap grows with terms and depth — the honest\n\
         cost of Theorem 2's parallelism on nonlinear networks."
    );
    bs::shape_note();
}
