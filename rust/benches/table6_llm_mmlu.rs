//! Table 6 — LLM stand-in: W4A16 weight-only expansion on the char LM,
//! MMLU-style 4-subject multiple choice scored by sequence likelihood.
//!
//!     cargo bench --bench table6_llm_mmlu

use fp_xint::datasets::charlm::{CharLmTask, SUBJECTS};
use fp_xint::models::TinyLm;
use fp_xint::train::{train_lm, TrainConfig};
use fp_xint::util::{logger, Table};
use fp_xint::xint::layer::LayerPolicy;

fn mmlu_eval(lm: &TinyLm, task: &CharLmTask) -> ([f64; 4], f64) {
    let qs = task.questions();
    let mut correct = [0usize; 4];
    let mut total = [0usize; 4];
    for q in &qs {
        total[q.subject] += 1;
        if lm.answer(q) == q.answer {
            correct[q.subject] += 1;
        }
    }
    let mut per = [0.0f64; 4];
    for s in 0..4 {
        per[s] = correct[s] as f64 / total[s].max(1) as f64 * 100.0;
    }
    let avg = correct.iter().sum::<usize>() as f64 / qs.len() as f64 * 100.0;
    (per, avg)
}

fn main() {
    logger::init(false);
    let task = CharLmTask::new(11);
    let stream = task.tokens();
    let mut lm = TinyLm::new(32, 64, 2, 32, 13);
    println!("training char LM ({} params) on {} tokens…", lm.params(), stream.len());
    let cfg = TrainConfig { steps: 500, batch: 16, lr: 0.08, log_every: 100 };
    let report = train_lm(&mut lm, &stream, &cfg);
    println!(
        "LM loss {:.3} -> {:.3}",
        report.loss_curve.first().unwrap().1,
        report.loss_curve.last().unwrap().1
    );

    let mut t = Table::new(
        "Table 6 — MMLU stand-in (W4A16 weight-only), 24 questions / 4 subjects",
        &["Method", SUBJECTS[0], SUBJECTS[1], SUBJECTS[2], SUBJECTS[3], "Avg."],
    );
    let fmt_row = |name: &str, per: [f64; 4], avg: f64| {
        [
            name.to_string(),
            format!("{:.1}", per[0]),
            format!("{:.1}", per[1]),
            format!("{:.1}", per[2]),
            format!("{:.1}", per[3]),
            format!("{:.1}", avg),
        ]
    };
    let (per, avg) = mmlu_eval(&lm, &task);
    t.row(&fmt_row("Full Prec. (TinyLM)", per, avg));

    // W4 panel (the paper's setting; often lossless on this small LM —
    // the discriminative panel below pushes to W2 where single-term breaks)
    for (name, w_bits, terms) in [
        ("Normal (W4 1-term)", 4u32, 1usize),
        ("Ours (W4 series k=2)", 4, 2),
        ("Normal (W2 1-term)", 2, 1),
        ("Ours (W2 series k=2)", 2, 2),
        ("Ours (W2 series k=3)", 2, 3),
    ] {
        let mut q = lm.clone();
        q.quantize_weights(&LayerPolicy::new(w_bits, 16).with_terms(terms, 1));
        let (per, avg) = mmlu_eval(&q, &task);
        t.row(&fmt_row(name, per, avg));
    }
    t.print();
    fp_xint::bench_support::shape_note();
}
