//! Table 4 — NLP tasks under W4A4: SQuAD stand-in (span F1, per-position
//! start/end head) and MNLI stand-in (entailment accuracy) on TinyBert.
//!
//!     cargo bench --bench table4_nlp

use fp_xint::datasets::textgen::{span_f1, EntailTask, SpanTask};
use fp_xint::models::tinybert::{quantized_copy, BertHead, TinyBert};
use fp_xint::tensor::Tensor;
use fp_xint::train::{train_bert, TrainConfig};
use fp_xint::util::{logger, Table};
use fp_xint::xint::layer::LayerPolicy;

const SEQ: usize = 24;
const SEQ_SPAN: usize = 32;

fn eval_entail(m: &TinyBert, task: &EntailTask) -> f64 {
    let batch = task.batch(300, 2);
    let tokens: Vec<Vec<usize>> = batch.iter().map(|e| e.tokens.clone()).collect();
    let logits = m.forward(&tokens);
    let pred = logits.argmax_rows();
    let correct = pred.iter().zip(&batch).filter(|(p, e)| **p == e.label).count();
    correct as f64 / batch.len() as f64 * 100.0
}

/// Span model: BertHead::Span gives per-token (start, end) logits.
/// Training: cross-entropy over the position axis for each head.
fn train_span(model: &mut TinyBert, task: &SpanTask, steps: usize) {
    let mut opt = fp_xint::train::Sgd::new(0.05);
    for step in 0..steps {
        let b = task.batch(32, 3_000 + step as u64);
        let tokens: Vec<Vec<usize>> = b.iter().map(|e| e.tokens.clone()).collect();
        model.zero_grad();
        let logits = model.forward_train(&tokens); // (N·T, 2)
        let n = b.len();
        // softmax over positions per head
        let mut dl = Tensor::zeros(&[n * SEQ_SPAN, 2]);
        for (s, ex) in b.iter().enumerate() {
            for head in 0..2 {
                let gold = if head == 0 { ex.start } else { ex.end };
                // softmax over the T positions of this sequence
                let mut mx = f32::NEG_INFINITY;
                for p in 0..SEQ_SPAN {
                    mx = mx.max(logits.at(&[s * SEQ_SPAN + p, head]));
                }
                let mut z = 0.0f32;
                let mut probs = [0.0f32; 64];
                for p in 0..SEQ_SPAN {
                    probs[p] = (logits.at(&[s * SEQ_SPAN + p, head]) - mx).exp();
                    z += probs[p];
                }
                for p in 0..SEQ_SPAN {
                    let soft = probs[p] / z;
                    let target = if p == gold { 1.0 } else { 0.0 };
                    dl.data_mut()[(s * SEQ_SPAN + p) * 2 + head] =
                        (soft - target) / (n as f32 * 2.0);
                }
            }
        }
        model.backward(&dl);
        opt.step(|f| model.visit_params(f));
    }
}

fn eval_span(m: &TinyBert, task: &SpanTask) -> f64 {
    let batch = task.batch(200, 2);
    let tokens: Vec<Vec<usize>> = batch.iter().map(|e| e.tokens.clone()).collect();
    let logits = m.forward(&tokens); // (N·T, 2)
    let mut f1 = 0.0;
    for (i, ex) in batch.iter().enumerate() {
        let mut best_s = (0usize, f32::NEG_INFINITY);
        let mut best_e = (0usize, f32::NEG_INFINITY);
        for p in 0..SEQ_SPAN {
            let s = logits.at(&[i * SEQ_SPAN + p, 0]);
            let e = logits.at(&[i * SEQ_SPAN + p, 1]);
            if s > best_s.1 {
                best_s = (p, s);
            }
            if e > best_e.1 {
                best_e = (p, e);
            }
        }
        f1 += span_f1((best_s.0, best_e.0), (ex.start, ex.end));
    }
    f1 / batch.len() as f64 * 100.0
}

fn main() {
    logger::init(false);
    // --- MNLI stand-in: 3-way entailment
    let entail = EntailTask::new(SEQ, 5);
    let mut bert_cls = TinyBert::new(32, 24, 48, 2, SEQ, BertHead::Cls { classes: 3 }, 7);
    let cfg = TrainConfig { steps: 900, batch: 32, lr: 0.04, log_every: 300 };
    println!("training entailment model ({} params)…", bert_cls.params());
    train_bert(
        &mut bert_cls,
        |step| {
            let b = entail.batch(32, 1_000 + step as u64);
            (
                b.iter().map(|e| e.tokens.clone()).collect(),
                b.iter().map(|e| e.label).collect(),
            )
        },
        &cfg,
    );

    // --- SQuAD stand-in: per-position span head
    let span = SpanTask::new(SEQ_SPAN, 9);
    let mut bert_span = TinyBert::new(32, 24, 48, 2, SEQ_SPAN, BertHead::Span, 11);
    println!("training span model ({} params)…", bert_span.params());
    train_span(&mut bert_span, &span, 1200);

    let mut t = Table::new(
        "Table 4 — NLP W4A4 (synthetic SQuAD/MNLI stand-ins)",
        &["Method", "SQuAD-like (F1)", "MNLI-like (Acc)"],
    );
    t.row_str(&[
        "Full Prec.",
        &format!("{:.2}", eval_span(&bert_span, &span)),
        &format!("{:.2}", eval_entail(&bert_cls, &entail)),
    ]);
    let rows: Vec<(&str, LayerPolicy, (u32, usize))> = vec![
        ("Naive W4A4 (1 term)", LayerPolicy::new(4, 4).with_terms(1, 1), (4, 1)),
        ("Naive W2A4 (1 term)", LayerPolicy::new(2, 4).with_terms(1, 1), (4, 1)),
        ("Ours W4A4 (series)", LayerPolicy::new(4, 4).with_terms(2, 4), (4, 4)),
        ("Ours W2A4 (series)", LayerPolicy::new(2, 4).with_terms(3, 4), (4, 4)),
    ];
    for (name, policy, act) in rows {
        let mut q_cls = quantized_copy(&bert_cls, &policy);
        q_cls.act_quant = Some(act);
        let mut q_span = quantized_copy(&bert_span, &policy);
        q_span.act_quant = Some(act);
        t.row_str(&[
            name,
            &format!("{:.2}", eval_span(&q_span, &span)),
            &format!("{:.2}", eval_entail(&q_cls, &entail)),
        ]);
    }
    t.print();
    fp_xint::bench_support::shape_note();
}
