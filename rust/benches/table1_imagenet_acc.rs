//! Table 1 — top-1 accuracy of the CNN suite under W4A4 / W2A4 / W2A2,
//! ours vs PTQ baselines. Paper reference rows in EXPERIMENTS.md.
//!
//!     cargo bench --bench table1_imagenet_acc

use fp_xint::bench_support as bs;
use fp_xint::util::{logger, Table};

fn main() {
    logger::init(false);
    let suite = bs::suite();
    // train / load every model once
    let trained: Vec<(&str, fp_xint::models::Model, f64)> = suite
        .iter()
        .map(|(paper, tag, build)| {
            let (m, fp) = bs::trained(tag, *build);
            (*paper, m, fp)
        })
        .collect();

    for (w_bits, a_bits) in [(4u32, 4u32), (2, 4), (2, 2)] {
        let header: Vec<String> = std::iter::once("Method".to_string())
            .chain(trained.iter().map(|(n, _, _)| n.to_string()))
            .collect();
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Table 1 — Bits W{w_bits}A{a_bits} (top-1 %, synthetic ImageNet stand-in)"),
            &header_refs,
        );
        // Full precision row
        let mut row = vec!["Full Prec.".to_string()];
        row.extend(trained.iter().map(|(_, _, fp)| bs::pct(*fp)));
        t.row(&row);
        // Baselines
        for method in bs::methods() {
            let mut row = vec![method.name().to_string()];
            for (_, m, _) in &trained {
                row.push(bs::pct(bs::baseline_acc(m, method.as_ref(), w_bits, a_bits)));
            }
            t.row(&row);
        }
        // Ours
        let mut row = vec!["Ours (series)".to_string()];
        for (_, m, _) in &trained {
            row.push(bs::pct(bs::ours_acc(m, w_bits, a_bits)));
        }
        t.row(&row);
        t.print();
        println!();
    }
    bs::shape_note();
}
