//! Execution runtime: token-passing scheduler + vector-clock weak memory.
//!
//! One model runs at a time (`MODEL_LOCK` serializes `loom::model` calls
//! across test threads). Inside a model, registered threads are real OS
//! threads but only the thread holding the token (`State::current`) may
//! run; every vendored primitive operation funnels through a scheduling
//! point where the token can move. Blocking (mutex contention, condvar
//! waits, joins) is explicit in `Tstate`, which makes deadlock detection a
//! simple "no runnable thread" check.
//!
//! Registration of atomics / mutexes / condvars is lazy: each object holds
//! an epoch-tagged id cell, so objects created in a previous iteration (or
//! outside any model) are re-registered cleanly instead of dangling.
//!
//! On any failure (panic in a model thread, deadlock, leaked thread) the
//! `panicked` flag flips the whole runtime into pass-through mode: every
//! blocked thread is woken, scheduling stops, and primitives degrade to
//! their plain `std` behavior so the iteration can drain and the failure
//! can be reported from `run_model` instead of hanging the test binary.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicU64 as IdCell;
use std::sync::atomic::Ordering as StdOrdering;
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

const EPOCH_SHIFT: u32 = 32;
const IDX_MASK: u64 = (1 << EPOCH_SHIFT) - 1;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Block {
    Mutex(usize),
    Cond(usize),
    Join(usize),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Tstate {
    Runnable,
    Blocked(Block),
    Finished,
}

/// One entry in an atomic location's modification order.
struct StoreRec {
    val: u64,
    clock: Vec<u64>,
    release: bool,
}

struct Location {
    stores: Vec<StoreRec>,
    /// Per-thread coherence floor: index of the newest store each thread
    /// has already observed (a thread may never read older than this).
    floor: Vec<usize>,
}

struct State {
    active: bool,
    epoch: u64,
    current: usize,
    threads: Vec<Tstate>,
    clocks: Vec<Vec<u64>>,
    locations: Vec<Location>,
    sync_objects: usize,
    rng: u64,
    preemptions_left: usize,
    panicked: Option<String>,
}

impl State {
    const fn new() -> State {
        State {
            active: false,
            epoch: 0,
            current: 0,
            threads: Vec::new(),
            clocks: Vec::new(),
            locations: Vec::new(),
            sync_objects: 0,
            rng: 0,
            preemptions_left: 0,
            panicked: None,
        }
    }

    /// SplitMix64: deterministic per-iteration schedule randomness.
    fn next_u64(&mut self) -> u64 {
        self.rng = self.rng.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.rng;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

struct Rt {
    m: Mutex<State>,
    cv: Condvar,
}

fn rt() -> &'static Rt {
    static RT: OnceLock<Rt> = OnceLock::new();
    RT.get_or_init(|| Rt { m: Mutex::new(State::new()), cv: Condvar::new() })
}

static MODEL_LOCK: Mutex<()> = Mutex::new(());

thread_local! {
    /// (epoch, tid) of the model execution this OS thread belongs to.
    static TID: Cell<Option<(u64, usize)>> = const { Cell::new(None) };
}

fn tls() -> Option<(u64, usize)> {
    TID.with(|t| t.get())
}

/// Cheap hint (no lock): is this OS thread a registered model thread?
pub(crate) fn in_model() -> bool {
    tls().is_some()
}

/// Definitive check under the runtime lock: the calling thread belongs to
/// the *current, live, non-failed* model execution.
fn ctx(st: &State) -> Option<usize> {
    let (epoch, tid) = tls()?;
    if st.active && epoch == st.epoch && st.panicked.is_none() {
        Some(tid)
    } else {
        None
    }
}

fn lock_state() -> MutexGuard<'static, State> {
    rt().m.lock().unwrap_or_else(|e| e.into_inner())
}

fn clock_get(c: &[u64], i: usize) -> u64 {
    c.get(i).copied().unwrap_or(0)
}

fn clock_le(a: &[u64], b: &[u64]) -> bool {
    (0..a.len().max(b.len())).all(|i| clock_get(a, i) <= clock_get(b, i))
}

fn clock_join(dst: &mut Vec<u64>, src: &[u64]) {
    if dst.len() < src.len() {
        dst.resize(src.len(), 0);
    }
    for (i, v) in src.iter().enumerate() {
        if *v > dst[i] {
            dst[i] = *v;
        }
    }
}

/// Tick `me`'s own component and return a snapshot of its clock.
fn tick(st: &mut State, me: usize) -> Vec<u64> {
    if st.clocks[me].len() <= me {
        st.clocks[me].resize(me + 1, 0);
    }
    st.clocks[me][me] += 1;
    st.clocks[me].clone()
}

/// Hand the token to a random runnable thread; records a deadlock (all
/// live threads blocked) in `panicked` instead of hanging.
fn pick_next(st: &mut State) {
    let runnable: Vec<usize> = st
        .threads
        .iter()
        .enumerate()
        .filter(|(_, t)| matches!(t, Tstate::Runnable))
        .map(|(i, _)| i)
        .collect();
    if runnable.is_empty() {
        let any_blocked = st.threads.iter().any(|t| matches!(t, Tstate::Blocked(_)));
        if any_blocked && st.panicked.is_none() {
            st.panicked = Some(format!(
                "deadlock: every live model thread is blocked ({:?})",
                st.threads
            ));
        }
        return;
    }
    let r = st.next_u64() as usize;
    st.current = runnable[r % runnable.len()];
}

fn wait_token(mut st: MutexGuard<'static, State>, epoch: u64, me: usize) {
    while st.active && st.panicked.is_none() && st.epoch == epoch && st.current != me {
        st = rt().cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
}

/// A scheduling point: with probability 1/2 (while the preemption budget
/// lasts) hand the token to another runnable thread and wait to get it
/// back. `voluntary` points (yield/sleep) always offer the token and do
/// not consume the budget.
fn switch_point(voluntary: bool) {
    if tls().is_none() {
        return;
    }
    let mut st = lock_state();
    let Some(me) = ctx(&st) else { return };
    let epoch = st.epoch;
    let others: Vec<usize> = st
        .threads
        .iter()
        .enumerate()
        .filter(|(i, t)| *i != me && matches!(t, Tstate::Runnable))
        .map(|(i, _)| i)
        .collect();
    if others.is_empty() {
        return;
    }
    let take = if voluntary {
        true
    } else if st.preemptions_left == 0 {
        false
    } else {
        st.next_u64() % 2 == 0
    };
    if !take {
        return;
    }
    if !voluntary {
        st.preemptions_left -= 1;
    }
    let r = st.next_u64() as usize;
    st.current = others[r % others.len()];
    rt().cv.notify_all();
    wait_token(st, epoch, me);
}

pub(crate) fn sched_point() {
    switch_point(false);
}

pub(crate) fn yield_point() {
    if tls().is_some() {
        switch_point(true);
    } else {
        std::thread::yield_now();
    }
}

/// Block the calling thread on `why` until some other thread unblocks it
/// and the scheduler hands it the token. Returns false in pass-through
/// mode (no model scheduling happened; the caller must fall back to plain
/// `std` behavior).
fn block_current(why: Block) -> bool {
    let mut st = lock_state();
    let Some(me) = ctx(&st) else { return false };
    let epoch = st.epoch;
    st.threads[me] = Tstate::Blocked(why);
    pick_next(&mut st);
    rt().cv.notify_all();
    while st.active
        && st.panicked.is_none()
        && st.epoch == epoch
        && !(st.current == me && matches!(st.threads[me], Tstate::Runnable))
    {
        st = rt().cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
    // On a pass-through exit (failure elsewhere) make sure we are not left
    // marked blocked, so the all-finished accounting still converges.
    if matches!(st.threads[me], Tstate::Blocked(_)) {
        st.threads[me] = Tstate::Runnable;
    }
    true
}

/// Resolve an object's epoch-tagged id cell, registering it on first use
/// within the current iteration.
fn resolve_id(
    st: &mut State,
    cell: &IdCell,
    mut register: impl FnMut(&mut State) -> usize,
) -> usize {
    let packed = cell.load(StdOrdering::Relaxed);
    if packed >> EPOCH_SHIFT == st.epoch {
        return (packed & IDX_MASK) as usize;
    }
    let idx = register(st);
    cell.store((st.epoch << EPOCH_SHIFT) | idx as u64, StdOrdering::Relaxed);
    idx
}

fn resolve_sync_id(st: &mut State, cell: &IdCell) -> usize {
    resolve_id(st, cell, |st| {
        st.sync_objects += 1;
        st.sync_objects - 1
    })
}

fn resolve_loc(st: &mut State, cell: &IdCell, init: u64) -> usize {
    resolve_id(st, cell, |st| {
        st.locations.push(Location {
            // The initial value: an all-zero clock is `<=` every thread's
            // clock, so it is always visible, and marking it release makes
            // acquiring it a no-op join.
            stores: vec![StoreRec { val: init, clock: Vec::new(), release: true }],
            floor: Vec::new(),
        });
        st.locations.len() - 1
    })
}

// ---- sync primitives -------------------------------------------------

pub(crate) fn block_on_mutex(cell: &IdCell) -> bool {
    let why = {
        let mut st = lock_state();
        if ctx(&st).is_none() {
            return false;
        }
        Block::Mutex(resolve_sync_id(&mut st, cell))
    };
    block_current(why)
}

pub(crate) fn mutex_released(cell: &IdCell) {
    let mut st = lock_state();
    let Some((epoch, _)) = tls() else { return };
    // Wake waiters even when `panicked` is set: they exit to pass-through.
    if !st.active || st.epoch != epoch {
        return;
    }
    let id = resolve_sync_id(&mut st, cell);
    for t in st.threads.iter_mut() {
        if *t == Tstate::Blocked(Block::Mutex(id)) {
            *t = Tstate::Runnable;
        }
    }
    rt().cv.notify_all();
}

pub(crate) fn cond_block(cell: &IdCell) -> bool {
    let why = {
        let mut st = lock_state();
        if ctx(&st).is_none() {
            return false;
        }
        Block::Cond(resolve_sync_id(&mut st, cell))
    };
    block_current(why)
}

pub(crate) fn cond_notify(cell: &IdCell, all: bool) {
    let mut st = lock_state();
    let Some((epoch, _)) = tls() else { return };
    if !st.active || st.epoch != epoch {
        return;
    }
    let id = resolve_sync_id(&mut st, cell);
    let mut woken = 0usize;
    for t in st.threads.iter_mut() {
        if *t == Tstate::Blocked(Block::Cond(id)) {
            *t = Tstate::Runnable;
            woken += 1;
            if !all && woken == 1 {
                break;
            }
        }
    }
    if woken > 0 {
        rt().cv.notify_all();
    }
}

// ---- atomics ---------------------------------------------------------

/// Model-checked atomic load. `None` means pass-through (caller should
/// use its real fallback atomic).
pub(crate) fn atomic_load(cell: &IdCell, init: u64, acquire: bool) -> Option<u64> {
    tls()?;
    sched_point();
    let mut st = lock_state();
    let me = ctx(&st)?;
    let loc_i = resolve_loc(&mut st, cell, init);
    let r = st.next_u64() as usize;
    let my_clock = st.clocks[me].clone();
    let (val, join_clock) = {
        let loc = &mut st.locations[loc_i];
        if loc.floor.len() <= me {
            loc.floor.resize(me + 1, 0);
        }
        let hi = loc.stores.len() - 1;
        // Visibility floor: the newest store already ordered before us by
        // happens-before; anything older would be an incoherent read.
        let mut lo = loc.floor[me];
        for i in (lo..=hi).rev() {
            if clock_le(&loc.stores[i].clock, &my_clock) {
                lo = lo.max(i);
                break;
            }
        }
        let idx = if hi > lo { lo + r % (hi - lo + 1) } else { lo };
        loc.floor[me] = idx;
        let s = &loc.stores[idx];
        let join = if acquire && s.release { Some(s.clock.clone()) } else { None };
        (s.val, join)
    };
    if let Some(c) = join_clock {
        clock_join(&mut st.clocks[me], &c);
    }
    Some(val)
}

pub(crate) fn atomic_store(cell: &IdCell, init: u64, val: u64, release: bool) -> Option<()> {
    tls()?;
    sched_point();
    let mut st = lock_state();
    let me = ctx(&st)?;
    let loc_i = resolve_loc(&mut st, cell, init);
    let snap = tick(&mut st, me);
    let loc = &mut st.locations[loc_i];
    loc.stores.push(StoreRec { val, clock: snap, release });
    if loc.floor.len() <= me {
        loc.floor.resize(me + 1, 0);
    }
    loc.floor[me] = loc.stores.len() - 1;
    Some(())
}

/// Model-checked read-modify-write: reads the newest store (joining its
/// clock — RMWs are modeled acquire+release) and, if `f` returns a new
/// value, appends it to the modification order. `Ok((prev, new))` /
/// `Err(prev)` mirror `fetch_update`'s contract.
pub(crate) fn atomic_rmw(
    cell: &IdCell,
    init: u64,
    f: &mut dyn FnMut(u64) -> Option<u64>,
) -> Option<Result<(u64, u64), u64>> {
    tls()?;
    sched_point();
    let mut st = lock_state();
    let me = ctx(&st)?;
    let loc_i = resolve_loc(&mut st, cell, init);
    let (prev, join_clock) = {
        let s = st.locations[loc_i].stores.last().expect("location has an initial store");
        let join = if s.release { Some(s.clock.clone()) } else { None };
        (s.val, join)
    };
    if let Some(c) = join_clock {
        clock_join(&mut st.clocks[me], &c);
    }
    match f(prev) {
        Some(new) => {
            let snap = tick(&mut st, me);
            let loc = &mut st.locations[loc_i];
            loc.stores.push(StoreRec { val: new, clock: snap, release: true });
            if loc.floor.len() <= me {
                loc.floor.resize(me + 1, 0);
            }
            loc.floor[me] = loc.stores.len() - 1;
            Some(Ok((prev, new)))
        }
        None => Some(Err(prev)),
    }
}

// ---- thread lifecycle ------------------------------------------------

/// Register a child thread from the (token-holding) parent. Returns the
/// child's (epoch, tid), or `None` in pass-through mode.
pub(crate) fn register_thread() -> Option<(u64, usize)> {
    let mut st = lock_state();
    let parent = ctx(&st)?;
    let epoch = st.epoch;
    let tid = st.threads.len();
    st.threads.push(Tstate::Runnable);
    // Spawn edge: the child starts with (a copy of) the parent's clock.
    let mut child_clock = tick(&mut st, parent);
    if child_clock.len() <= tid {
        child_clock.resize(tid + 1, 0);
    }
    child_clock[tid] += 1;
    st.clocks.push(child_clock);
    Some((epoch, tid))
}

pub(crate) fn attach(epoch: u64, tid: usize) {
    TID.with(|t| t.set(Some((epoch, tid))));
}

pub(crate) fn detach() {
    TID.with(|t| t.set(None));
}

/// A freshly spawned model thread parks here until first scheduled.
pub(crate) fn wait_first_token(epoch: u64, tid: usize) {
    let st = lock_state();
    wait_token(st, epoch, tid);
}

pub(crate) fn thread_finished(epoch: u64, tid: usize, panic_msg: Option<String>) {
    let mut st = lock_state();
    if st.epoch != epoch {
        return;
    }
    st.threads[tid] = Tstate::Finished;
    if let Some(msg) = panic_msg {
        if st.panicked.is_none() {
            st.panicked = Some(msg);
        }
    }
    for t in st.threads.iter_mut() {
        if *t == Tstate::Blocked(Block::Join(tid)) {
            *t = Tstate::Runnable;
        }
    }
    if st.active && st.current == tid {
        pick_next(&mut st);
    }
    rt().cv.notify_all();
}

/// Model-aware join: blocks (scheduler-visible) until `child` finishes,
/// then joins its clock (join edge). Returns false in pass-through mode;
/// either way the caller still performs the real `JoinHandle::join`.
pub(crate) fn join_thread(epoch: u64, child: usize) -> bool {
    loop {
        {
            let mut st = lock_state();
            let Some(me) = ctx(&st) else { return false };
            if st.epoch != epoch {
                return false;
            }
            if matches!(st.threads[child], Tstate::Finished) {
                let c = st.clocks[child].clone();
                clock_join(&mut st.clocks[me], &c);
                return true;
            }
        }
        if !block_current(Block::Join(child)) {
            return false;
        }
    }
}

// ---- model driver ----------------------------------------------------

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub(crate) fn iters_from_env() -> usize {
    env_usize("LOOM_MAX_ITERS", 512).max(1)
}

pub(crate) fn run_model(iters: usize, f: &dyn Fn()) {
    let _serial = MODEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    assert!(tls().is_none(), "nested loom::model is not supported");
    let max_preemptions = env_usize("LOOM_MAX_PREEMPTIONS", 4);
    for iter in 0..iters {
        run_one(iter as u64, max_preemptions, f);
    }
}

fn run_one(iter: u64, max_preemptions: usize, f: &dyn Fn()) {
    let epoch = {
        let mut st = lock_state();
        st.epoch += 1;
        st.active = true;
        st.current = 0;
        st.threads = vec![Tstate::Runnable];
        st.clocks = vec![vec![0]];
        st.locations.clear();
        st.sync_objects = 0;
        st.rng = 0x0d2c_e0ed ^ iter.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        st.preemptions_left = max_preemptions;
        st.panicked = None;
        st.epoch
    };
    attach(epoch, 0);
    let out = catch_unwind(AssertUnwindSafe(f));
    // Main is done: hand the token over and wait for every spawned thread
    // to finish (models are expected to join their threads; the timeout
    // turns a leak into a loud failure instead of a hang).
    let (leaked, failure) = {
        let mut st = lock_state();
        st.threads[0] = Tstate::Finished;
        if out.is_err() && st.panicked.is_none() {
            st.panicked = Some(String::from("model main thread panicked"));
        }
        if st.current == 0 {
            pick_next(&mut st);
        }
        rt().cv.notify_all();
        let deadline = Instant::now() + Duration::from_secs(10);
        let leaked = loop {
            if st.threads.iter().all(|t| matches!(t, Tstate::Finished)) {
                break false;
            }
            if Instant::now() >= deadline {
                break true;
            }
            rt().cv.notify_all();
            let (g, _) = rt()
                .cv
                .wait_timeout(st, Duration::from_millis(50))
                .unwrap_or_else(|e| e.into_inner());
            st = g;
        };
        st.active = false;
        let failure = st.panicked.clone();
        rt().cv.notify_all();
        (leaked, failure)
    };
    detach();
    if let Err(e) = out {
        resume_unwind(e);
    }
    if leaked {
        panic!("loom: model iteration {iter} leaked threads after main returned");
    }
    if let Some(msg) = failure {
        panic!("loom: model failed at iteration {iter}: {msg}");
    }
}
