//! Model-aware threads: inside `loom::model`, spawned threads register
//! with the scheduler and run token-serialized; outside, everything
//! delegates straight to `std::thread`.

use std::fmt;
use std::io;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::time::Duration;

use crate::rt;

pub use std::thread::Result;

pub struct JoinHandle<T> {
    inner: std::thread::JoinHandle<T>,
    model: Option<(u64, usize)>,
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        if let Some((epoch, tid)) = self.model {
            // Scheduler-visible wait (join edge for the vector clocks);
            // the real join below then completes without blocking long.
            rt::join_thread(epoch, tid);
        }
        self.inner.join()
    }

    pub fn is_finished(&self) -> bool {
        self.inner.is_finished()
    }

    pub fn thread(&self) -> &std::thread::Thread {
        self.inner.thread()
    }
}

impl<T> fmt::Debug for JoinHandle<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("JoinHandle { .. }")
    }
}

#[derive(Debug, Default)]
pub struct Builder {
    name: Option<String>,
    stack_size: Option<usize>,
}

impl Builder {
    pub fn new() -> Builder {
        Builder::default()
    }

    pub fn name(mut self, name: String) -> Builder {
        self.name = Some(name);
        self
    }

    pub fn stack_size(mut self, size: usize) -> Builder {
        self.stack_size = Some(size);
        self
    }

    pub fn spawn<F, T>(self, f: F) -> io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let mut b = std::thread::Builder::new();
        if let Some(n) = self.name {
            b = b.name(n);
        }
        if let Some(s) = self.stack_size {
            b = b.stack_size(s);
        }
        match rt::register_thread() {
            Some((epoch, tid)) => {
                let spawned = b.spawn(move || {
                    rt::attach(epoch, tid);
                    rt::wait_first_token(epoch, tid);
                    let out = catch_unwind(AssertUnwindSafe(f));
                    rt::thread_finished(epoch, tid, panic_message(&out));
                    rt::detach();
                    match out {
                        Ok(v) => v,
                        Err(e) => resume_unwind(e),
                    }
                });
                match spawned {
                    Ok(inner) => Ok(JoinHandle { inner, model: Some((epoch, tid)) }),
                    Err(e) => {
                        // Never ran: retire the registration so the model
                        // does not wait for a thread that cannot finish.
                        rt::thread_finished(epoch, tid, None);
                        Err(e)
                    }
                }
            }
            None => {
                let inner = b.spawn(f)?;
                Ok(JoinHandle { inner, model: None })
            }
        }
    }
}

fn panic_message<T>(out: &std::thread::Result<T>) -> Option<String> {
    let e = out.as_ref().err()?;
    if let Some(s) = e.downcast_ref::<&str>() {
        Some((*s).to_string())
    } else if let Some(s) = e.downcast_ref::<String>() {
        Some(s.clone())
    } else {
        Some(String::from("model thread panicked"))
    }
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    Builder::new().spawn(f).expect("failed to spawn thread")
}

pub fn yield_now() {
    rt::yield_point();
}

/// Inside a model the duration is ignored: sleeping is modeled as a
/// voluntary scheduling point (any interleaving a real sleep could expose
/// is reachable that way, without slowing the model down).
pub fn sleep(dur: Duration) {
    if rt::in_model() {
        rt::yield_point();
    } else {
        std::thread::sleep(dur);
    }
}

pub fn panicking() -> bool {
    std::thread::panicking()
}

/// Host parallelism is model-independent — delegate to std (sizing
/// decisions are data, not synchronization; nothing to explore).
pub fn available_parallelism() -> io::Result<std::num::NonZeroUsize> {
    std::thread::available_parallelism()
}

pub fn current() -> std::thread::Thread {
    std::thread::current()
}
