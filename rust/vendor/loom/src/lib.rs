//! Vendored miniature [loom](https://github.com/tokio-rs/loom)-style model
//! checker, API-compatible with the subset of loom that `fp_xint`'s
//! `util::sync` shim re-exports. The container this repo builds in has no
//! network registry access, so instead of the real loom we vendor a small
//! checker with the same contract:
//!
//! - [`model`] runs a closure many times (default 512 iterations, override
//!   with `LOOM_MAX_ITERS`), each under a different seeded schedule.
//! - Inside a model, `loom::thread::spawn` threads are real OS threads
//!   serialized by a token-passing scheduler: exactly one model thread runs
//!   at a time, and every atomic / mutex / condvar operation is a scheduling
//!   point where the token may move (bounded by `LOOM_MAX_PREEMPTIONS`,
//!   default 4 forced preemptions per execution; voluntary blocking and
//!   `yield_now` are always scheduling edges and never count).
//! - Weak memory is simulated for atomics: each location keeps its full
//!   store history with a vector-clock snapshot per store, and a load may
//!   return any store not ruled out by per-thread coherence (a thread never
//!   re-reads an older store than one it already read) or happens-before
//!   (stores whose clock is `<=` the reader's clock put a floor on how stale
//!   the read may be). An `Acquire` load that observes a `Release` store
//!   joins the reader's clock with the writer's. Read-modify-writes always
//!   read the newest store and publish with release semantics — a sound
//!   strengthening that cannot hide plain load/store reordering bugs.
//! - `SeqCst` is approximated as Release+Acquire. This can miss bugs that
//!   depend on the absence of a single total order across locations, but it
//!   admits no false positives, and none of the modeled protocols rely on
//!   `SeqCst`-only reasoning.
//!
//! Outside [`model`], every vendored primitive behaves exactly like its
//! `std::sync` / `std::thread` counterpart, so the whole `fp_xint` test
//! suite still compiles and runs correctly under `--cfg loom`.
//!
//! A failing interleaving panics with the iteration index; iterations are
//! deterministic given the same `LOOM_MAX_ITERS` / `LOOM_MAX_PREEMPTIONS`,
//! so a failure reproduces by re-running the same test.

#![forbid(unsafe_code)]

mod rt;
pub mod sync;
pub mod thread;

/// Run `f` under the model checker for `LOOM_MAX_ITERS` (default 512)
/// seeded schedules. Panics (with the iteration index) on the first
/// schedule in which `f` panics, deadlocks, or leaks an unjoined thread.
pub fn model<F: Fn()>(f: F) {
    rt::run_model(rt::iters_from_env(), &f);
}

/// [`model`] with an explicit iteration count, for expensive models that
/// need a smaller budget than the global default.
pub fn model_iters<F: Fn()>(iters: usize, f: F) {
    rt::run_model(iters, &f);
}
