//! `std::sync`-shaped primitives, model-aware inside `loom::model`.
//!
//! `Mutex` and `Condvar` wrap their `std` counterparts; inside a model
//! every acquire / wait / notify goes through the runtime so blocking is
//! visible to the scheduler (and deadlocks are detected instead of hung).
//! `Condvar::wait_timeout` inside a model returns an immediate spurious
//! timeout (legal per its contract) after a release + scheduling point,
//! so timed waits cannot stall the single-token scheduler.
//!
//! `Arc`, `mpsc`, and `OnceLock` are plain `std` re-exports: the runtime
//! serializes model threads onto real OS threads, so `std`'s own versions
//! are already correct — only *blocking* (`mpsc::Receiver::recv` etc.)
//! would be invisible to the scheduler. Models must use `try_recv`.

pub mod atomic;

pub use std::sync::{mpsc, Arc, LockResult, OnceLock, PoisonError, TryLockError, Weak};

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::AtomicU64 as IdCell;
use std::sync::Condvar as StdCondvar;
use std::sync::Mutex as StdMutex;
use std::sync::MutexGuard as StdMutexGuard;
use std::time::{Duration, Instant};

use crate::rt;

pub struct Mutex<T> {
    id: IdCell,
    inner: StdMutex<T>,
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
}

fn wrap_lock<'a, T>(
    lock: &'a Mutex<T>,
    r: LockResult<StdMutexGuard<'a, T>>,
) -> LockResult<MutexGuard<'a, T>> {
    match r {
        Ok(g) => Ok(MutexGuard { lock, inner: Some(g) }),
        Err(p) => Err(PoisonError::new(MutexGuard { lock, inner: Some(p.into_inner()) })),
    }
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Mutex<T> {
        Mutex { id: IdCell::new(0), inner: StdMutex::new(t) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if !rt::in_model() {
            return wrap_lock(self, self.inner.lock());
        }
        let mut teardown: Option<Instant> = None;
        loop {
            rt::sched_point();
            match self.inner.try_lock() {
                Ok(g) => return Ok(MutexGuard { lock: self, inner: Some(g) }),
                Err(TryLockError::Poisoned(p)) => {
                    let g = MutexGuard { lock: self, inner: Some(p.into_inner()) };
                    return Err(PoisonError::new(g));
                }
                Err(TryLockError::WouldBlock) => {
                    if rt::block_on_mutex(&self.id) {
                        continue;
                    }
                    // Pass-through (model tearing down after a failure):
                    // the holder now runs freely and will release soon,
                    // unless the failure was a genuine lock cycle — bound
                    // the spin so that still fails loudly.
                    let t0 = *teardown.get_or_insert_with(Instant::now);
                    if t0.elapsed() > Duration::from_secs(5) {
                        panic!("loom: lock unavailable during model teardown");
                    }
                    std::thread::yield_now();
                }
            }
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        match self.inner.into_inner() {
            Ok(t) => Ok(t),
            Err(p) => Err(PoisonError::new(p.into_inner())),
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        match self.inner.get_mut() {
            Ok(t) => Ok(t),
            Err(p) => Err(PoisonError::new(p.into_inner())),
        }
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T> From<T> for Mutex<T> {
    fn from(t: T) -> Mutex<T> {
        Mutex::new(t)
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after release")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard accessed after release")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        let g = self.inner.take();
        if g.is_some() {
            // Release the real lock first, then wake model waiters.
            drop(g);
            rt::mutex_released(&self.lock.id);
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

pub struct Condvar {
    id: IdCell,
    inner: StdCondvar,
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar { id: IdCell::new(0), inner: StdCondvar::new() }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        if rt::in_model() {
            // The waiter holds the scheduler token from the release until
            // it is marked blocked, so a notify cannot slip in between:
            // no lost wakeups.
            drop(guard.inner.take());
            rt::mutex_released(&lock.id);
            drop(guard);
            rt::cond_block(&self.id);
            lock.lock()
        } else {
            let std_g = guard.inner.take().expect("guard accessed after release");
            drop(guard);
            wrap_lock(lock, self.inner.wait(std_g))
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        let lock = guard.lock;
        if rt::in_model() {
            // Modeled as an immediate (legal) spurious timeout, with a
            // real release + scheduling point so contenders can take the
            // lock in between.
            drop(guard.inner.take());
            rt::mutex_released(&lock.id);
            drop(guard);
            rt::yield_point();
            let timed = WaitTimeoutResult { timed_out: true };
            match lock.lock() {
                Ok(g) => Ok((g, timed)),
                Err(p) => Err(PoisonError::new((p.into_inner(), timed))),
            }
        } else {
            let std_g = guard.inner.take().expect("guard accessed after release");
            drop(guard);
            match self.inner.wait_timeout(std_g, dur) {
                Ok((g, w)) => {
                    let out = MutexGuard { lock, inner: Some(g) };
                    Ok((out, WaitTimeoutResult { timed_out: w.timed_out() }))
                }
                Err(p) => {
                    let (g, w) = p.into_inner();
                    let out = MutexGuard { lock, inner: Some(g) };
                    Err(PoisonError::new((out, WaitTimeoutResult { timed_out: w.timed_out() })))
                }
            }
        }
    }

    pub fn notify_one(&self) {
        rt::cond_notify(&self.id, false);
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        rt::cond_notify(&self.id, true);
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("Condvar { .. }")
    }
}
