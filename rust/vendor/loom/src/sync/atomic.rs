//! Model-checked atomics.
//!
//! Inside a model, every operation routes through the runtime's
//! per-location store history (weak-memory simulation: a load may observe
//! any coherent, happens-before-consistent store, not just the newest).
//! Outside a model, each type degrades to its plain `std` counterpart via
//! the embedded fallback atomic, which the model path also mirrors so
//! `Debug` and pass-through reads always see the newest value.

pub use std::sync::atomic::Ordering;

use std::fmt;
use std::sync::atomic::AtomicBool as StdAtomicBool;
use std::sync::atomic::AtomicU32 as StdAtomicU32;
use std::sync::atomic::AtomicU64 as StdAtomicU64;
use std::sync::atomic::AtomicUsize as StdAtomicUsize;

use crate::rt;

fn is_acquire(o: Ordering) -> bool {
    matches!(o, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

fn is_release(o: Ordering) -> bool {
    matches!(o, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

fn u64_ident(v: u64) -> u64 {
    v
}

fn usize_into(v: usize) -> u64 {
    v as u64
}

fn usize_from(v: u64) -> usize {
    v as usize
}

fn u32_into(v: u32) -> u64 {
    u64::from(v)
}

fn u32_from(v: u64) -> u32 {
    v as u32
}

fn bool_into(v: bool) -> u64 {
    u64::from(v)
}

fn bool_from(v: u64) -> bool {
    v != 0
}

/// Shared surface: construction, load/store, swap, CAS, `fetch_update`,
/// and the bit ops valid for every atomic type (incl. `AtomicBool`).
macro_rules! model_atomic_core {
    ($name:ident, $prim:ty, $std:ty, $into:path, $from:path) => {
        pub struct $name {
            id: StdAtomicU64,
            v: $std,
        }

        impl $name {
            pub fn new(v: $prim) -> $name {
                $name { id: StdAtomicU64::new(0), v: <$std>::new(v) }
            }

            fn init(&self) -> u64 {
                $into(self.v.load(Ordering::SeqCst))
            }

            /// Model-path RMW with fallback mirroring; `None` = not in a
            /// model (caller must use the fallback atomic).
            fn rmw(
                &self,
                f: &mut dyn FnMut($prim) -> Option<$prim>,
            ) -> Option<Result<$prim, $prim>> {
                let out = rt::atomic_rmw(&self.id, self.init(), &mut |cur| {
                    f($from(cur)).map($into)
                })?;
                Some(match out {
                    Ok((prev, new)) => {
                        self.v.store($from(new), Ordering::SeqCst);
                        Ok($from(prev))
                    }
                    Err(prev) => Err($from(prev)),
                })
            }

            pub fn load(&self, order: Ordering) -> $prim {
                match rt::atomic_load(&self.id, self.init(), is_acquire(order)) {
                    Some(v) => $from(v),
                    None => self.v.load(order),
                }
            }

            pub fn store(&self, val: $prim, order: Ordering) {
                match rt::atomic_store(&self.id, self.init(), $into(val), is_release(order)) {
                    Some(()) => self.v.store(val, Ordering::SeqCst),
                    None => self.v.store(val, order),
                }
            }

            pub fn swap(&self, val: $prim, order: Ordering) -> $prim {
                match self.rmw(&mut |_| Some(val)) {
                    Some(Ok(prev)) => prev,
                    Some(Err(_)) => unreachable!("swap rmw cannot fail"),
                    None => self.v.swap(val, order),
                }
            }

            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                match self.rmw(&mut |cur| if cur == current { Some(new) } else { None }) {
                    Some(r) => r,
                    None => self.v.compare_exchange(current, new, success, failure),
                }
            }

            pub fn compare_exchange_weak(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                // The model never fails spuriously (a sound strengthening).
                self.compare_exchange(current, new, success, failure)
            }

            pub fn fetch_update<F>(
                &self,
                set_order: Ordering,
                fetch_order: Ordering,
                mut f: F,
            ) -> Result<$prim, $prim>
            where
                F: FnMut($prim) -> Option<$prim>,
            {
                match self.rmw(&mut f) {
                    Some(r) => r,
                    None => self.v.fetch_update(set_order, fetch_order, f),
                }
            }

            pub fn fetch_or(&self, val: $prim, order: Ordering) -> $prim {
                match self.rmw(&mut |cur| Some(cur | val)) {
                    Some(Ok(prev)) => prev,
                    Some(Err(_)) => unreachable!("fetch_or rmw cannot fail"),
                    None => self.v.fetch_or(val, order),
                }
            }

            pub fn fetch_and(&self, val: $prim, order: Ordering) -> $prim {
                match self.rmw(&mut |cur| Some(cur & val)) {
                    Some(Ok(prev)) => prev,
                    Some(Err(_)) => unreachable!("fetch_and rmw cannot fail"),
                    None => self.v.fetch_and(val, order),
                }
            }
        }

        impl Default for $name {
            fn default() -> $name {
                $name::new(<$prim>::default())
            }
        }

        impl From<$prim> for $name {
            fn from(v: $prim) -> $name {
                $name::new(v)
            }
        }

        impl fmt::Debug for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                fmt::Debug::fmt(&self.v.load(Ordering::SeqCst), f)
            }
        }
    };
}

/// Arithmetic ops, valid for the integer atomics only.
macro_rules! model_atomic_arith {
    ($name:ident, $prim:ty) => {
        impl $name {
            pub fn fetch_add(&self, val: $prim, order: Ordering) -> $prim {
                match self.rmw(&mut |cur| Some(cur.wrapping_add(val))) {
                    Some(Ok(prev)) => prev,
                    Some(Err(_)) => unreachable!("fetch_add rmw cannot fail"),
                    None => self.v.fetch_add(val, order),
                }
            }

            pub fn fetch_sub(&self, val: $prim, order: Ordering) -> $prim {
                match self.rmw(&mut |cur| Some(cur.wrapping_sub(val))) {
                    Some(Ok(prev)) => prev,
                    Some(Err(_)) => unreachable!("fetch_sub rmw cannot fail"),
                    None => self.v.fetch_sub(val, order),
                }
            }

            pub fn fetch_max(&self, val: $prim, order: Ordering) -> $prim {
                match self.rmw(&mut |cur| Some(cur.max(val))) {
                    Some(Ok(prev)) => prev,
                    Some(Err(_)) => unreachable!("fetch_max rmw cannot fail"),
                    None => self.v.fetch_max(val, order),
                }
            }

            pub fn fetch_min(&self, val: $prim, order: Ordering) -> $prim {
                match self.rmw(&mut |cur| Some(cur.min(val))) {
                    Some(Ok(prev)) => prev,
                    Some(Err(_)) => unreachable!("fetch_min rmw cannot fail"),
                    None => self.v.fetch_min(val, order),
                }
            }
        }
    };
}

model_atomic_core!(AtomicU64, u64, StdAtomicU64, u64_ident, u64_ident);
model_atomic_core!(AtomicUsize, usize, StdAtomicUsize, usize_into, usize_from);
model_atomic_core!(AtomicU32, u32, StdAtomicU32, u32_into, u32_from);
model_atomic_core!(AtomicBool, bool, StdAtomicBool, bool_into, bool_from);

model_atomic_arith!(AtomicU64, u64);
model_atomic_arith!(AtomicUsize, usize);
model_atomic_arith!(AtomicU32, u32);
