//! Offline stub of the `xla` PJRT bindings.
//!
//! The container this repo grows in has no XLA install, so the real
//! bindings cannot link. This stub exposes the exact API surface
//! `fp_xint::runtime` uses:
//!
//! * [`Literal`] is fully functional (host-side f32 arrays): create,
//!   reshape, read back — the marshalling layer round-trips for real.
//! * HLO parsing / compilation / execution ([`HloModuleProto`],
//!   [`PjRtClient::compile`], [`PjRtLoadedExecutable::execute`]) return
//!   a descriptive [`Error`] — callers degrade exactly as they would on
//!   a host with a broken accelerator runtime.
//!
//! Swap the `xla` path dependency in `rust/Cargo.toml` for the real
//! crate to execute the AOT artifacts; no fp_xint source changes needed.

use std::path::Path;

/// Stub error: carries the operation that is unavailable offline.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(op: &str) -> Error {
        Error(format!("{op} unavailable: offline xla stub (no PJRT/XLA install)"))
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Dense host-side f32 array with a shape — functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    /// Reshape to `dims`; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Read the elements back; the stub stores f32 only.
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from(v)).collect())
    }

    /// Decompose a tuple literal — never constructed by the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("tuple literals"))
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module — parsing is unavailable offline.
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error(format!(
            "parse {:?} unavailable: offline xla stub (no HLO parser)",
            path.as_ref()
        )))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle — never produced by the stub.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("buffer readback"))
    }
}

/// Compiled executable — never produced by the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execution"))
    }
}

/// PJRT client. Construction succeeds (so runtimes boot and report a
/// platform); compilation fails with a descriptive error.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("HLO compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn unavailable_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        assert!(client.compile(&comp).is_err());
        assert!(PjRtBuffer(()).to_literal_sync().is_err());
    }
}
