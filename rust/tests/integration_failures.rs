//! Failure injection: the coordinator/runtime must degrade cleanly, not
//! wedge — worker panics, failing workers, corrupt artifacts/manifests,
//! overload shedding, and malformed wire traffic.

use fp_xint::coordinator::{
    BasisWorker, BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool,
};
use fp_xint::runtime::Manifest;
use fp_xint::serve::server::serve_tcp;
use fp_xint::tensor::Tensor;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

struct FlakyWorker {
    calls: Arc<AtomicUsize>,
    fail_every: usize,
}

impl BasisWorker for FlakyWorker {
    fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if self.fail_every > 0 && n % self.fail_every == self.fail_every - 1 {
            anyhow::bail!("injected failure #{n}");
        }
        Ok(x.clone())
    }
}

#[test]
fn failing_batches_are_reported_not_hung() {
    let calls = Arc::new(AtomicUsize::new(0));
    let c2 = calls.clone();
    let pool = WorkerPool::new(
        1,
        Arc::new(move |_| {
            Box::new(FlakyWorker { calls: c2.clone(), fail_every: 2 }) as Box<dyn BasisWorker>
        }),
    );
    let coord = Coordinator::new(
        BatcherConfig::uniform(1, 100, 16),
        ExpansionScheduler::new(pool),
    );
    let mut ok = 0;
    let mut failed = 0;
    for _ in 0..10 {
        let rx = coord.submit(Tensor::zeros(&[1, 4])).unwrap();
        // a failed batch sends an explicit error reply — never a hang,
        // never a silently dropped channel
        match rx.recv_timeout(std::time::Duration::from_secs(10)) {
            Ok(resp) if resp.error.is_none() => ok += 1,
            Ok(resp) => {
                assert!(
                    resp.error.unwrap().contains("injected failure"),
                    "error reply must carry the cause"
                );
                failed += 1;
            }
            Err(e) => panic!("reply channel must not drop: {e:?}"),
        }
    }
    assert!(ok > 0, "some requests must succeed");
    assert!(failed > 0, "injected failures must surface");
    assert_eq!(coord.metrics.completed() as usize, ok);
    assert_eq!(coord.metrics.failed() as usize, failed);
    coord.shutdown();
}

#[test]
fn panicking_worker_becomes_an_error_not_a_deadlock() {
    struct Panicker;
    impl BasisWorker for Panicker {
        fn run(&mut self, _x: &Tensor) -> anyhow::Result<Tensor> {
            panic!("worker exploded");
        }
    }
    let pool = WorkerPool::new(1, Arc::new(|_| Box::new(Panicker) as Box<dyn BasisWorker>));
    // the panic kills the worker thread; broadcast must error (the reply
    // channel drops), not block forever
    let res = std::thread::spawn(move || pool.broadcast(Tensor::zeros(&[1, 1])))
        .join()
        .expect("harness thread");
    assert!(res.is_err(), "dead worker must surface as an error");
}

#[test]
fn corrupt_manifest_is_rejected_with_context() {
    let dir = std::env::temp_dir().join(format!("fpx_manifest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), b"{not json").unwrap();
    let err = Manifest::load(dir.join("manifest.json")).unwrap_err();
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn manifest_missing_fields_rejected() {
    assert!(Manifest::parse("{}").is_err());
    assert!(Manifest::parse(r#"{"din": 1, "hidden": 2}"#).is_err());
}

#[test]
fn corrupt_hlo_artifact_fails_compile_not_process() {
    let dir = std::env::temp_dir().join(format!("fpx_hlo_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), b"HloModule nonsense garbage {{{").unwrap();
    let mut rt = fp_xint::runtime::Runtime::cpu(&dir).unwrap();
    assert!(rt.load("bad.hlo.txt").is_err());
    assert!(rt.load("missing.hlo.txt").is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn overload_sheds_instead_of_oom() {
    struct Slow;
    impl BasisWorker for Slow {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            std::thread::sleep(std::time::Duration::from_millis(50));
            Ok(x.clone())
        }
    }
    let pool = WorkerPool::new(1, Arc::new(|_| Box::new(Slow) as Box<dyn BasisWorker>));
    let coord = Coordinator::new(
        BatcherConfig::uniform(1, 10, 4),
        ExpansionScheduler::new(pool),
    );
    let mut shed = 0;
    let mut accepted = Vec::new();
    for _ in 0..64 {
        match coord.submit(Tensor::zeros(&[1, 2])) {
            Ok(rx) => accepted.push(rx),
            Err(fp_xint::coordinator::SubmitError::Busy(t)) => {
                assert_eq!(t, fp_xint::qos::Tier::Exact, "shed reason names the tier");
                shed += 1;
            }
            Err(e) => panic!("{e:?}"),
        }
    }
    assert!(shed >= 48, "bounded queue must shed most of a 64-burst: shed {shed}");
    for rx in accepted {
        assert!(rx.recv_timeout(std::time::Duration::from_secs(15)).is_ok());
    }
    coord.shutdown();
}

#[test]
fn tcp_garbage_header_closes_cleanly() {
    struct Echo;
    impl BasisWorker for Echo {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.clone())
        }
    }
    let pool = WorkerPool::new(1, Arc::new(|_| Box::new(Echo) as Box<dyn BasisWorker>));
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::default(),
        ExpansionScheduler::new(pool),
    ));
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
    // absurd n·d (> the 16M element guard) must be refused
    let mut s = std::net::TcpStream::connect(handle.addr).unwrap();
    s.write_all(&u32::MAX.to_le_bytes()).unwrap();
    s.write_all(&u32::MAX.to_le_bytes()).unwrap();
    let mut reply = [0u8; 8];
    s.read_exact(&mut reply).unwrap();
    assert_eq!(u32::from_le_bytes(reply[0..4].try_into().unwrap()), 0);
    assert_eq!(
        u32::from_le_bytes(reply[4..8].try_into().unwrap()),
        fp_xint::serve::server::CODE_MALFORMED,
        "oversized request must be rejected as malformed"
    );
    // server still serves normal traffic afterwards
    let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
    let y = fp_xint::serve::server::client_infer(handle.addr, &x).unwrap();
    assert_eq!(y.data(), x.data());
    handle.stop();
}

#[test]
fn checkpoint_truncation_detected() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("fpx_trunc_{}.fpxw", std::process::id()));
    let mut m = fp_xint::models::zoo::mlp(16, &[8], 2, 1);
    fp_xint::models::serialize::save_model(&path, &mut m).unwrap();
    // truncate the file
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let mut m2 = fp_xint::models::zoo::mlp(16, &[8], 2, 2);
    assert!(fp_xint::models::serialize::load_model(&path, &mut m2).is_err());
    std::fs::remove_file(path).ok();
}
