//! Integration: coordinator + runtime + serve — PJRT-backed basis workers
//! must agree with native basis workers, survive concurrent load, and the
//! AllReduce must be order-invariant end to end.

use fp_xint::coordinator::{BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool};
use fp_xint::serve::server::{client_infer, serve_tcp};
use fp_xint::serve::workers::{mlp_basis_factory, pjrt_mlp_basis_factory, MlpWeights};
use fp_xint::tensor::{Rng, Tensor};
use std::sync::Arc;

fn weights(seed: u64) -> MlpWeights {
    // geometry must match the AOT manifest (256 → 64 → 10)
    let mut rng = Rng::seed(seed);
    MlpWeights {
        w1: Tensor::randn(&[64, 256], 0.3, &mut rng),
        b1: Tensor::randn(&[64], 0.1, &mut rng),
        w2: Tensor::randn(&[10, 64], 0.3, &mut rng),
        b2: Tensor::randn(&[10], 0.1, &mut rng),
    }
}

fn artifacts_ready() -> bool {
    fp_xint::runtime::Runtime::default_artifact_dir().join("manifest.json").exists()
}

#[test]
fn pjrt_and_native_basis_workers_agree() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let w = weights(91);
    let terms = 2;
    let mut rng = Rng::seed(92);
    let x = Tensor::randn(&[4, 256], 1.0, &mut rng);

    let native = ExpansionScheduler::new(WorkerPool::new(terms, mlp_basis_factory(&w, 4, terms)));
    let y_native = native.forward(x.clone()).unwrap();
    native.shutdown();

    let dir = fp_xint::runtime::Runtime::default_artifact_dir();
    let pjrt = ExpansionScheduler::new(WorkerPool::new(
        terms,
        pjrt_mlp_basis_factory(dir, &w, 4, terms),
    ));
    let y_pjrt = pjrt.forward(x).unwrap();
    pjrt.shutdown();

    assert_eq!(y_native.dims(), y_pjrt.dims());
    let rel = y_native.sub(&y_pjrt).norm() / y_native.norm();
    // both compute single-plane basis slices with one-step activation
    // quantization; small numeric differences come from scale estimation
    // (native uses per-channel max, kernel uses per-tensor max)
    assert!(rel < 0.25, "native vs PJRT basis drift: rel {rel}");
}

#[test]
fn coordinator_survives_concurrent_tcp_load() {
    let w = weights(93);
    let pool = WorkerPool::new(3, mlp_basis_factory(&w, 8, 3));
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(16, 500, 256),
        ExpansionScheduler::new(pool),
    ));
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
    let addr = handle.addr;
    let threads: Vec<_> = (0..6)
        .map(|t| {
            std::thread::spawn(move || {
                let mut rng = Rng::seed(1000 + t);
                for _ in 0..10 {
                    let x = Tensor::randn(&[1 + (t as usize % 3), 256], 1.0, &mut rng);
                    let y = client_infer(addr, &x).unwrap();
                    assert_eq!(y.dims()[0], x.dims()[0]);
                    assert_eq!(y.dims()[1], 10);
                    assert!(y.data().iter().all(|v| v.is_finite()));
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(coord.metrics.completed(), 60);
    assert_eq!(coord.metrics.failed(), 0);
    handle.stop();
}

#[test]
fn allreduce_invariant_to_worker_permutation() {
    // two pools with permuted slice order must produce identical sums
    let w = weights(94);
    let terms = 4;
    let mut rng = Rng::seed(95);
    let x = Tensor::randn(&[3, 256], 1.0, &mut rng);

    let fwd = ExpansionScheduler::new(WorkerPool::new(terms, mlp_basis_factory(&w, 4, terms)));
    let y1 = fwd.forward(x.clone()).unwrap();
    fwd.shutdown();

    // permuted: wrap the factory to reverse worker indices
    let base = mlp_basis_factory(&w, 4, terms);
    let rev: fp_xint::coordinator::pool::WorkerFactory =
        Arc::new(move |i: usize| base(terms - 1 - i));
    let bwd = ExpansionScheduler::new(WorkerPool::new(terms, rev));
    let y2 = bwd.forward(x).unwrap();
    bwd.shutdown();

    let rel = y1.sub(&y2).norm() / y1.norm().max(1e-9);
    assert!(rel < 1e-5, "AbelianAdd must commute: rel {rel}");
}

#[test]
fn batcher_latency_accounting_sane() {
    let w = weights(96);
    let pool = WorkerPool::new(2, mlp_basis_factory(&w, 8, 2));
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(8, 2_000, 64),
        ExpansionScheduler::new(pool),
    ));
    let mut rng = Rng::seed(97);
    for _ in 0..5 {
        let x = Tensor::randn(&[2, 256], 1.0, &mut rng);
        let resp = coord.infer(x).unwrap();
        assert!(resp.latency_s >= 0.0 && resp.latency_s < 5.0);
    }
    let s = coord.metrics.latency_summary();
    assert_eq!(s.n, 5);
    assert!(coord.metrics.mean_batch_size() >= 1.0);
}
