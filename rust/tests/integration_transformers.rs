//! Integration: transformer stack (TinyBert / TinyLm) through training
//! and quantization — the Table 4 / Table 6 pipelines end to end at a
//! small budget.

use fp_xint::datasets::charlm::CharLmTask;
use fp_xint::datasets::textgen::EntailTask;
use fp_xint::models::tinybert::{quantized_copy, BertHead, TinyBert};
use fp_xint::models::TinyLm;
use fp_xint::train::{train_bert, train_lm, TrainConfig};
use fp_xint::xint::layer::LayerPolicy;
use std::sync::OnceLock;

const SEQ: usize = 20;

static BERT: OnceLock<(TinyBert, EntailTask)> = OnceLock::new();

fn bert() -> &'static (TinyBert, EntailTask) {
    BERT.get_or_init(|| {
        let task = EntailTask::new(SEQ, 15);
        let mut m = TinyBert::new(32, 24, 48, 2, SEQ, BertHead::Cls { classes: 3 }, 16);
        let cfg = TrainConfig { steps: 600, batch: 32, lr: 0.04, log_every: 1_000 };
        train_bert(
            &mut m,
            |step| {
                let b = task.batch(32, 500 + step as u64);
                (b.iter().map(|e| e.tokens.clone()).collect(), b.iter().map(|e| e.label).collect())
            },
            &cfg,
        );
        (m, task)
    })
}

fn entail_acc(m: &TinyBert, task: &EntailTask) -> f64 {
    let b = task.batch(200, 2);
    let logits = m.forward(&b.iter().map(|e| e.tokens.clone()).collect::<Vec<_>>());
    let pred = logits.argmax_rows();
    pred.iter().zip(&b).filter(|(p, e)| **p == e.label).count() as f64 / b.len() as f64
}

#[test]
fn bert_learns_entailment_above_chance() {
    let (m, task) = bert();
    let acc = entail_acc(m, task);
    assert!(acc > 0.55, "entail acc {acc:.3} (chance 0.33)");
}

#[test]
fn bert_w8_quantization_preserves_accuracy() {
    let (m, task) = bert();
    let fp = entail_acc(m, task);
    let mut q = quantized_copy(m, &LayerPolicy::new(8, 8).with_terms(2, 1));
    q.act_quant = Some((8, 1));
    let qa = entail_acc(&q, task);
    assert!(qa >= fp - 0.05, "W8A8 {qa:.3} vs FP {fp:.3}");
}

#[test]
fn bert_series_beats_single_term_at_w4a4() {
    let (m, task) = bert();
    let mut naive = quantized_copy(m, &LayerPolicy::new(4, 4).with_terms(1, 1));
    naive.act_quant = Some((4, 1));
    let mut ours = quantized_copy(m, &LayerPolicy::new(4, 4).with_terms(2, 1));
    ours.act_quant = Some((4, 4));
    let a_naive = entail_acc(&naive, task);
    let a_ours = entail_acc(&ours, task);
    assert!(
        a_ours >= a_naive - 0.02,
        "series W4A4 {a_ours:.3} must not meaningfully lose to naive {a_naive:.3}"
    );
}

#[test]
fn lm_trains_and_w4_series_tracks_fp_answers() {
    let task = CharLmTask::new(21);
    let stream = task.tokens();
    let mut lm = TinyLm::new(16, 32, 1, 24, 22);
    let cfg = TrainConfig { steps: 150, batch: 8, lr: 0.08, log_every: 1_000 };
    let rep = train_lm(&mut lm, &stream, &cfg);
    let first = rep.loss_curve.first().unwrap().1;
    let last = rep.loss_curve.last().unwrap().1;
    assert!(last < first, "LM loss {first} -> {last}");
    // W4 series answers must agree with FP answers on most questions
    let mut q = lm.clone();
    q.quantize_weights(&LayerPolicy::new(4, 16).with_terms(2, 1));
    let qs = task.questions();
    let agree = qs.iter().filter(|question| lm.answer(question) == q.answer(question)).count();
    assert!(
        agree as f64 / qs.len() as f64 > 0.7,
        "W4 series only agrees on {agree}/{} answers",
        qs.len()
    );
}
