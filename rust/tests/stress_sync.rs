//! Sanitizer stress harness for the lock-free core. These are not
//! correctness proofs — the `loom_model_*` tests are — they are the
//! *data-race* oracle: run under ThreadSanitizer (`ci.yml` job `tsan`)
//! they hammer the seqlock ring and the controller's CAS paths with
//! real OS-thread contention so any unsynchronized access the models
//! abstracted away shows up as a TSan report. They also pass as plain
//! tests (tier-1 `--all-targets` compiles and runs them), just with
//! weaker guarantees.
//!
//! Keep iteration counts modest: TSan is ~10x slower and the CI job
//! runs with `--test-threads=1` so the races are the ones we stage,
//! not scheduler noise between test cases.

use fp_xint::obs::{SpanKind, TraceEvent, TraceRecorder};
use fp_xint::qos::{QosConfig, TermController, Tier};
use fp_xint::tensor::{IntTensor, Rng};
use fp_xint::xint::kernel::{self, GridRun, Kernel, KernelPool, PackedPlane};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Redundancy-encode an event off its trace id so a torn snapshot is
/// detectable no matter which field tore (same scheme as the loom
/// models in `obs::recorder`).
fn encoded(id: u64) -> TraceEvent {
    TraceEvent {
        trace_id: id,
        span: SpanKind::WorkerTerm,
        tier: Tier::Balanced,
        error: false,
        t_start_ns: id,
        t_end_ns: id + 1,
        detail: [id, id, id],
    }
}

fn assert_untorn(e: &TraceEvent) {
    assert!(e.trace_id >= 1, "phantom event surfaced: {e:?}");
    assert_eq!(e.t_start_ns, e.trace_id, "torn snapshot accepted: {e:?}");
    assert_eq!(e.t_end_ns, e.trace_id + 1, "torn snapshot accepted: {e:?}");
    assert_eq!(e.detail, [e.trace_id; 3], "torn snapshot accepted: {e:?}");
    assert_eq!(e.span, SpanKind::WorkerTerm);
    assert_eq!(e.tier, Tier::Balanced);
}

/// N writers race the ring while a dedicated reader snapshots in a
/// tight loop until every writer has finished. The reader must only
/// ever surface whole events; the counters must be exact afterwards.
#[test]
#[cfg_attr(miri, ignore)] // real-thread stress; minutes under miri
fn seqlock_ring_survives_writer_reader_stress() {
    const WRITERS: u64 = 4;
    const PER_WRITER: u64 = 2_000;
    // Capacity far above writer concurrency — the documented envelope
    // for single-writer slot ownership (see obs::recorder docs).
    let rec = Arc::new(TraceRecorder::new(1024));
    let stop = Arc::new(AtomicBool::new(false));

    let reader = {
        let rec = Arc::clone(&rec);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut snapshots = 0u64;
            while !stop.load(Ordering::Acquire) {
                for e in rec.events() {
                    assert_untorn(&e);
                }
                snapshots += 1;
            }
            snapshots
        })
    };

    let writers: Vec<_> = (0..WRITERS)
        .map(|w| {
            let rec = Arc::clone(&rec);
            std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    // ids start at 1: 0 is the never-written sentinel
                    rec.record(encoded(1 + w * PER_WRITER + i));
                }
            })
        })
        .collect();
    for h in writers {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    let snapshots = reader.join().unwrap();
    assert!(snapshots >= 1, "reader never snapshotted");

    assert_eq!(rec.recorded(), WRITERS * PER_WRITER);
    assert_eq!(rec.dropped(), WRITERS * PER_WRITER - 1024);
    let evs = rec.events();
    assert_eq!(evs.len(), 1024, "quiescent ring must be fully stable");
    for e in &evs {
        assert_untorn(e);
    }
}

/// Concurrent `record_latency` vs. `take_tier_p99`: samples may land in
/// the pre- or post-take window but are never duplicated, invented, or
/// (once quiescent) lost beyond the digest's documented one-window lag.
#[test]
#[cfg_attr(miri, ignore)]
fn latency_digest_is_exact_under_contention() {
    const WRITERS: usize = 4;
    const PER_WRITER: usize = 500;
    let cfg = QosConfig::new(8).with_slo_target(Tier::Balanced, 1.0);
    let ctl = Arc::new(TermController::new(cfg));

    let writers: Vec<_> = (0..WRITERS)
        .map(|_| {
            let ctl = Arc::clone(&ctl);
            std::thread::spawn(move || {
                for _ in 0..PER_WRITER {
                    ctl.record_latency(Tier::Balanced, 5.0);
                }
            })
        })
        .collect();
    // Race the consumer against the writers. Every written sample is
    // 5.0 and unwritten slots read as the 0.0 init (the documented
    // claimed-but-unwritten staleness), so a surfaced percentile must
    // stay inside the hull of those two — anything else is fabricated.
    for _ in 0..200 {
        if let Some(p) = ctl.take_tier_p99(Tier::Balanced) {
            assert!((0.0..=5.0).contains(&p), "digest fabricated a sample: {p}");
        }
    }
    for h in writers {
        h.join().unwrap();
    }
    // Quiescent: one more take drains anything still buffered; a second
    // take must then see an empty window (no sample is surfaced twice).
    let _ = ctl.take_tier_p99(Tier::Balanced);
    assert_eq!(ctl.take_tier_p99(Tier::Balanced), None, "window consumed twice");
}

/// Concurrent grid runs race one kernel pool: several driver threads
/// hammer `execute_parallel_with` on the same workers, so the block
/// claim cursor, the task channels, and the result handoff all see real
/// cross-job contention. Every run must still come back bit-identical
/// to the sequential execution — a lost or doubled block shows up as a
/// wrong row, an unsynchronized payload as a TSan report.
#[test]
#[cfg_attr(miri, ignore)]
fn kernel_pool_grid_runs_exact_under_contention() {
    const DRIVERS: usize = 3;
    const PER_DRIVER: usize = 20;
    let (m, n, k) = (48usize, 16usize, 64usize);
    let mut rng = Rng::seed(90);
    let plane = |rng: &mut Rng, rows: usize| {
        let vals: Vec<i32> = (0..rows * k).map(|_| rng.below(255) as i32 - 127).collect();
        Arc::new(PackedPlane::pack(&IntTensor::from_vec(&[rows, k], vals)).unwrap())
    };
    let w_planes: Vec<_> = (0..2).map(|_| plane(&mut rng, n)).collect();
    let a_planes: Vec<_> = (0..2).map(|_| plane(&mut rng, m)).collect();
    let w_scales: Vec<Arc<Vec<f32>>> =
        (0..2).map(|_| Arc::new((0..n).map(|_| rng.uniform(0.01, 1.0)).collect())).collect();
    let a_scales: Vec<f32> = (0..2).map(|_| rng.uniform(0.01, 1.0)).collect();
    let run = Arc::new(GridRun::new(
        w_planes,
        w_scales,
        a_planes,
        a_scales,
        vec![(0, 0), (0, 1), (1, 0), (1, 1)],
    ));
    let mut y_seq = vec![0.0f32; m * n];
    kernel::execute(&run, Kernel::Portable, &mut y_seq);
    let y_seq = Arc::new(y_seq);

    let pool = Arc::new(KernelPool::new(3));
    let drivers: Vec<_> = (0..DRIVERS)
        .map(|_| {
            let pool = Arc::clone(&pool);
            let run = Arc::clone(&run);
            let y_seq = Arc::clone(&y_seq);
            std::thread::spawn(move || {
                for it in 0..PER_DRIVER {
                    let mut y = vec![0.0f32; run.m * run.n];
                    kernel::execute_parallel_with(&pool, &run, Kernel::Portable, &mut y);
                    assert_eq!(y, *y_seq, "iteration {it} diverged");
                }
            })
        })
        .collect();
    for h in drivers {
        h.join().unwrap();
    }
}

/// Producer threads race the reactor's completion queue and wake latch
/// through a real pipe-backed waker while a consumer drains in the
/// clear-then-drain order the reactor thread uses. Every pushed
/// completion must surface exactly once — a lost wake or a dropped item
/// shows up as the deadline firing, a racy handoff as a TSan report.
#[test]
#[cfg_attr(miri, ignore)]
fn wake_queue_handoff_loses_nothing_under_contention() {
    use fp_xint::serve::reactor::{WakeQueue, Waker};
    const PRODUCERS: u64 = 4;
    const PER_PRODUCER: u64 = 5_000;
    let (waker, mut rx) = Waker::pair().expect("waker pipe");
    let waker = Arc::new(waker);
    let q = Arc::new(WakeQueue::new());
    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = Arc::clone(&q);
            let waker = Arc::clone(&waker);
            std::thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    // push-then-signal, exactly the scheduler-side order
                    if q.push(p * PER_PRODUCER + i) {
                        waker.signal();
                    }
                }
            })
        })
        .collect();
    let total = (PRODUCERS * PER_PRODUCER) as usize;
    let mut seen = vec![false; total];
    let mut got = 0usize;
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    while got < total {
        assert!(
            std::time::Instant::now() < deadline,
            "handoff stranded {} completions",
            total - got
        );
        // reactor order: drain the wake pipe BEFORE the queue, so a
        // push racing this drain re-arms the latch and signals again
        rx.clear();
        for v in q.drain() {
            let idx = v as usize;
            assert!(!seen[idx], "completion {v} delivered twice");
            seen[idx] = true;
            got += 1;
        }
    }
    for h in producers {
        h.join().unwrap();
    }
    assert!(q.drain().is_empty(), "items appeared after all producers finished");
}

/// Concurrent `observe_batch` EWMA updates: the CAS loop must not lose
/// or fabricate samples — the final EWMA is reachable by *some*
/// serialization of the observed occupancies, all of which are 0.5
/// here, so the EWMA must stay inside the closed interval the samples
/// span.
#[test]
#[cfg_attr(miri, ignore)]
fn ewma_cas_converges_under_contention() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 200;
    let ctl = Arc::new(TermController::new(QosConfig::new(8)));

    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let ctl = Arc::clone(&ctl);
            std::thread::spawn(move || {
                for _ in 0..PER_THREAD {
                    ctl.observe_batch(Tier::Throughput, 0.5, Some(2.0), None);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let ewma = ctl.tier_service_ewma(Tier::Throughput).expect("samples were recorded");
    // All samples equal 2.0, so any serialization of the CAS updates
    // blends 2.0 into 2.0: the fixed point is exact.
    assert_eq!(ewma, 2.0, "EWMA drifted off the unique fixed point");
    // Occupancy 0.5 sits between the default watermarks: no pressure.
    assert_eq!(ctl.pressure(), 0);
}
