//! Integration + property tests for the QoS control plane: the Abelian
//! prefix-truncation algebra (⊎ prefix sums are valid group elements,
//! order-invariant), monotone precision in the term budget, and the
//! end-to-end degrade-instead-of-shed behavior.

use fp_xint::coordinator::{
    BasisWorker, BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool,
};
use fp_xint::models::quantized::quantize_model;
use fp_xint::models::zoo;
use fp_xint::qos::{QosConfig, TermController, Tier};
use fp_xint::serve::server::{client_infer_tier, serve_tcp};
use fp_xint::serve::workers::{mlp_basis_factory_with, BiasPlacement, MlpWeights, QuantModelWorker};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::util::prop::{forall, no_shrink, PropConfig};
use fp_xint::xint::abelian::abelian_reduce;
use fp_xint::xint::layer::LayerPolicy;
use fp_xint::xint::planner::BudgetPlanner;
use fp_xint::xint::{
    BitSpec, BudgetPlan, ExpandConfig, ExpansionMonitor, SeriesExpansion, TermBudget,
};
use std::sync::Arc;

fn close(a: &Tensor, b: &Tensor, tol: f32) -> Result<(), String> {
    if a.dims() != b.dims() {
        return Err(format!("dims {:?} vs {:?}", a.dims(), b.dims()));
    }
    for (x, y) in a.data().iter().zip(b.data()) {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("{x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn property_prefix_reduction_matches_sequential_sum_any_order() {
    // ⊎ over any prefix of the gained basis outputs, in any order,
    // equals the sequential left-fold — the algebra the scheduler's
    // truncated broadcast relies on
    forall(
        PropConfig { cases: 30, seed: 0xA11CE, max_shrink: 0 },
        |r| {
            let k = 2 + r.below(6);
            let rows = 1 + r.below(4);
            let cols = 1 + r.below(6);
            let mut rng = r.fork(3);
            let outs: Vec<Tensor> =
                (0..k).map(|_| Tensor::randn(&[rows, cols], 1.0, &mut rng)).collect();
            let prefix = 1 + rng.below(k);
            (outs, prefix, rng.next_u64())
        },
        no_shrink,
        |(outs, prefix, perm_seed)| {
            let head: Vec<Tensor> = outs[..*prefix].to_vec();
            // sequential left fold
            let mut seq = Tensor::zeros(head[0].dims());
            for o in &head {
                seq = seq.add(o);
            }
            let tree = abelian_reduce(head.clone()).expect("nonempty");
            close(&tree, &seq, 1e-5)?;
            // any reordering of the prefix reduces to the same element
            let mut shuffled = head;
            Rng::seed(*perm_seed).shuffle(&mut shuffled);
            let permuted = abelian_reduce(shuffled).expect("nonempty");
            close(&permuted, &seq, 1e-5)
        },
    );
}

#[test]
fn property_more_terms_no_worse_max_residual() {
    // tier budgets degrade monotonically: a larger term budget can
    // never reconstruct worse (up to f32 rounding noise)
    forall(
        PropConfig { cases: 30, seed: 0xB0B, max_shrink: 0 },
        |r| {
            let rows = 1 + r.below(8);
            let cols = 1 + r.below(24);
            let bits = [2u32, 3, 4, 8][r.below(4)];
            let terms = 2 + r.below(5);
            let scale = 10f32.powi(r.below(4) as i32 - 1);
            let mut rng = r.fork(7);
            (Tensor::randn(&[rows, cols], scale, &mut rng), bits, terms)
        },
        no_shrink,
        |(m, bits, terms)| {
            let cfg = ExpandConfig::symmetric(BitSpec::int(*bits), *terms);
            let e = SeriesExpansion::expand(m, &cfg);
            let mut prev = f32::INFINITY;
            for t in 1..=*terms {
                let resid = m.sub(&e.reconstruct_terms(t)).max_abs();
                let slack = 1e-6 * (1.0 + m.max_abs());
                if resid > prev + slack {
                    return Err(format!("terms {t}: residual {resid} > {prev}"));
                }
                prev = resid;
            }
            Ok(())
        },
    );
}

#[test]
fn monitor_calibrated_budgets_are_monotone_across_tiers() {
    let mut mon = ExpansionMonitor::new();
    let cfg = ExpandConfig::symmetric(BitSpec::int(4), 8);
    let mut rng = Rng::seed(0xCAFE);
    for _ in 0..3 {
        mon.observe(&Tensor::randn(&[16, 64], 1.0, &mut rng), &cfg).unwrap();
    }
    let ctl = TermController::new(QosConfig::new(8));
    ctl.calibrate(&mon);
    let budgets: Vec<usize> = Tier::ALL.iter().map(|&t| ctl.budget_for(t)).collect();
    assert!(budgets.windows(2).all(|w| w[1] <= w[0]), "{budgets:?}");
    // and the monitor's loss estimate at each budget honors the tolerance
    for tier in [Tier::Balanced, Tier::Throughput, Tier::BestEffort] {
        let b = ctl.budget_for(tier);
        if let (Some(loss), Some(tol)) = (mon.max_diff_at(b), tier.tolerance()) {
            // either within tolerance or already at the full series
            assert!(loss < tol || b == 8, "{tier}: loss {loss} tol {tol} budget {b}");
        }
    }
}

struct Sleepy(std::time::Duration);
impl BasisWorker for Sleepy {
    fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
        std::thread::sleep(self.0);
        Ok(x.clone())
    }
}

#[test]
fn pressure_degrades_then_restores_under_load() {
    // slow workers + burst traffic: the controller must pick up the
    // flooded tier's own queue pressure, serve IT with fewer terms,
    // and restore later — without the flood leaking into other tiers
    let terms = 8;
    // low watermark threshold so the burst reliably crosses it even if
    // the batcher drains a request or two while we are still submitting;
    // SLO targets off so queue occupancy (the channel under test) is
    // the only pressure input regardless of CI host speed
    let mut qcfg = QosConfig::new(terms);
    qcfg.high_watermark = 0.5;
    qcfg.slo_targets = [0.0; 4];
    let ctl = Arc::new(TermController::new(qcfg));
    let pool = WorkerPool::new(
        terms,
        Arc::new(|_| {
            Box::new(Sleepy(std::time::Duration::from_millis(5))) as Box<dyn BasisWorker>
        }),
    );
    // plain (un-Arc'd) coordinator: everything here is single-threaded,
    // and the consuming `shutdown(self)` cannot be called through Arc
    let coord = Coordinator::new(
        BatcherConfig::uniform(1, 100, 16),
        ExpansionScheduler::new(pool).with_controller(ctl.clone()),
    );
    // Balanced burst: fill most of the tier's queue, watch ITS pressure
    // rise (Balanced serves 4 of 8 terms unpressured, 2 at its floor)
    let unpressured = ctl.budget_for(Tier::Balanced);
    assert_eq!(unpressured, 4);
    let mut rxs = Vec::new();
    for _ in 0..15 {
        if let Ok(rx) = coord.submit_tier(Tensor::zeros(&[1, 2]), Tier::Balanced) {
            rxs.push(rx);
        }
    }
    // a Throughput request riding alongside mid-flood keeps its own
    // unpressured default budget (2 of 8 — a FIXED expectation, so a
    // regression back to global pressure fails here instead of moving
    // both sides of the comparison together)
    let rider = coord.infer_tier(Tensor::zeros(&[1, 2]), Tier::Throughput).unwrap();
    assert_eq!(rider.terms, 2, "flood leaked across tiers");
    let mut terms_seen = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(20)).unwrap();
        assert!(resp.error.is_none());
        terms_seen.push(resp.terms);
    }
    assert!(ctl.snapshot().degrade_events > 0, "pressure never rose");
    assert!(
        terms_seen.iter().any(|&t| t < unpressured),
        "no degraded service under pressure: {terms_seen:?}"
    );
    // drain: light traffic at empty queue lowers pressure back to zero
    for _ in 0..20 {
        let _ = coord.infer_tier(Tensor::zeros(&[1, 2]), Tier::Balanced);
    }
    assert_eq!(ctl.pressure(), 0, "pressure must fall once the queue drains");
    let s = ctl.snapshot();
    assert_eq!(s.tier_degrade_events[Tier::Exact.idx()], 0);
    assert_eq!(s.tier_degrade_events[Tier::Throughput.idx()], 0);
    assert_eq!(s.tier_degrade_events[Tier::BestEffort.idx()], 0, "flood coupled across tiers");
    coord.shutdown();
}

#[test]
fn property_no_tier_starves_under_a_sustained_flood() {
    // for every flood tier F: requests of every other tier, submitted
    // while F saturates its own queue, must still complete — the WDRR
    // per-tier queues guarantee each non-empty queue is visited every
    // rotation, so no tier can monopolize service
    use std::sync::atomic::{AtomicBool, Ordering};
    for flood in Tier::ALL {
        let pool = WorkerPool::new(
            2,
            Arc::new(|_| {
                Box::new(Sleepy(std::time::Duration::from_millis(2))) as Box<dyn BasisWorker>
            }),
        );
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(4, 200, 64),
            ExpansionScheduler::new(pool),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let flooder = {
            let coord = coord.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut accepted = Vec::new();
                while !stop.load(Ordering::Relaxed) {
                    match coord.submit_tier(Tensor::zeros(&[1, 2]), flood) {
                        Ok(rx) => accepted.push(rx),
                        Err(_) => std::thread::yield_now(),
                    }
                }
                // flood replies must also all arrive (no tier starves,
                // including the flooding tier itself)
                for rx in accepted {
                    assert!(
                        rx.recv_timeout(std::time::Duration::from_secs(30)).is_ok(),
                        "flood tier {flood} lost a reply"
                    );
                }
            })
        };
        // let the flood saturate its queue, then submit the victims
        std::thread::sleep(std::time::Duration::from_millis(30));
        for tier in Tier::ALL {
            if tier == flood {
                continue;
            }
            let rx = coord
                .submit_tier(Tensor::zeros(&[1, 2]), tier)
                .unwrap_or_else(|e| panic!("{tier} refused during a {flood} flood: {e:?}"));
            let resp = rx
                .recv_timeout(std::time::Duration::from_secs(20))
                .unwrap_or_else(|_| panic!("{tier} starved under a {flood} flood"));
            assert!(resp.error.is_none(), "{tier} errored under a {flood} flood");
        }
        stop.store(true, Ordering::Relaxed);
        flooder.join().unwrap();
    }
}

#[test]
fn replication_mode_budget_flows_tier_to_gemm_grid() {
    // Tier → BudgetPlan end to end in replication mode: the same
    // layer-sync QuantModel serves Exact bit-identically to the direct
    // forward while a BestEffort request executes measurably fewer
    // (i, j) GEMM terms inside the worker.
    let mut rng = Rng::seed(0xF00D);
    let probe = Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng);
    let mut m = zoo::mini_resnet_a(4, 0xBEE);
    let _ = m.forward_train(&probe); // settle BN stats
    let q = quantize_model(&m, LayerPolicy::new(4, 4));
    let x = Tensor::randn(&[2, 1, 16, 16], 1.0, &mut rng);
    let direct = q.forward(&x);
    let (_, full_stats) = q.forward_with(&x, &BudgetPlan::full());

    let qw = q.clone();
    let pool = WorkerPool::new(
        1,
        Arc::new(move |_| {
            Box::new(QuantModelWorker { model: qw.clone(), sample_dims: Some(vec![1, 16, 16]) })
                as Box<dyn BasisWorker>
        }),
    );
    let ctl = Arc::new(TermController::new(QosConfig::new(1)));
    let coord = Coordinator::new(
        BatcherConfig::uniform(4, 200, 16),
        ExpansionScheduler::new(pool).with_controller(ctl.clone()),
    );
    let flat = x.reshape(&[2, 256]);

    let exact = coord.infer_tier(flat.clone(), Tier::Exact).unwrap();
    assert_eq!(exact.logits.data(), direct.data(), "Exact must be bit-identical");
    assert_eq!(exact.grid_terms, full_stats.grid_terms, "Exact runs the full grid");

    let be = coord.infer_tier(flat, Tier::BestEffort).unwrap();
    assert!(
        be.grid_terms < exact.grid_terms,
        "BestEffort must execute fewer GEMM terms: {} !< {}",
        be.grid_terms,
        exact.grid_terms
    );
    assert!(be.grid_terms > 0, "budget metering must reach the worker");
    assert!(be.logits.data().iter().all(|v| v.is_finite()));
    // the per-tier metrics expose the same separation
    assert!(
        coord.metrics.tier_mean_grid_terms(Tier::BestEffort)
            < coord.metrics.tier_mean_grid_terms(Tier::Exact)
    );
    coord.shutdown();
}

#[test]
fn tcp_mixed_tiers_end_to_end() {
    let mut rng = Rng::seed(0xD00D);
    let w = MlpWeights {
        w1: Tensor::randn(&[32, 16], 0.3, &mut rng),
        b1: Tensor::randn(&[32], 0.1, &mut rng),
        w2: Tensor::randn(&[4, 32], 0.3, &mut rng),
        b2: Tensor::randn(&[4], 0.1, &mut rng),
    };
    let terms = 4;
    let mut mon = ExpansionMonitor::new();
    let ecfg = ExpandConfig::symmetric(BitSpec::int(4), terms);
    for _ in 0..3 {
        mon.observe(&Tensor::randn(&[8, 16], 1.0, &mut rng), &ecfg).unwrap();
    }
    let ctl = Arc::new(TermController::new(QosConfig::new(terms)));
    ctl.calibrate(&mon);
    let pool =
        WorkerPool::new(terms, mlp_basis_factory_with(&w, 4, terms, BiasPlacement::FirstTerm));
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(8, 300, 64),
        ExpansionScheduler::new(pool).with_controller(ctl.clone()),
    ));
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
    for tier in Tier::ALL {
        for _ in 0..3 {
            let x = Tensor::randn(&[2, 16], 1.0, &mut rng);
            let y = client_infer_tier(handle.addr, &x, tier).unwrap();
            assert_eq!(y.dims(), &[2, 4]);
            assert!(y.data().iter().all(|v| v.is_finite()));
        }
        assert_eq!(coord.metrics.tier_completed(tier), 3, "{tier}");
    }
    // tier budgets actually shaped the service (no pressure involved)
    let exact_terms = coord.metrics.tier_mean_terms(Tier::Exact);
    let be_terms = coord.metrics.tier_mean_terms(Tier::BestEffort);
    assert!((exact_terms - terms as f64).abs() < 1e-9, "exact got {exact_terms}");
    assert!(be_terms <= exact_terms, "{be_terms} > {exact_terms}");
    assert_eq!(coord.metrics.failed(), 0);
    handle.stop();
}

#[test]
fn property_planned_forward_error_monotone_in_ceiling() {
    // Theorem 1's prefix argument end to end: greedy allocations at
    // growing ceilings are nested (the upgrade order is
    // ceiling-independent), so every layer's executed grid at ceiling
    // c2 > c1 is a superset of its grid at c1 — the budgeted forward's
    // max error vs the full forward must be monotone non-increasing in
    // the plan's total grid ceiling, up to the wiggle nonlinearities
    // can add between adjacent layerwise-better approximations.
    let mut rng = Rng::seed(0x9999);
    let probe = Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng);
    let mut m = zoo::mini_resnet_a(4, 0xABC);
    let _ = m.forward_train(&probe);
    let q = quantize_model(&m, LayerPolicy::new(4, 4));
    let mut mon = ExpansionMonitor::new();
    q.observe_layers(&probe, &mut mon).unwrap();
    let profiles = q.grid_profiles(&mon);
    let x = Tensor::randn(&[2, 1, 16, 16], 1.0, &mut rng);
    let full = q.forward(&x);
    let scale = full.max_abs().max(1e-6);
    let floor = BudgetPlanner::floor_cost(&profiles);
    let max = profiles.iter().filter(|p| !p.exempt).map(|p| p.w_terms * p.a_terms).sum::<usize>();
    assert!(max > floor, "need room between floor and saturation");
    // every interior upgrade costs w_terms = 2, so stepping by 2 visits
    // every distinct plan; always include the saturating ceiling
    let mut ceilings: Vec<usize> = (floor..=max).step_by(2).collect();
    if ceilings.last() != Some(&max) {
        ceilings.push(max);
    }
    let mut errs: Vec<(usize, f32)> = Vec::new();
    let mut prev_spend = 0usize;
    for ceiling in ceilings {
        let plan = BudgetPlanner::new(ceiling).plan(&profiles);
        let (y, stats) = q.forward_with(&x, &plan);
        // nested plans ⇒ executed grids only grow (spend scales with
        // conv batch rows, so compare spend to spend, not to ceiling)
        assert!(stats.grid_terms >= prev_spend, "spend shrank as the ceiling grew");
        prev_spend = stats.grid_terms;
        errs.push((ceiling, full.sub(&y).max_abs() / scale));
    }
    // endpoint: a plan that covers every layer's grid reproduces the
    // full forward bit-for-bit (shared natural-order path)
    let sat_layers: Vec<TermBudget> = profiles
        .iter()
        .map(|p| {
            if p.exempt {
                TermBudget::full()
            } else {
                TermBudget::new(p.w_terms, p.a_terms)
            }
        })
        .collect();
    let sat = BudgetPlan::per_layer(sat_layers, TermBudget::full());
    let (y_sat, _) = q.forward_with(&x, &sat);
    assert_eq!(y_sat.data(), full.data(), "saturated plan must be bit-identical");
    assert!(errs.last().unwrap().1 <= 1e-3, "max-ceiling plan must track the full forward");
    // monotone non-increasing along the nested ceilings, with slack for
    // the nonlinear wiggle (layerwise-better ⇒ output-better only up to
    // ReLU/pool interactions; gross violations mean the plan is ignored)
    for w in errs.windows(2) {
        let ((c1, e1), (c2, e2)) = (w[0], w[1]);
        assert!(
            e2 <= e1 + 0.05 + 0.05 * e1,
            "ceiling {c2} err {e2} regressed past ceiling {c1} err {e1}: {errs:?}"
        );
    }
    // and the trend is real: the floor allocation is measurably worse
    // than the saturated one
    assert!(errs[0].1 > errs.last().unwrap().1, "no error range across ceilings: {errs:?}");
}

#[test]
fn planned_tier_serving_flows_calibration_to_grid_metrics() {
    // calibrate → calibrate_layers → plan_for → QuantModelWorker: a
    // planned non-Exact tier spends fewer grid terms than Exact, the
    // planned ceiling lands in the metrics, and Exact stays
    // bit-identical under per-layer calibration.
    let mut rng = Rng::seed(0x51AB);
    let probe = Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng);
    let mut m = zoo::mini_resnet_a(4, 0xCAB);
    let _ = m.forward_train(&probe);
    let q = quantize_model(&m, LayerPolicy::new(4, 4));
    let x = Tensor::randn(&[2, 1, 16, 16], 1.0, &mut rng);
    let direct = q.forward(&x);

    // per-layer calibration from the quantized model itself
    let mut mon = ExpansionMonitor::new();
    q.observe_layers(&probe, &mut mon).unwrap();
    let profiles = q.grid_profiles(&mon);
    let ctl = Arc::new(TermController::new(QosConfig::new(1)));
    ctl.calibrate_layers(profiles);
    let snap = ctl.snapshot();
    assert!(snap.plan_ceilings[Tier::Throughput.idx()].is_some(), "calibration armed plans");

    let qw = q.clone();
    let pool = WorkerPool::new(
        1,
        Arc::new(move |_| {
            Box::new(QuantModelWorker { model: qw.clone(), sample_dims: Some(vec![1, 16, 16]) })
                as Box<dyn BasisWorker>
        }),
    );
    let coord = Coordinator::new(
        BatcherConfig::uniform(4, 200, 16),
        ExpansionScheduler::new(pool).with_controller(ctl.clone()),
    );
    let flat = x.reshape(&[2, 256]);
    let exact = coord.infer_tier(flat.clone(), Tier::Exact).unwrap();
    assert_eq!(exact.logits.data(), direct.data(), "Exact immune to plan calibration");
    let thr = coord.infer_tier(flat, Tier::Throughput).unwrap();
    assert!(
        thr.grid_terms < exact.grid_terms,
        "planned tier must execute fewer GEMMs: {} !< {}",
        thr.grid_terms,
        exact.grid_terms
    );
    assert!(thr.grid_terms > 0);
    // the planned ceiling is observable per tier, and only there
    assert!(coord.metrics.tier_mean_planned_grid_terms(Tier::Throughput) > 0.0);
    assert_eq!(coord.metrics.tier_mean_planned_grid_terms(Tier::Exact), 0.0);
    coord.shutdown();
}

#[test]
fn throughput_flood_leaves_balanced_and_exact_bit_identical() {
    // the cross-tier coupling regression, end to end in replication
    // mode: a sustained Throughput flood that violates ITS OWN SLO on
    // every batch must ramp only Throughput's pressure — Balanced and
    // Exact keep their planned ceilings, served grid spend, and output
    // bits exactly as in the unloaded run.
    let mut rng = Rng::seed(0x1501);
    let probe = Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng);
    let mut m = zoo::mini_resnet_a(4, 0xFACE);
    let _ = m.forward_train(&probe);
    let q = quantize_model(&m, LayerPolicy::new(4, 4));
    let mut mon = ExpansionMonitor::new();
    q.observe_layers(&probe, &mut mon).unwrap();
    let profiles = q.grid_profiles(&mon);
    // a 1 ns Throughput SLO makes every served Throughput batch a
    // deterministic SLO violation; Balanced/BestEffort latency SLOs are
    // off, so the ONLY channel that could move them is the cross-tier
    // coupling this test pins against (Exact has no SLO by contract)
    let qcfg = QosConfig::new(1)
        .with_slo_target(Tier::Throughput, 1e-9)
        .with_slo_target(Tier::Balanced, 0.0)
        .with_slo_target(Tier::BestEffort, 0.0);
    let ctl = Arc::new(TermController::new(qcfg));
    ctl.calibrate_layers(profiles);
    let qw = q.clone();
    let pool = WorkerPool::new(
        1,
        Arc::new(move |_| {
            Box::new(QuantModelWorker { model: qw.clone(), sample_dims: Some(vec![1, 16, 16]) })
                as Box<dyn BasisWorker>
        }),
    );
    let coord = Coordinator::new(
        BatcherConfig::uniform(4, 200, 64),
        ExpansionScheduler::new(pool).with_controller(ctl.clone()),
    );
    let x = Tensor::randn(&[2, 1, 16, 16], 1.0, &mut rng).reshape(&[2, 256]);

    // unloaded reference service
    let bal_cold = coord.infer_tier(x.clone(), Tier::Balanced).unwrap();
    let exact_cold = coord.infer_tier(x.clone(), Tier::Exact).unwrap();
    let cold = ctl.snapshot();

    // sustained Throughput flood (the forming thread processes batches
    // sequentially, so after request k returns, decisions 1..k-1 have
    // landed — after 6, Throughput's pressure is deterministically up)
    for _ in 0..6 {
        let r = coord.infer_tier(x.clone(), Tier::Throughput).unwrap();
        assert!(r.error.is_none());
    }
    assert!(ctl.tier_pressure(Tier::Throughput) >= 1, "flood never ramped its own tier");
    let hot = ctl.snapshot();
    let ti = Tier::Throughput.idx();
    let bi = Tier::Balanced.idx();
    let ei = Tier::Exact.idx();
    assert!(
        hot.plan_ceilings[ti].unwrap() < cold.plan_ceilings[ti].unwrap(),
        "throughput's own ceiling must shrink: {:?} !< {:?}",
        hot.plan_ceilings[ti],
        cold.plan_ceilings[ti]
    );

    // the acceptance contract: Balanced/Exact are bit-for-bit unmoved
    // while the flooding tier is degraded
    assert_eq!(ctl.tier_pressure(Tier::Balanced), 0);
    assert_eq!(ctl.tier_pressure(Tier::Exact), 0);
    assert_eq!(hot.plan_ceilings[bi], cold.plan_ceilings[bi]);
    assert_eq!(hot.plan_ceilings[ei], cold.plan_ceilings[ei]);
    assert_eq!(hot.budgets[bi], cold.budgets[bi]);
    let bal_hot = coord.infer_tier(x.clone(), Tier::Balanced).unwrap();
    assert_eq!(
        bal_hot.logits.data(),
        bal_cold.logits.data(),
        "balanced output moved under a throughput flood"
    );
    assert_eq!(bal_hot.terms, bal_cold.terms);
    assert_eq!(bal_hot.grid_terms, bal_cold.grid_terms, "balanced grid spend moved");
    let exact_hot = coord.infer_tier(x, Tier::Exact).unwrap();
    assert_eq!(exact_hot.logits.data(), exact_cold.logits.data());
    assert_eq!(exact_hot.grid_terms, exact_cold.grid_terms);
    coord.shutdown();
    let s = ctl.snapshot();
    assert!(s.tier_degrade_events[ti] >= 1);
    assert_eq!(s.tier_degrade_events[Tier::Balanced.idx()], 0);
    assert_eq!(s.tier_degrade_events[Tier::Exact.idx()], 0);
}

#[test]
fn flood_tier_pressure_ramps_and_recovers_without_touching_neighbors() {
    // occupancy-channel twin of the SLO test above: a Throughput queue
    // flood ramps Throughput's pressure, light post-flood traffic fully
    // drains it, and no other tier ever steps
    let terms = 4;
    let mut qcfg = QosConfig::new(terms);
    qcfg.high_watermark = 0.5;
    // occupancy is the only channel under test — latency SLOs off so a
    // slow CI host cannot add steps through the p99 path
    qcfg.slo_targets = [0.0; 4];
    let ctl = Arc::new(TermController::new(qcfg));
    let pool = WorkerPool::new(
        terms,
        Arc::new(|_| {
            Box::new(Sleepy(std::time::Duration::from_millis(4))) as Box<dyn BasisWorker>
        }),
    );
    let coord = Coordinator::new(
        BatcherConfig::uniform(1, 100, 16),
        ExpansionScheduler::new(pool).with_controller(ctl.clone()),
    );
    let mut rxs = Vec::new();
    for _ in 0..15 {
        if let Ok(rx) = coord.submit_tier(Tensor::zeros(&[1, 2]), Tier::Throughput) {
            rxs.push(rx);
        }
    }
    // a Balanced rider mid-flood is served at its full unpressured
    // budget (2 of 4 terms)
    let bal = coord.infer_tier(Tensor::zeros(&[1, 2]), Tier::Balanced).unwrap();
    assert_eq!(bal.terms, 2, "balanced rider degraded by a throughput flood");
    for rx in rxs {
        rx.recv_timeout(std::time::Duration::from_secs(20)).unwrap();
    }
    // drain: light Throughput traffic at an empty queue
    for _ in 0..12 {
        let _ = coord.infer_tier(Tensor::zeros(&[1, 2]), Tier::Throughput);
    }
    coord.shutdown();
    let s = ctl.snapshot();
    let ti = Tier::Throughput.idx();
    assert!(s.tier_degrade_events[ti] > 0, "flood never ramped its own tier");
    assert!(s.tier_restore_events[ti] > 0, "drain never restored");
    assert_eq!(s.pressures[ti], 0, "pressure must fully recover on drain");
    for t in [Tier::Exact, Tier::Balanced, Tier::BestEffort] {
        assert_eq!(s.tier_degrade_events[t.idx()], 0, "{t} coupled to a throughput flood");
        assert_eq!(s.pressures[t.idx()], 0);
    }
}
