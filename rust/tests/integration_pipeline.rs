//! Integration: the full PTQ pipeline across modules — train (train::) →
//! fold BN (models::) → expand (xint::) → evaluate (datasets::), plus the
//! baseline comparators — asserting the orderings the paper's tables rest
//! on rather than point values.

use fp_xint::baselines::{self, PtqMethod};
use fp_xint::datasets::{accuracy, SynthImg};
use fp_xint::models::{quantized, zoo};
use fp_xint::train::{train_classifier, TrainConfig};
use fp_xint::xint::layer::LayerPolicy;
use std::sync::OnceLock;

struct Fixture {
    model: fp_xint::models::Model,
    data: SynthImg,
    fp_acc: f64,
}

static FIX_CELL: OnceLock<Fixture> = OnceLock::new();

fn fix() -> &'static Fixture {
    FIX_CELL.get_or_init(|| {
        let data = SynthImg::new(6, 1, 14, 0.2, 77);
        let mut model = zoo::mini_resnet_a(6, 78);
        let cfg = TrainConfig { steps: 250, batch: 32, lr: 0.05, log_every: 1_000 };
        let rep = train_classifier(&mut model, &data, &cfg);
        Fixture { model, data, fp_acc: rep.final_val_acc }
    })
}

fn ours_acc(w: u32, a: u32, k: usize, t: usize) -> f64 {
    let val = fix().data.batch(384, 2);
    let q = quantized::quantize_model(&fix().model, LayerPolicy::new(w, a).with_terms(k, t));
    accuracy(&q.forward(&val.x), &val.y)
}

#[test]
fn fp_model_is_good_enough_to_quantize() {
    assert!(fix().fp_acc > 0.7, "fixture underfit: {:.2}", fix().fp_acc);
}

#[test]
fn w4a4_series_within_two_points_of_fp() {
    let acc = ours_acc(4, 4, 2, 4);
    assert!(
        acc >= fix().fp_acc - 0.02,
        "W4A4 {:.3} vs FP {:.3}",
        acc,
        fix().fp_acc
    );
}

#[test]
fn series_recovers_what_single_term_loses_at_2bit() {
    let single = ours_acc(2, 2, 1, 1);
    let series = ours_acc(2, 2, 2, 4);
    assert!(
        series >= single,
        "series {series:.3} must not lose to single-term {single:.3}"
    );
    // and series W2A2 stays within 10 points of FP while single-term
    // typically collapses on this fixture
    assert!(series >= fix().fp_acc - 0.10, "series W2A2 {series:.3} vs FP {:.3}", fix().fp_acc);
}

#[test]
fn ours_beats_every_baseline_at_w2a2() {
    let val = fix().data.batch(384, 2);
    let calib = fix().data.batch(32, 3).x;
    let ours = ours_acc(2, 2, 2, 4);
    for method in [
        &baselines::Rtn as &dyn PtqMethod,
        &baselines::Aciq,
        &baselines::MseClip,
    ] {
        let q = method.quantize(&fix().model, 2, 2, &calib);
        let b = accuracy(&q.forward(&val.x), &val.y);
        assert!(
            ours >= b,
            "{}: baseline {b:.3} beat ours {ours:.3} at W2A2",
            method.name()
        );
    }
}

#[test]
fn accuracy_monotone_in_bits_for_single_term() {
    let a2 = ours_acc(2, 2, 1, 1);
    let a4 = ours_acc(4, 4, 1, 1);
    let a8 = ours_acc(8, 8, 1, 1);
    assert!(a8 >= a4 - 0.02 && a4 >= a2 - 0.02, "a2 {a2:.3} a4 {a4:.3} a8 {a8:.3}");
}

#[test]
fn quantization_is_deterministic() {
    let q1 = quantized::quantize_model(&fix().model, LayerPolicy::new(4, 4));
    let q2 = quantized::quantize_model(&fix().model, LayerPolicy::new(4, 4));
    let probe = fix().data.batch(16, 5).x;
    assert_eq!(q1.forward(&probe), q2.forward(&probe));
}

#[test]
fn storage_ordering_w2_lt_w4_lt_w4k2() {
    let s = |w: u32, k: usize| {
        quantized::quantize_model(&fix().model, LayerPolicy::new(w, 4).with_terms(k, 1))
            .storage_bytes()
    };
    assert!(s(2, 1) < s(4, 1));
    assert!(s(4, 1) < s(4, 2));
}
