//! End-to-end trace-plane test: drive mixed-tier traffic through the
//! real TCP server with the flight recorder armed, then assert every
//! completed request left a complete, well-nested span chain whose
//! trace id matches the response header and whose per-layer grid spans
//! sum to exactly the response's executed grid terms — and that the
//! exported dump parses as Chrome-trace JSON.

use fp_xint::coordinator::{
    BasisWorker, BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool,
};
use fp_xint::models::quantized::quantize_model;
use fp_xint::models::zoo;
use fp_xint::obs::{SpanKind, TraceEvent, TraceRecorder};
use fp_xint::qos::{QosConfig, TermController, Tier};
use fp_xint::serve::server::{client_infer_traced, client_metrics, client_trace_json, serve_tcp};
use fp_xint::serve::workers::QuantModelWorker;
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::util::json::Json;
use fp_xint::xint::layer::LayerPolicy;
use std::sync::Arc;

fn span(evs: &[TraceEvent], kind: SpanKind) -> Vec<&TraceEvent> {
    evs.iter().filter(|e| e.span == kind).collect()
}

#[test]
fn tcp_trace_chains_are_complete_and_well_nested() {
    let mut rng = Rng::seed(0x7ACE);
    let probe = Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng);
    let mut m = zoo::mini_resnet_a(4, 0x51);
    let _ = m.forward_train(&probe); // settle BN stats
    let q = quantize_model(&m, LayerPolicy::new(4, 4));
    let pool = WorkerPool::new(
        1,
        Arc::new(move |_| {
            Box::new(QuantModelWorker { model: q.clone(), sample_dims: Some(vec![1, 16, 16]) })
                as Box<dyn BasisWorker>
        }),
    );
    // non-anytime controller: no speculative lookaheads, so the traced
    // per-layer grid spans account for the full executed grid
    let ctl = Arc::new(TermController::new(QosConfig::new(1)));
    let rec = Arc::new(TraceRecorder::default());
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(4, 200, 16),
        ExpansionScheduler::new(pool).with_controller(ctl).with_recorder(rec.clone()),
    ));
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();

    let mut ids = Vec::new();
    for (i, &tier) in Tier::ALL.iter().cycle().take(12).enumerate() {
        let x = Tensor::randn(&[2, 256], 1.0, &mut rng);
        let id = 100 + i as u64;
        let (y, echoed) = client_infer_traced(handle.addr, &x, tier, id).unwrap();
        assert_eq!(y.dims(), &[2, 4]);
        assert_eq!(echoed, id, "response must echo the request's trace id");
        ids.push(id);
    }

    // the request-root span lands just after the reply bytes; wait for
    // every connection thread to flush it
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let evs = rec.events();
        let done = ids
            .iter()
            .all(|&id| evs.iter().any(|e| e.trace_id == id && e.span == SpanKind::Request));
        if done {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "request-root spans missing");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    for &id in &ids {
        let evs = rec.events_for(id);
        let req = {
            let roots = span(&evs, SpanKind::Request);
            assert_eq!(roots.len(), 1, "trace {id}: want exactly one request-root span");
            *roots[0]
        };
        assert!(!req.error, "trace {id}: completed request flagged as error");
        for kind in [
            SpanKind::Decode,
            SpanKind::Admission,
            SpanKind::QueueWait,
            SpanKind::BatchForm,
            SpanKind::Schedule,
            SpanKind::WorkerTerm,
            SpanKind::Reduce,
            SpanKind::Reply,
            SpanKind::LayerGrid,
        ] {
            assert!(!span(&evs, kind).is_empty(), "trace {id}: missing {kind:?} span");
        }
        // well-nested: every span closes, and sits inside the root
        for e in &evs {
            assert!(e.t_start_ns <= e.t_end_ns, "trace {id}: inverted span {e:?}");
            if e.span != SpanKind::Request {
                assert!(
                    e.t_start_ns >= req.t_start_ns && e.t_end_ns <= req.t_end_ns,
                    "trace {id}: {:?} escapes the request span",
                    e.span
                );
            }
        }
        // pipeline phases start in order
        let start_of = |k: SpanKind| span(&evs, k)[0].t_start_ns;
        assert!(start_of(SpanKind::QueueWait) <= start_of(SpanKind::BatchForm), "trace {id}");
        assert!(start_of(SpanKind::BatchForm) <= start_of(SpanKind::Schedule), "trace {id}");
        assert!(start_of(SpanKind::Schedule) <= start_of(SpanKind::Reduce), "trace {id}");
        // worker terms nest inside the reduction, layer grids inside a
        // worker term
        let reduce = span(&evs, SpanKind::Reduce)[0];
        let workers = span(&evs, SpanKind::WorkerTerm);
        for w in &workers {
            assert!(
                w.t_start_ns >= reduce.t_start_ns && w.t_end_ns <= reduce.t_end_ns,
                "trace {id}: worker span escapes the reduce span"
            );
        }
        for lg in span(&evs, SpanKind::LayerGrid) {
            assert!(
                workers.iter().any(|w| lg.t_start_ns >= w.t_start_ns && lg.t_end_ns <= w.t_end_ns),
                "trace {id}: layer-grid span outside every worker span"
            );
        }
        // the per-layer grid spans account for exactly the grid terms
        // echoed in the response (request-root detail slot 2)
        let layer_sum: u64 = span(&evs, SpanKind::LayerGrid).iter().map(|e| e.detail[1]).sum();
        assert!(layer_sum > 0, "trace {id}: no grid work traced");
        assert_eq!(layer_sum, req.detail[2], "trace {id}: layer grid sum != response grid terms");
    }

    // the exported dump is a Chrome-trace JSON array of complete events
    let text = client_trace_json(handle.addr).unwrap();
    let parsed = Json::parse(&text).expect("trace dump must parse as JSON");
    let arr = parsed.as_arr().expect("chrome trace is a JSON array");
    assert!(arr.len() >= ids.len() * 9, "dump too small: {} events", arr.len());
    for ev in arr {
        assert_eq!(ev.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert!(ev.get("ts").and_then(|v| v.as_num()).is_some());
        assert!(ev.get("dur").and_then(|v| v.as_num()).is_some());
        assert!(ev.get("tid").and_then(|v| v.as_usize()).is_some());
    }

    // the scrape endpoint agrees with the traffic served
    let metrics = client_metrics(handle.addr).unwrap();
    assert!(
        metrics.contains("fpxint_requests_completed_total{tier=\"exact\"} 3"),
        "completed counter missing:\n{metrics}"
    );
    handle.stop();
}

#[test]
fn shed_requests_leave_error_flagged_spans_and_are_counted() {
    struct Slow;
    impl BasisWorker for Slow {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            std::thread::sleep(std::time::Duration::from_millis(500));
            Ok(x.clone())
        }
    }
    let pool = WorkerPool::new(1, Arc::new(|_| Box::new(Slow) as Box<dyn BasisWorker>));
    let rec = Arc::new(TraceRecorder::default());
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(1, 10, 2),
        ExpansionScheduler::new(pool).with_recorder(rec.clone()),
    ));
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
    // fill the Throughput queue in-process so the TCP request sheds
    let mut keep = Vec::new();
    loop {
        match coord.submit_tier(Tensor::zeros(&[1, 2]), Tier::Throughput) {
            Ok(rx) => keep.push(rx),
            Err(_) => break,
        }
        assert!(keep.len() < 64, "queue never filled");
    }
    let shed = client_infer_traced(handle.addr, &Tensor::zeros(&[1, 2]), Tier::Throughput, 777);
    assert!(shed.is_err(), "saturated tier must shed");
    // the rejected request still leaves a CLOSED, error-flagged chain
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let evs = rec.events_for(777);
        let has = |k: SpanKind| evs.iter().any(|e| e.span == k && e.error);
        if has(SpanKind::Admission) && has(SpanKind::Request) {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "error spans missing: {evs:?}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    // and the shed is counted in the exposition
    let metrics = client_metrics(handle.addr).unwrap();
    let line = metrics
        .lines()
        .find(|l| l.starts_with("fpxint_requests_shed_total{tier=\"throughput\"}"))
        .expect("shed series missing");
    let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
    assert!(v >= 1.0, "shed not counted: {line}");
    for rx in keep {
        let _ = rx.recv_timeout(std::time::Duration::from_secs(20));
    }
    handle.stop();
}
