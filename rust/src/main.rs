//! `fp-xint` CLI — leader entrypoint.
//!
//! Subcommands:
//!   quantize  — train (or load) a model, series-expand it, report accuracy
//!   serve     — start the TCP serving coordinator over basis workers
//!   eval      — FP vs xINT vs baseline accuracy on the synthetic val set
//!   info      — artifact manifest + environment report
//!   metrics   — scrape a running server's metrics exposition (--addr)
//!   trace     — dump a running server's flight recorder as Chrome-trace
//!               JSON (--addr, --out; open the file in Perfetto)
//!   analyze   — run the domain-aware static analyzer over the crate's
//!               own sources (see ANALYSIS.md; --self-test, --deny
//!               warnings, --src DIR, --out FILE)

use fp_xint::baselines::{self, PtqMethod};
use fp_xint::coordinator::{BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool};
use fp_xint::datasets::{accuracy, SynthImg};
use fp_xint::models::{quantized, zoo};
use fp_xint::serve::{self, workers::MlpWeights};
use fp_xint::tensor::Tensor;
use fp_xint::train::{trained_model_cached, TrainConfig};
use fp_xint::util::sync::{thread, Arc};
use fp_xint::util::{cli::Args, logger, Table};
use fp_xint::xint::layer::LayerPolicy;

fn main() {
    let mut args = Args::from_env();
    let verbose = args.flag("verbose");
    logger::init(verbose);
    match args.subcommand().map(|s| s.to_string()).as_deref() {
        Some("quantize") => cmd_quantize(args),
        Some("serve") => cmd_serve(args),
        Some("eval") => cmd_eval(args),
        Some("info") => cmd_info(),
        Some("metrics") => cmd_metrics(args),
        Some("trace") => cmd_trace(args),
        Some("analyze") => cmd_analyze(args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!(
                "fp-xint {} — low-bit series expansion PTQ\n\
                 usage: fp-xint <quantize|serve|eval|info|metrics|trace|analyze> [--bits N] \n\
                 [--w-terms K] [--a-terms T] [--model NAME] [--steps N] [--port P] \n\
                 [--addr HOST:PORT] [--out FILE] [--deny warnings] [--self-test] [--verbose]",
                fp_xint::VERSION
            );
            std::process::exit(if other.is_some() { 2 } else { 0 });
        }
    }
}

fn load_model(name: &str, steps: usize) -> (fp_xint::models::Model, SynthImg, f64) {
    let data = SynthImg::standard(42);
    let build: Box<dyn Fn() -> fp_xint::models::Model> = match name {
        "mini-resnet-a" => Box::new(|| zoo::mini_resnet_a(10, 1)),
        "mini-resnet-b" => Box::new(|| zoo::mini_resnet_b(10, 2)),
        "mini-resnet-c" => Box::new(|| zoo::mini_resnet_c(10, 3)),
        "regnet" => Box::new(|| zoo::regnet_style(10, 5)),
        "inception" => Box::new(|| zoo::inception_style(10, 6)),
        "mobilenet" => Box::new(|| zoo::mobilenet_style(10, 7)),
        "mlp" => Box::new(|| zoo::mlp(256, &[64], 10, 8)),
        other => {
            eprintln!("unknown model {other}");
            std::process::exit(2);
        }
    };
    let cfg = TrainConfig { steps, ..Default::default() };
    let (m, acc) = trained_model_cached(&format!("cli_{name}"), &*build, &data, &cfg);
    (m, data, acc)
}

fn cmd_quantize(mut args: Args) {
    let bits: u32 = args.get_num("bits", 4);
    let w_terms: usize = args.get_num("w-terms", 2);
    let a_terms: usize = args.get_num("a-terms", 4);
    let steps: usize = args.get_num("steps", 400);
    let model_name = args.get("model", "mini-resnet-a");
    let (model, data, fp_acc) = load_model(&model_name, steps);
    let policy = LayerPolicy::new(bits, bits).with_terms(w_terms, a_terms);
    let (q, dt) = fp_xint::util::timer::time_once(|| quantized::quantize_model(&model, policy));
    let val = data.batch(512, 2);
    let q_acc = accuracy(&q.forward(&val.x), &val.y);
    let mut t = Table::new(
        &format!("{model_name} W{bits}A{bits} (k={w_terms}, t={a_terms})"),
        &["metric", "value"],
    );
    t.row_str(&["FP val acc", &format!("{:.2}%", fp_acc * 100.0)]);
    t.row_str(&["xINT val acc", &format!("{:.2}%", q_acc * 100.0)]);
    t.row_str(&["quantization time", &format!("{dt:.3}s")]);
    t.row_str(&["quantized size", &format!("{} B", q.storage_bytes())]);
    t.print();
}

fn cmd_eval(mut args: Args) {
    let bits: u32 = args.get_num("bits", 4);
    let steps: usize = args.get_num("steps", 400);
    let model_name = args.get("model", "mini-resnet-a");
    let (model, data, fp_acc) = load_model(&model_name, steps);
    let val = data.batch(512, 2);
    let calib = data.batch(32, 3).x;
    let mut t = Table::new(
        &format!("{model_name} — W{bits}A{bits} method comparison"),
        &["method", "val acc"],
    );
    t.row_str(&["Full Prec.", &format!("{:.2}%", fp_acc * 100.0)]);
    let methods: Vec<Box<dyn PtqMethod>> = vec![
        Box::new(baselines::Rtn),
        Box::new(baselines::Aciq),
        Box::new(baselines::AdaQuant::default()),
    ];
    for m in methods {
        let q = m.quantize(&model, bits, bits, &calib);
        let acc = accuracy(&q.forward(&val.x), &val.y);
        t.row_str(&[m.name(), &format!("{:.2}%", acc * 100.0)]);
    }
    let q = quantized::quantize_model(&model, LayerPolicy::new(bits, bits));
    let acc = accuracy(&q.forward(&val.x), &val.y);
    t.row_str(&["Ours (series)", &format!("{:.2}%", acc * 100.0)]);
    t.print();
}

fn cmd_serve(mut args: Args) {
    let bits: u32 = args.get_num("bits", 8);
    let terms: usize = args.get_num("terms", 3);
    let port: u16 = args.get_num("port", 7878);
    let steps: usize = args.get_num("steps", 300);
    // MLP serving path (matches the AOT artifacts' geometry)
    let (mut model, _data, _) = load_model("mlp", steps);
    model.fold_bn();
    let weights = mlp_weights_of(&model);
    let pool = WorkerPool::new(terms, serve::workers::mlp_basis_factory(&weights, bits, terms));
    // flight recorder on by default: spans feed the `metrics` / `trace`
    // subcommands and the TCP control frames
    let recorder = Arc::new(fp_xint::obs::TraceRecorder::default());
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::default(),
        ExpansionScheduler::new(pool).with_recorder(recorder),
    ));
    let handle =
        serve::serve_tcp(&format!("127.0.0.1:{port}"), coord.clone()).expect("bind server");
    println!("serving xINT basis models on {} (Ctrl-C to stop)", handle.addr);
    loop {
        thread::sleep(std::time::Duration::from_secs(5));
        let s = coord.metrics.latency_summary();
        log::info!(
            "completed {} failed {} mean batch {:.1} p50 {:.2}ms",
            coord.metrics.completed(),
            coord.metrics.failed(),
            coord.metrics.mean_batch_size(),
            s.p50 * 1e3
        );
    }
}

fn mlp_weights_of(model: &fp_xint::models::Model) -> MlpWeights {
    use fp_xint::models::Layer;
    let linears: Vec<&fp_xint::models::LinearLayer> = model
        .layers
        .iter()
        .filter_map(|l| match l {
            Layer::Linear(lin) => Some(lin),
            _ => None,
        })
        .collect();
    assert!(linears.len() >= 2, "serve expects the MLP model");
    MlpWeights {
        w1: linears[0].w.clone(),
        b1: linears[0].b.clone().unwrap_or_else(|| Tensor::zeros(&[linears[0].w.dims()[0]])),
        w2: linears[1].w.clone(),
        b2: linears[1].b.clone().unwrap_or_else(|| Tensor::zeros(&[linears[1].w.dims()[0]])),
    }
}

fn parse_addr(args: &mut Args) -> std::net::SocketAddr {
    let addr = args.get("addr", "127.0.0.1:7878");
    match addr.parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad --addr {addr:?}: {e}");
            std::process::exit(2);
        }
    }
}

fn cmd_metrics(mut args: Args) {
    let addr = parse_addr(&mut args);
    match serve::client_metrics(addr) {
        Ok(text) => print!("{text}"),
        Err(e) => {
            eprintln!("metrics scrape from {addr} failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cmd_trace(mut args: Args) {
    let addr = parse_addr(&mut args);
    let out = args.get("out", "trace.json");
    let json = match serve::client_trace_json(addr) {
        Ok(json) => json,
        Err(e) => {
            eprintln!("trace dump from {addr} failed: {e:#}");
            std::process::exit(1);
        }
    };
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("cannot write {out}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out} ({} bytes) — open in Perfetto or chrome://tracing", json.len());
}

fn cmd_analyze(mut args: Args) {
    use fp_xint::analyze;
    if args.flag("self-test") {
        let report = analyze::selftest::run();
        if report.failed.is_empty() {
            println!("analyze self-test: {} checks passed", report.total);
            return;
        }
        for f in &report.failed {
            eprintln!("self-test failure: {f}");
        }
        eprintln!("analyze self-test: {}/{} checks failed", report.failed.len(), report.total);
        std::process::exit(1);
    }
    let src = match args.get_opt("src") {
        Some(s) => std::path::PathBuf::from(s),
        None => match analyze::default_src_root() {
            Some(p) => p,
            None => {
                eprintln!("cannot locate the crate sources; pass --src DIR");
                std::process::exit(2);
            }
        },
    };
    let set = match analyze::SourceSet::load(&src) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot read sources under {}: {e}", src.display());
            std::process::exit(2);
        }
    };
    let findings = analyze::run_all(&set);
    let report = analyze::render_report(&set, &findings);
    // the JSON report always lands (stdout or --out) before any exit,
    // so CI can archive it from failing runs too
    match args.get_opt("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(&path, &report) {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
        None => println!("{report}"),
    }
    for f in &findings {
        eprintln!("{}", f.render_line());
    }
    let errors = findings.iter().filter(|f| f.level == analyze::Level::Error).count();
    let warnings = findings.len() - errors;
    eprintln!("analyze: {} files, {errors} errors, {warnings} warnings", set.files.len());
    if errors > 0 || (warnings > 0 && args.get("deny", "") == "warnings") {
        std::process::exit(1);
    }
}

fn cmd_info() {
    println!("fp-xint {}", fp_xint::VERSION);
    let dir = fp_xint::runtime::Runtime::default_artifact_dir();
    match fp_xint::runtime::Manifest::load(dir.join("manifest.json")) {
        Ok(m) => {
            println!(
                "artifacts: {} entries (din={} hidden={} classes={} bits={})",
                m.artifacts.len(),
                m.din,
                m.hidden,
                m.classes,
                m.bits
            );
            for (k, v) in &m.artifacts {
                println!("  {k} -> {v}");
            }
        }
        Err(e) => println!("no artifacts ({e:#}); run `make artifacts`"),
    }
}
