//! Observability plane: request-scoped tracing + metrics exposition.
//!
//! The FP=xINT serving stack trades *precision* for latency at runtime
//! — tier budgets, per-layer [`BudgetPlan`](crate::xint::BudgetPlan)s,
//! §5.3 anytime stops, per-tier pressure loops — so "why was this
//! request served with 9 grid terms at 80 ms" is a per-request
//! question. This module answers it:
//!
//! * [`recorder`] — the [`TraceRecorder`] flight-recorder ring: every
//!   pipeline stage from TCP accept to per-layer grid execution records
//!   a closed span keyed by the request's `trace_id` (threaded through
//!   the wire protocol and echoed in the response). Lock-free,
//!   bounded, drop-oldest; cheap enough to leave on in production.
//! * [`export`] — Chrome-trace-event/Perfetto JSON dump of the ring
//!   ([`chrome_trace_json`]), fetched over the serve protocol's trace
//!   control frame or the `trace` CLI subcommand.
//! * [`exposition`] — the [`ExpositionBuilder`] for Prometheus text
//!   exposition (per-tier latency histograms, queue depths, sheds,
//!   pressure, degrade/restore events, grid-term means, est-loss),
//!   served by the metrics control frame / `metrics` CLI subcommand.
//!
//! Wiring: construct a recorder, hand it to
//! `ExpansionScheduler::with_recorder` (the
//! [`Coordinator`](crate::coordinator::Coordinator) picks it up from
//! the scheduler, exactly like the QoS controller), and serve — every
//! request now leaves a well-nested span chain
//! `request → decode/admission/queue_wait/batch_form/schedule/
//! worker_term/layer_grid/reduce/reply` in the ring.

pub mod export;
pub mod exposition;
pub mod recorder;

pub use export::chrome_trace_json;
pub use exposition::ExpositionBuilder;
pub use recorder::{SpanKind, TraceEvent, TraceRecorder, DEFAULT_CAPACITY};
