//! Prometheus-style text exposition (text format 0.0.4): a small
//! builder that enforces the format invariants the scrape linter checks
//! — exactly one `# TYPE` per family, no duplicate series, plain
//! parseable float values — so every exporter in the crate produces
//! scrape-clean output by construction.

use crate::util::stats::Histogram;
use std::collections::BTreeSet;

/// Accumulates one exposition document.
#[derive(Default)]
pub struct ExpositionBuilder {
    out: String,
    families: BTreeSet<String>,
    series: BTreeSet<String>,
}

impl ExpositionBuilder {
    pub fn new() -> ExpositionBuilder {
        ExpositionBuilder::default()
    }

    /// Open a metric family: one `# HELP` + `# TYPE` header. Declaring
    /// the same family twice is a caller bug (debug-asserted, ignored
    /// in release so a scrape never dies on it).
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        if !self.families.insert(name.to_string()) {
            debug_assert!(false, "duplicate metric family {name}");
            return;
        }
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// Emit one series line. Duplicate (name, labels) pairs are a
    /// caller bug (debug-asserted, dropped in release).
    pub fn series(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        let labels = render_labels(labels);
        if !self.series.insert(format!("{name}{labels}")) {
            debug_assert!(false, "duplicate series {name}{labels}");
            return;
        }
        self.out.push_str(&format!("{name}{labels} {}\n", render_value(value)));
    }

    /// Emit the `_bucket`/`_sum`/`_count` series of a histogram family
    /// (declare the family itself with `family(name, "histogram", …)`
    /// first). Buckets are cumulative, closing with `le="+Inf"`.
    pub fn histogram(&mut self, name: &str, labels: &[(&str, &str)], hist: &Histogram) {
        let bucket = format!("{name}_bucket");
        let mut cumulative = 0u64;
        for (bound, count) in hist.bounds().iter().zip(hist.bucket_counts()) {
            cumulative += count;
            let le = format!("{bound}");
            let mut labels_le: Vec<(&str, &str)> = labels.to_vec();
            labels_le.push(("le", &le));
            self.series(&bucket, &labels_le, cumulative as f64);
        }
        let mut labels_inf: Vec<(&str, &str)> = labels.to_vec();
        labels_inf.push(("le", "+Inf"));
        self.series(&bucket, &labels_inf, hist.count() as f64);
        self.series(&format!("{name}_sum"), labels, hist.sum());
        self.series(&format!("{name}_count"), labels, hist.count() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

fn render_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{{{}}}", body.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn render_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_well_formed_exposition() {
        let mut b = ExpositionBuilder::new();
        b.family("fpxint_requests_total", "counter", "completed requests");
        b.series("fpxint_requests_total", &[("tier", "exact")], 12.0);
        b.series("fpxint_requests_total", &[("tier", "balanced")], 3.5);
        b.family("fpxint_queue_depth", "gauge", "queued requests");
        b.series("fpxint_queue_depth", &[], 0.0);
        let text = b.finish();
        assert_eq!(text.matches("# TYPE fpxint_requests_total").count(), 1);
        assert!(text.contains("fpxint_requests_total{tier=\"exact\"} 12\n"));
        assert!(text.contains("fpxint_requests_total{tier=\"balanced\"} 3.5\n"));
        assert!(text.contains("fpxint_queue_depth 0\n"));
    }

    #[test]
    fn histogram_series_are_cumulative_and_close_with_inf() {
        let mut h = Histogram::new(vec![0.01, 0.1, 1.0]);
        for v in [0.005, 0.005, 0.05, 0.5, 5.0] {
            h.observe(v);
        }
        let mut b = ExpositionBuilder::new();
        b.family("fpxint_latency_seconds", "histogram", "request latency");
        b.histogram("fpxint_latency_seconds", &[("tier", "exact")], &h);
        let text = b.finish();
        assert!(text.contains("fpxint_latency_seconds_bucket{tier=\"exact\",le=\"0.01\"} 2\n"));
        assert!(text.contains("fpxint_latency_seconds_bucket{tier=\"exact\",le=\"0.1\"} 3\n"));
        assert!(text.contains("fpxint_latency_seconds_bucket{tier=\"exact\",le=\"1\"} 4\n"));
        assert!(text.contains("fpxint_latency_seconds_bucket{tier=\"exact\",le=\"+Inf\"} 5\n"));
        assert!(text.contains("fpxint_latency_seconds_count{tier=\"exact\"} 5\n"));
    }

    #[test]
    fn duplicate_series_are_dropped_not_duplicated() {
        // release behavior: the duplicate line never reaches the output
        if cfg!(debug_assertions) {
            return; // debug builds assert instead
        }
        let mut b = ExpositionBuilder::new();
        b.family("m", "gauge", "x");
        b.series("m", &[], 1.0);
        b.series("m", &[], 2.0);
        let text = b.finish();
        assert_eq!(text.matches("\nm ").count(), 1);
        assert!(text.contains("m 1\n"));
        assert!(!text.contains("m 2\n"));
    }

    #[test]
    fn special_values_render_parseably() {
        assert_eq!(render_value(f64::NAN), "NaN");
        assert_eq!(render_value(f64::INFINITY), "+Inf");
        assert_eq!(render_value(1.25), "1.25");
        assert_eq!(escape_label("a\"b\\c"), "a\\\"b\\\\c");
    }
}
