//! Chrome-trace-event export: turn a flight-recorder snapshot into the
//! JSON array format `chrome://tracing` and Perfetto's legacy importer
//! open directly (`ui.perfetto.dev` → *Open trace file*).
//!
//! Every [`TraceEvent`] becomes one *complete* event (`"ph": "X"`) with
//! microsecond `ts`/`dur`, `pid` fixed at 1 and `tid` set to the
//! `trace_id` — so each request renders as its own track and the span
//! chain (request → decode → queue wait → … → reply) nests visually on
//! that track. Span details are exported as named `args` (labels from
//! [`SpanKind::detail_names`]) next to the tier and error flag, putting
//! the precision axis (grid terms, planned grid, budget) on the same
//! timeline as the latency axis.

use super::recorder::TraceEvent;
use crate::util::json::Json;

/// Build the Chrome-trace JSON array for a snapshot of events.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    Json::Arr(events.iter().map(event_json).collect())
}

fn event_json(ev: &TraceEvent) -> Json {
    let mut args = vec![
        ("tier".to_string(), Json::str(ev.tier.name())),
        ("error".to_string(), Json::Bool(ev.error)),
    ];
    for (name, value) in ev.span.detail_names().iter().zip(ev.detail.iter()) {
        if !name.is_empty() {
            args.push((name.to_string(), Json::num(*value as f64)));
        }
    }
    Json::Obj(
        [
            ("name".to_string(), Json::str(ev.span.name())),
            ("cat".to_string(), Json::str("fpxint")),
            ("ph".to_string(), Json::str("X")),
            ("ts".to_string(), Json::num(ev.t_start_ns as f64 / 1_000.0)),
            (
                "dur".to_string(),
                Json::num(ev.t_end_ns.saturating_sub(ev.t_start_ns) as f64 / 1_000.0),
            ),
            ("pid".to_string(), Json::num(1.0)),
            ("tid".to_string(), Json::num(ev.trace_id as f64)),
            ("args".to_string(), Json::Obj(args.into_iter().collect())),
        ]
        .into_iter()
        .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::SpanKind;
    use crate::qos::Tier;

    #[test]
    fn renders_valid_chrome_trace() {
        let events = vec![
            TraceEvent {
                trace_id: 42,
                span: SpanKind::Request,
                tier: Tier::Exact,
                error: false,
                t_start_ns: 1_000,
                t_end_ns: 9_000,
                detail: [4, 8, 96],
            },
            TraceEvent {
                trace_id: 42,
                span: SpanKind::WorkerTerm,
                tier: Tier::Exact,
                error: true,
                t_start_ns: 2_000,
                t_end_ns: 3_500,
                detail: [3, 12, 0],
            },
        ];
        let text = chrome_trace_json(&events).render();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let root = &arr[0];
        assert_eq!(root.get("name").unwrap().as_str(), Some("request"));
        assert_eq!(root.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(root.get("ts").unwrap().as_num(), Some(1.0));
        assert_eq!(root.get("dur").unwrap().as_num(), Some(8.0));
        assert_eq!(root.get("tid").unwrap().as_usize(), Some(42));
        let args = root.get("args").unwrap();
        assert_eq!(args.get("tier").unwrap().as_str(), Some("exact"));
        assert_eq!(args.get("error"), Some(&Json::Bool(false)));
        assert_eq!(args.get("rows").unwrap().as_usize(), Some(4));
        assert_eq!(args.get("grid_terms").unwrap().as_usize(), Some(96));
        let worker = &arr[1];
        assert_eq!(worker.get("args").unwrap().get("worker").unwrap().as_usize(), Some(3));
        assert_eq!(worker.get("args").unwrap().get("error"), Some(&Json::Bool(true)));
        // unused detail slots are not exported
        assert!(worker.get("args").unwrap().get("planned_grid").is_none());
    }
}
