//! Lock-free flight recorder: a bounded ring of closed span events.
//!
//! The recorder is the hot-path half of the trace plane: every pipeline
//! stage (accept/decode, admission, queue wait, batch formation,
//! schedule, per-worker term execution, reduction, reply, per-layer
//! grid) records one **closed** span — `(trace_id, kind, tier,
//! t_start_ns, t_end_ns, detail)` — into a fixed-size ring with a
//! single `fetch_add` cursor. Writers never block, never allocate, and
//! never contend on a lock; when the ring wraps, the oldest events are
//! overwritten (drop-oldest, [`TraceRecorder::dropped`] counts the
//! loss). Timestamps are nanoseconds on a monotonic clock anchored at
//! the recorder's construction ([`TraceRecorder::now_ns`] /
//! [`TraceRecorder::ns_of`]), so spans from every thread share one
//! timeline.
//!
//! Each slot is a seqlock: the writer flips the slot's sequence word
//! odd, stores the fields, then flips it even; the reader
//! ([`TraceRecorder::events`]) rejects slots whose sequence is odd or
//! changed mid-read. The memory orderings are what make that sequence
//! check sound: field values are published with `Release` stores and
//! read with `Acquire` loads, so a reader that observed any field of a
//! newer write has also synchronized with that write's odd sequence
//! flip and must fail its recheck. (An earlier revision stored the
//! fields `Relaxed` and claimed a torn read was "impossible to observe"
//! — the loom models below refute that: a relaxed field store may
//! become visible before the odd flip, letting both sequence checks
//! pass around a mixed-write snapshot. See
//! `loom_model_all_relaxed_seqlock_is_torn` and CONCURRENCY.md.) There
//! is no `unsafe` anywhere. Slot ownership is single-writer: the cursor
//! RMW hands each `record()` call a distinct slot, and two calls share
//! one only if the cursor laps the *entire ring* while the first is
//! still mid-write. A reader spanning two laps still rejects — per-slot
//! sequence values strictly increase, so its recheck cannot see the
//! first value again — but two *writers* interleaved inside one slot
//! could leave it even-and-mixed, so capacity must stay far above
//! writer concurrency (the default 64 Ki slots vs. a handful of worker
//! threads; CONCURRENCY.md states the bound).

use crate::qos::Tier;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Default ring capacity (events). At ~10 events per request this holds
/// the last several thousand requests.
pub const DEFAULT_CAPACITY: usize = 64 * 1024;

/// Pipeline stage a span covers. The numbering is stable (it is packed
/// into ring slots and exported); append, never reorder.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum SpanKind {
    /// root span: TCP accept of the request header → reply flushed
    Request = 0,
    /// header + payload read and tensor decode
    Decode = 1,
    /// admission control (queue-cap check); `error` flags a shed
    Admission = 2,
    /// per-tier queue residence: enqueue → batch formation
    QueueWait = 3,
    /// batch formation → scheduler pickup
    BatchForm = 4,
    /// scheduler dispatch: budget/plan resolution before reduction
    Schedule = 5,
    /// one basis worker executing its term (detail: worker index, grid
    /// terms executed)
    WorkerTerm = 6,
    /// the ⊎ prefix reduction across worker outputs
    Reduce = 7,
    /// response encode + socket write
    Reply = 8,
    /// one quantized layer's Eq. 3 grid execution (detail: layer
    /// position, executed grid terms, planned grid terms)
    LayerGrid = 9,
    /// reactor accept: listener readable → connection registered
    Accept = 10,
    /// reply frame queued on the connection → last byte flushed
    Write = 11,
    /// progressive refinement: reduction start → last delta emitted
    Refine = 12,
}

impl SpanKind {
    pub const ALL: [SpanKind; 13] = [
        SpanKind::Request,
        SpanKind::Decode,
        SpanKind::Admission,
        SpanKind::QueueWait,
        SpanKind::BatchForm,
        SpanKind::Schedule,
        SpanKind::WorkerTerm,
        SpanKind::Reduce,
        SpanKind::Reply,
        SpanKind::LayerGrid,
        SpanKind::Accept,
        SpanKind::Write,
        SpanKind::Refine,
    ];

    pub fn from_u8(v: u8) -> Option<SpanKind> {
        SpanKind::ALL.get(v as usize).copied()
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Decode => "decode",
            SpanKind::Admission => "admission",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::BatchForm => "batch_form",
            SpanKind::Schedule => "schedule",
            SpanKind::WorkerTerm => "worker_term",
            SpanKind::Reduce => "reduce",
            SpanKind::Reply => "reply",
            SpanKind::LayerGrid => "layer_grid",
            SpanKind::Accept => "accept",
            SpanKind::Write => "write",
            SpanKind::Refine => "refine",
        }
    }

    /// Labels for the three detail slots (empty = unused), so exports
    /// can name arguments instead of dumping raw integers.
    pub fn detail_names(&self) -> [&'static str; 3] {
        match self {
            SpanKind::Request => ["rows", "terms", "grid_terms"],
            SpanKind::Decode => ["rows", "cols", ""],
            SpanKind::Admission => ["queue_depth", "", ""],
            SpanKind::QueueWait => ["queue_depth", "", ""],
            SpanKind::BatchForm => ["batch_rows", "parts", ""],
            SpanKind::Schedule => ["budget_terms", "planned_grid", ""],
            SpanKind::WorkerTerm => ["worker", "grid_terms", ""],
            SpanKind::Reduce => ["terms", "grid_terms", ""],
            SpanKind::Reply => ["bytes", "", ""],
            SpanKind::LayerGrid => ["layer", "grid_terms", "planned_grid"],
            SpanKind::Accept => ["token", "", ""],
            SpanKind::Write => ["bytes", "queued_frames", ""],
            SpanKind::Refine => ["terms", "frames", ""],
        }
    }
}

impl std::fmt::Display for SpanKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One closed span, as read back out of the ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// request-scoped correlation id (echoed in the TCP response)
    pub trace_id: u64,
    pub span: SpanKind,
    pub tier: Tier,
    /// true when the stage failed (shed, batch error, …) — error-path
    /// requests still close every span, they just carry this flag
    pub error: bool,
    /// nanoseconds since the recorder epoch
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    /// span-specific payload, labeled by [`SpanKind::detail_names`]
    pub detail: [u64; 3],
}

fn pack_meta(span: SpanKind, tier: Tier, error: bool) -> u64 {
    (span as u64) | ((tier.idx() as u64) << 8) | ((error as u64) << 16)
}

fn unpack_meta(meta: u64) -> Option<(SpanKind, Tier, bool)> {
    let span = SpanKind::from_u8((meta & 0xff) as u8)?;
    let tier = Tier::from_u32(((meta >> 8) & 0xff) as u32)?;
    Some((span, tier, (meta >> 16) & 1 == 1))
}

#[derive(Default)]
struct Slot {
    /// seqlock word: 0 = never written, odd = write in progress,
    /// even = stable (the writer stores `2n+1` then `2n+2` for cursor
    /// position `n`, so every write changes the value)
    seq: AtomicU64,
    trace_id: AtomicU64,
    t_start: AtomicU64,
    t_end: AtomicU64,
    meta: AtomicU64,
    d0: AtomicU64,
    d1: AtomicU64,
    d2: AtomicU64,
}

/// The flight recorder. Cheap to share (`Arc`), cheap to write (one
/// `fetch_add` + eight relaxed stores), bounded in memory.
pub struct TraceRecorder {
    epoch: Instant,
    slots: Vec<Slot>,
    cursor: AtomicU64,
}

impl TraceRecorder {
    /// A recorder holding the last `capacity` events (min 1).
    pub fn new(capacity: usize) -> TraceRecorder {
        let capacity = capacity.max(1);
        TraceRecorder {
            epoch: Instant::now(),
            slots: std::iter::repeat_with(Slot::default).take(capacity).collect(),
            cursor: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Nanoseconds since the recorder epoch, now.
    pub fn now_ns(&self) -> u64 {
        self.ns_of(Instant::now())
    }

    /// Nanoseconds since the recorder epoch for an [`Instant`] captured
    /// elsewhere (0 for instants before the epoch).
    pub fn ns_of(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch).map(|d| d.as_nanos() as u64).unwrap_or(0)
    }

    /// Record one closed span. Never blocks; overwrites the oldest
    /// event when the ring is full.
    pub fn record(&self, ev: TraceEvent) {
        // ordering: Relaxed — the cursor RMW only claims a slot index
        // (atomicity is what matters); publication of the slot contents
        // is carried entirely by the seqlock protocol below.
        let n = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        // ordering: Release — the odd flip opens the write window; it
        // must be visible no later than any field store below.
        slot.seq.store(2 * n + 1, Ordering::Release);
        // Each field store publishes the odd flip along with the value,
        // so a reader whose Acquire load observes any field of this
        // write also observes `2n + 1` (or later) in its sequence
        // recheck and rejects the snapshot. With Relaxed field stores
        // the recheck is fiction: a field store may become visible
        // before the odd flip (the loom model
        // `loom_model_all_relaxed_seqlock_is_torn` finds exactly that
        // interleaving).
        // ordering: Release — all seven field stores, per the above.
        slot.trace_id.store(ev.trace_id, Ordering::Release);
        slot.t_start.store(ev.t_start_ns, Ordering::Release);
        slot.t_end.store(ev.t_end_ns, Ordering::Release);
        slot.meta.store(pack_meta(ev.span, ev.tier, ev.error), Ordering::Release);
        slot.d0.store(ev.detail[0], Ordering::Release);
        slot.d1.store(ev.detail[1], Ordering::Release);
        slot.d2.store(ev.detail[2], Ordering::Release);
        // ordering: Release — the even flip closes the window and
        // publishes every field store above to readers that observe it.
        slot.seq.store(2 * n + 2, Ordering::Release);
    }

    /// Convenience wrapper over [`TraceRecorder::record`].
    #[allow(clippy::too_many_arguments)]
    pub fn record_span(
        &self,
        trace_id: u64,
        span: SpanKind,
        tier: Tier,
        error: bool,
        t_start_ns: u64,
        t_end_ns: u64,
        detail: [u64; 3],
    ) {
        self.record(TraceEvent { trace_id, span, tier, error, t_start_ns, t_end_ns, detail });
    }

    /// Total events ever recorded.
    pub fn recorded(&self) -> u64 {
        // ordering: Relaxed — a monotonic statistic; no slot payload is
        // read on the strength of this value.
        self.cursor.load(Ordering::Relaxed)
    }

    /// Events lost to ring wraparound (drop-oldest).
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    /// Snapshot the ring: every stable event, ordered by start time
    /// (ties: longer span first, so parents precede their children).
    /// Slots being written concurrently are skipped, not torn.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            // ordering: Acquire — pairs with the writer's Release even
            // flip: observing `2n + 2` makes that write's field stores
            // visible to the loads below.
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue; // never written, or write in progress
            }
            // Pairs with the writer's Release field stores: observing
            // any field of a write newer than `s1` also makes that
            // write's odd flip visible, so the recheck below must fail.
            // That pairing is what turns the sequence recheck into an
            // actual proof of an untorn snapshot.
            // ordering: Acquire — all seven field loads, per the above.
            let trace_id = slot.trace_id.load(Ordering::Acquire);
            let t_start_ns = slot.t_start.load(Ordering::Acquire);
            let t_end_ns = slot.t_end.load(Ordering::Acquire);
            let meta = slot.meta.load(Ordering::Acquire);
            let detail = [
                slot.d0.load(Ordering::Acquire),
                slot.d1.load(Ordering::Acquire),
                slot.d2.load(Ordering::Acquire),
            ];
            // ordering: Acquire — the recheck; per-slot sequence values
            // strictly increase, so seeing `s1` again proves no writer
            // opened the slot while the fields were being read.
            if slot.seq.load(Ordering::Acquire) != s1 {
                continue; // overwritten mid-read
            }
            if let Some((span, tier, error)) = unpack_meta(meta) {
                out.push(TraceEvent { trace_id, span, tier, error, t_start_ns, t_end_ns, detail });
            }
        }
        out.sort_by(|a, b| a.t_start_ns.cmp(&b.t_start_ns).then(b.t_end_ns.cmp(&a.t_end_ns)));
        out
    }

    /// Snapshot of one request's spans, in the same order as
    /// [`TraceRecorder::events`].
    pub fn events_for(&self, trace_id: u64) -> Vec<TraceEvent> {
        let mut evs = self.events();
        evs.retain(|e| e.trace_id == trace_id);
        evs
    }
}

impl Default for TraceRecorder {
    fn default() -> TraceRecorder {
        TraceRecorder::new(DEFAULT_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ev(trace_id: u64, span: SpanKind, t0: u64, t1: u64) -> TraceEvent {
        TraceEvent {
            trace_id,
            span,
            tier: Tier::Balanced,
            error: false,
            t_start_ns: t0,
            t_end_ns: t1,
            detail: [1, 2, 3],
        }
    }

    #[test]
    fn roundtrips_events() {
        let rec = TraceRecorder::new(8);
        rec.record(ev(7, SpanKind::Request, 100, 900));
        rec.record(ev(7, SpanKind::Decode, 100, 200));
        let evs = rec.events();
        assert_eq!(evs.len(), 2);
        // equal starts: the longer (parent) span sorts first
        assert_eq!(evs[0].span, SpanKind::Request);
        assert_eq!(evs[1].span, SpanKind::Decode);
        assert_eq!(evs[0].trace_id, 7);
        assert_eq!(evs[0].detail, [1, 2, 3]);
        assert_eq!(evs[0].tier, Tier::Balanced);
        assert!(!evs[0].error);
        assert_eq!(rec.dropped(), 0);
        assert_eq!(rec.events_for(7).len(), 2);
        assert!(rec.events_for(8).is_empty());
    }

    #[test]
    fn ring_drops_oldest() {
        let rec = TraceRecorder::new(4);
        for i in 0..10u64 {
            rec.record(ev(i, SpanKind::Reply, i * 10, i * 10 + 5));
        }
        let evs = rec.events();
        assert_eq!(evs.len(), 4);
        assert_eq!(rec.recorded(), 10);
        assert_eq!(rec.dropped(), 6);
        // the survivors are the newest four
        let ids: Vec<u64> = evs.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
    }

    #[test]
    fn error_flag_and_tier_roundtrip() {
        let rec = TraceRecorder::new(4);
        for (i, &tier) in Tier::ALL.iter().enumerate() {
            rec.record(TraceEvent {
                trace_id: i as u64,
                span: SpanKind::Admission,
                tier,
                error: i % 2 == 1,
                t_start_ns: i as u64,
                t_end_ns: i as u64 + 1,
                detail: [0; 3],
            });
        }
        let evs = rec.events();
        assert_eq!(evs.len(), 4);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.tier, Tier::ALL[i]);
            assert_eq!(e.error, i % 2 == 1);
        }
    }

    #[test]
    fn timestamps_are_monotonic() {
        let rec = TraceRecorder::new(4);
        let a = rec.now_ns();
        let b = rec.now_ns();
        assert!(b >= a);
        let t = Instant::now();
        assert!(rec.ns_of(t) >= a);
        // an instant before the epoch clamps to zero instead of panicking
        if let Some(past) = t.checked_sub(std::time::Duration::from_secs(3600)) {
            assert_eq!(TraceRecorder::new(1).ns_of(past), 0);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 4 writers x 1000 events is minutes under miri
    fn concurrent_writers_never_corrupt_the_ring() {
        let rec = Arc::new(TraceRecorder::new(64));
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let rec = Arc::clone(&rec);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    rec.record(ev(w * 10_000 + i, SpanKind::WorkerTerm, i, i + 1));
                }
            }));
        }
        let _ = rec.events(); // read while writers are racing
        for h in handles {
            h.join().unwrap();
        }
        let evs = rec.events();
        assert!(evs.len() <= 64);
        assert_eq!(rec.recorded(), 4000);
        // every surviving event is one that was actually written
        for e in &evs {
            assert_eq!(e.span, SpanKind::WorkerTerm);
            assert_eq!(e.t_end_ns, e.t_start_ns + 1);
            assert!(e.trace_id % 10_000 < 1000);
        }
    }

    #[test]
    fn span_kind_table_is_consistent() {
        for (i, k) in SpanKind::ALL.iter().enumerate() {
            assert_eq!(SpanKind::from_u8(i as u8), Some(*k));
            assert!(!k.name().is_empty());
            assert_eq!(k.to_string(), k.name());
        }
        assert_eq!(SpanKind::from_u8(SpanKind::ALL.len() as u8), None);
    }
}

/// Loom models for the seqlock ring. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_model_`
/// (see CONCURRENCY.md). Events are redundancy-encoded — every field is
/// derived from `trace_id` — so a snapshot mixing two writes is
/// detectable no matter which fields tore.
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use crate::util::sync::atomic::{AtomicU64, Ordering};
    use crate::util::sync::{thread, Arc};

    fn encoded(id: u64) -> TraceEvent {
        TraceEvent {
            trace_id: id,
            span: SpanKind::WorkerTerm,
            tier: Tier::Balanced,
            error: false,
            t_start_ns: id,
            t_end_ns: id + 1,
            detail: [id, id, id],
        }
    }

    fn assert_untorn(e: &TraceEvent) {
        assert!(e.trace_id >= 1, "phantom event surfaced: {e:?}");
        assert_eq!(e.t_start_ns, e.trace_id, "torn snapshot accepted: {e:?}");
        assert_eq!(e.t_end_ns, e.trace_id + 1, "torn snapshot accepted: {e:?}");
        assert_eq!(e.detail, [e.trace_id; 3], "torn snapshot accepted: {e:?}");
        assert_eq!(e.span, SpanKind::WorkerTerm);
        assert_eq!(e.tier, Tier::Balanced);
    }

    /// Writer-vs-reader: two writers fill distinct slots (capacity ==
    /// writer count keeps slot ownership single-writer, matching the
    /// design envelope) while the reader snapshots mid-race. The reader
    /// must only ever surface whole events, and after the writers join,
    /// nothing may be lost or double-counted.
    #[test]
    fn loom_model_seqlock_rejects_torn_reads() {
        loom::model(|| {
            let rec = Arc::new(TraceRecorder::new(2));
            let writers: Vec<_> = (1..=2u64)
                .map(|id| {
                    let rec = Arc::clone(&rec);
                    thread::spawn(move || rec.record(encoded(id)))
                })
                .collect();
            // Snapshot while the writers race: partial writes must be
            // skipped, never surfaced torn.
            for e in rec.events() {
                assert_untorn(&e);
            }
            for h in writers {
                h.join().unwrap();
            }
            // Quiescent: both events are stable, whole, and accounted.
            let evs = rec.events();
            assert_eq!(evs.len(), 2, "stable slots lost after writers joined");
            for e in &evs {
                assert_untorn(e);
            }
            assert_eq!(rec.recorded(), 2);
            assert_eq!(rec.dropped(), 0, "dropped() miscounted");
        });
    }

    /// Ring wraparound under a concurrent reader: a quiescent write in
    /// slot 0 is lapped by a racing writer while the reader snapshots.
    /// The reader may surface the stale event whole or skip the slot —
    /// never a mix — and `dropped()`/`recorded()` are exact afterwards.
    #[test]
    fn loom_model_dropped_counter_is_exact() {
        loom::model(|| {
            let rec = Arc::new(TraceRecorder::new(2));
            // Lands in slot 0 before the race starts (spawn orders it).
            rec.record(encoded(1));
            let writers: Vec<_> = (2..=3u64)
                .map(|id| {
                    let rec = Arc::clone(&rec);
                    thread::spawn(move || rec.record(encoded(id)))
                })
                .collect();
            // ordering: (test) Relaxed via recorded() — a monotonic
            // statistic; it may lag claims but never overcount.
            let mid = rec.recorded();
            assert!((1..=3).contains(&mid), "recorded() miscounted mid-race: {mid}");
            for e in rec.events() {
                assert_untorn(&e);
            }
            for h in writers {
                h.join().unwrap();
            }
            assert_eq!(rec.recorded(), 3);
            assert_eq!(rec.dropped(), 1, "dropped() undercounted");
            let evs = rec.events();
            let mut ids: Vec<u64> = evs.iter().map(|e| e.trace_id).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![2, 3], "lap must evict exactly the oldest event");
            for e in &evs {
                assert_untorn(e);
            }
        });
    }

    /// Detection power: the recorder's *previous* protocol — Relaxed
    /// field stores inside Release sequence flips — must be caught by
    /// the checker. Release on the sequence word alone does not stop a
    /// later relaxed field store from becoming visible before its own
    /// odd flip, so a reader holding a stale even sequence can pass both
    /// checks around a lapped, mixed snapshot. The model finds that
    /// interleaving; `record()` now stores fields with Release, which
    /// the two models above verify.
    #[test]
    #[should_panic(expected = "torn")]
    fn loom_model_all_relaxed_seqlock_is_torn() {
        loom::model(|| {
            let seq = Arc::new(AtomicU64::new(0));
            let data = Arc::new(AtomicU64::new(0));
            // Write A completes before the reader starts looking.
            seq.store(1, Ordering::Release);
            data.store(41, Ordering::Relaxed);
            seq.store(2, Ordering::Release);
            // Writer B laps the slot with the same (broken) protocol.
            let w = {
                let seq = Arc::clone(&seq);
                let data = Arc::clone(&data);
                thread::spawn(move || {
                    seq.store(3, Ordering::Release);
                    data.store(43, Ordering::Relaxed); // the original sin
                    seq.store(4, Ordering::Release);
                })
            };
            let s1 = seq.load(Ordering::Acquire);
            let v = data.load(Ordering::Acquire);
            let s2 = seq.load(Ordering::Acquire);
            if s1 == 2 && s2 == 2 {
                // Under the broken protocol the reader can observe B's
                // field value while both sequence checks still read A's
                // even value — a torn snapshot accepted as stable.
                assert_eq!(v, 41, "torn read accepted by relaxed-field seqlock");
            }
            w.join().unwrap();
        });
    }
}
