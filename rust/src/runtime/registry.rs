//! AOT artifact manifest (written by `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed `manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub din: usize,
    pub hidden: usize,
    pub classes: usize,
    pub bits: u32,
    pub w_terms: usize,
    pub a_terms: usize,
    pub batches: Vec<usize>,
    /// key → artifact file name
    pub artifacts: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        let need = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing numeric '{k}'"))
        };
        let batches = j
            .get("batches")
            .and_then(Json::as_arr)
            .context("manifest missing 'batches'")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing 'artifacts'")?
            .iter()
            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
            .collect();
        Ok(Manifest {
            din: need("din")?,
            hidden: need("hidden")?,
            classes: need("classes")?,
            bits: need("bits")? as u32,
            w_terms: need("w_terms")?,
            a_terms: need("a_terms")?,
            batches,
            artifacts,
        })
    }

    /// Pick the smallest exported batch size that fits `n` samples
    /// (the router pads up to it).
    pub fn batch_for(&self, n: usize) -> Option<usize> {
        self.batches.iter().copied().filter(|&b| b >= n).min().or_else(|| {
            self.batches.iter().copied().max() // chunk large requests
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "din": 256, "hidden": 64, "classes": 10, "bits": 4,
        "w_terms": 2, "a_terms": 3, "batches": [1, 8, 32],
        "artifacts": {"fp_mlp_b1": "fp_mlp_b1.hlo.txt"}
    }"#;

    #[test]
    fn parses_fields() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.din, 256);
        assert_eq!(m.batches, vec![1, 8, 32]);
        assert_eq!(m.artifacts["fp_mlp_b1"], "fp_mlp_b1.hlo.txt");
    }

    #[test]
    fn batch_for_picks_smallest_fitting() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.batch_for(1), Some(1));
        assert_eq!(m.batch_for(2), Some(8));
        assert_eq!(m.batch_for(8), Some(8));
        assert_eq!(m.batch_for(9), Some(32));
        assert_eq!(m.batch_for(33), Some(32)); // chunking case
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse(r#"{"din": 1}"#).is_err());
    }
}
