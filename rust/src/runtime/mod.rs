//! PJRT runtime: loads the HLO-text artifacts `python/compile/aot.py`
//! emits and executes them from Rust. Python is never on this path — the
//! artifacts are self-contained.
//!
//! Threading note: the `xla` crate's `PjRtClient` is `Rc`-based (not
//! `Send`), so a [`Runtime`] lives on one thread. The coordinator spawns
//! one runtime per worker thread (see `coordinator::pool`), which also
//! mirrors the paper's one-basis-model-per-device deployment.

pub mod literal;
pub mod registry;

pub use literal::{literal_to_tensor, tensor_to_literal};
pub use registry::Manifest;

use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled executable plus its artifact name.
pub struct Exec {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Exec {
    /// Execute with dense f32 inputs; returns the tuple elements.
    ///
    /// All AOT artifacts are lowered with `return_tuple=True`, so the
    /// single output literal is a tuple we decompose.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = result.to_tuple().context("decompose result tuple")?;
        parts.iter().map(literal_to_tensor).collect()
    }

    /// Single-output convenience.
    pub fn run1(&self, inputs: &[Tensor]) -> Result<Tensor> {
        let mut out = self.run(inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.pop().unwrap())
    }
}

/// One-thread PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Exec>>,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        log::info!(
            "PJRT platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Default artifact directory (env-overridable for tests).
    pub fn default_artifact_dir() -> PathBuf {
        PathBuf::from(
            std::env::var("FP_XINT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
        )
    }

    /// Load the AOT manifest from the artifact directory.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(self.artifact_dir.join("manifest.json"))
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&mut self, file_name: &str) -> Result<std::rc::Rc<Exec>> {
        if let Some(e) = self.cache.get(file_name) {
            return Ok(e.clone());
        }
        let path = self.artifact_dir.join(file_name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {file_name}"))?;
        let exec = std::rc::Rc::new(Exec { name: file_name.to_string(), exe });
        self.cache.insert(file_name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Load an artifact by manifest key (e.g. "xint_mlp_b8").
    pub fn load_key(&mut self, key: &str) -> Result<std::rc::Rc<Exec>> {
        let manifest = self.manifest()?;
        let file = manifest
            .artifacts
            .get(key)
            .with_context(|| format!("artifact key {key} not in manifest"))?
            .clone();
        self.load(&file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn artifacts_ready() -> bool {
        Runtime::default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn fp_mlp_artifact_matches_native_forward() {
        if !artifacts_ready() {
            log::warn!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::cpu(Runtime::default_artifact_dir()).unwrap();
        let manifest = rt.manifest().unwrap();
        let exec = rt.load_key("fp_mlp_b8").unwrap();
        let (din, hidden, classes) = (manifest.din, manifest.hidden, manifest.classes);
        let mut rng = Rng::seed(7);
        let x = Tensor::randn(&[8, din], 1.0, &mut rng);
        let w1 = Tensor::randn(&[hidden, din], 0.3, &mut rng);
        let b1 = Tensor::randn(&[hidden], 0.1, &mut rng);
        let w2 = Tensor::randn(&[classes, hidden], 0.3, &mut rng);
        let b2 = Tensor::randn(&[classes], 0.1, &mut rng);
        let y = exec
            .run1(&[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone()])
            .unwrap();
        // native reference
        let h = crate::tensor::matmul_a_bt(&x, &w1).add_row_bias(&b1).relu();
        let want = crate::tensor::matmul_a_bt(&h, &w2).add_row_bias(&b2);
        assert_eq!(y.dims(), want.dims());
        let rel = want.sub(&y).norm() / want.norm();
        assert!(rel < 1e-5, "PJRT vs native rel err {rel}");
    }

    #[test]
    fn quantize_artifact_matches_native_fake_quant() {
        if !artifacts_ready() {
            log::warn!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::cpu(Runtime::default_artifact_dir()).unwrap();
        let manifest = rt.manifest().unwrap();
        let exec = rt.load_key("quantize_act_b8").unwrap();
        let mut rng = Rng::seed(8);
        let x = Tensor::randn(&[8, manifest.din], 1.0, &mut rng);
        let half = 128.0f32;
        let scale = x.max_abs() / half;
        let y = exec.run1(&[x.clone(), Tensor::vec1(&[scale])]).unwrap();
        for (a, b) in x.data().iter().zip(y.data()) {
            let q = (a / scale).round().clamp(-half, half - 1.0) * scale;
            assert!((q - b).abs() < 1e-5, "{a}: {q} vs {b}");
        }
    }

    #[test]
    fn executable_cache_hits() {
        if !artifacts_ready() {
            log::warn!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::cpu(Runtime::default_artifact_dir()).unwrap();
        let a = rt.load_key("fp_mlp_b1").unwrap();
        let b = rt.load_key("fp_mlp_b1").unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b));
    }
}
