//! Tensor ⇄ `xla::Literal` marshalling.

use crate::tensor::Tensor;
use anyhow::{Context, Result};

/// Dense f32 tensor → XLA literal with the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
    flat.reshape(&dims).context("reshape literal")
}

/// XLA literal (f32 array) → dense tensor.
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal data")?;
    Ok(Tensor::from_vec(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip_preserves_shape_and_data() {
        let mut rng = Rng::seed(17);
        for dims in [vec![4usize], vec![2, 3], vec![2, 3, 4]] {
            let t = Tensor::rand(&dims, -1.0, 1.0, &mut rng);
            let l = tensor_to_literal(&t).unwrap();
            let back = literal_to_tensor(&l).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn scalar_like_shapes() {
        let t = Tensor::from_vec(&[1], vec![42.0]);
        let back = literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap();
        assert_eq!(back.data(), &[42.0]);
    }
}
