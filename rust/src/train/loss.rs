//! Losses: cross-entropy with softmax gradient.

use crate::tensor::Tensor;

/// Cross-entropy result: mean loss and dLoss/dlogits.
#[derive(Clone, Debug)]
pub struct CrossEntropy {
    pub loss: f32,
    pub dlogits: Tensor,
}

/// Mean cross-entropy over rows of `logits` (N, K) against `labels`.
pub fn cross_entropy(logits: &Tensor, labels: &[usize]) -> CrossEntropy {
    let (n, k) = (logits.dims()[0], logits.dims()[1]);
    assert_eq!(n, labels.len());
    let ls = logits.log_softmax_rows();
    let mut loss = 0.0f32;
    for (i, &y) in labels.iter().enumerate() {
        assert!(y < k, "label {y} out of range");
        loss -= ls.at(&[i, y]);
    }
    loss /= n as f32;
    let mut dlogits = logits.softmax_rows();
    for (i, &y) in labels.iter().enumerate() {
        dlogits.data_mut()[i * k + y] -= 1.0;
    }
    CrossEntropy { loss, dlogits: dlogits.scale(1.0 / n as f32) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_loss_is_log_k() {
        let logits = Tensor::zeros(&[4, 5]);
        let ce = cross_entropy(&logits, &[0, 1, 2, 3]);
        assert!((ce.loss - (5.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn grad_matches_fd() {
        let logits = Tensor::from_vec(&[2, 3], vec![0.5, -1.0, 2.0, 0.1, 0.2, -0.4]);
        let labels = [2usize, 0];
        let ce = cross_entropy(&logits, &labels);
        let eps = 1e-3;
        for i in 0..6 {
            let mut lp = logits.clone();
            lp.data_mut()[i] += eps;
            let mut lm = logits.clone();
            lm.data_mut()[i] -= eps;
            let fd = (cross_entropy(&lp, &labels).loss - cross_entropy(&lm, &labels).loss)
                / (2.0 * eps);
            assert!((fd - ce.dlogits.data()[i]).abs() < 1e-3, "idx {i}");
        }
    }

    #[test]
    fn perfect_prediction_low_loss() {
        let logits = Tensor::from_vec(&[1, 2], vec![20.0, -20.0]);
        let ce = cross_entropy(&logits, &[0]);
        assert!(ce.loss < 1e-5);
    }
}
