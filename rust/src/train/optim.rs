//! Optimizers over the `visit_params` protocol.
//!
//! Both optimizers are *stateful over visit order*: they identify a
//! parameter by its position in the deterministic `visit_params` walk,
//! which is stable for a fixed architecture.

use crate::tensor::Tensor;

/// Plain SGD with momentum and weight decay.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<Tensor>,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Sgd { lr, momentum: 0.9, weight_decay: 1e-4, velocity: Vec::new() }
    }

    /// One update pass; call inside `model.visit_params` via [`Sgd::step`].
    pub fn step(&mut self, visit: impl FnOnce(&mut dyn FnMut(&mut Tensor, &Tensor))) {
        let mut idx = 0usize;
        let lr = self.lr;
        let mu = self.momentum;
        let wd = self.weight_decay;
        let vel = &mut self.velocity;
        visit(&mut |p, g| {
            if vel.len() <= idx {
                vel.push(Tensor::zeros(p.dims()));
            }
            let v = &mut vel[idx];
            debug_assert_eq!(v.dims(), p.dims(), "param order changed");
            for ((vv, &gv), pv) in
                v.data_mut().iter_mut().zip(g.data()).zip(p.data().to_vec())
            {
                *vv = mu * *vv + gv + wd * pv;
            }
            for (pv, &vv) in p.data_mut().iter_mut().zip(v.data()) {
                *pv -= lr * vv;
            }
            idx += 1;
        });
    }
}

/// Adam with bias correction.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: i32,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    pub fn step(&mut self, visit: impl FnOnce(&mut dyn FnMut(&mut Tensor, &Tensor))) {
        self.t += 1;
        let t = self.t;
        let (b1, b2, eps, lr, wd) = (self.beta1, self.beta2, self.eps, self.lr, self.weight_decay);
        let bc1 = 1.0 - b1.powi(t);
        let bc2 = 1.0 - b2.powi(t);
        let mut idx = 0usize;
        let ms = &mut self.m;
        let vs = &mut self.v;
        visit(&mut |p, g| {
            if ms.len() <= idx {
                ms.push(Tensor::zeros(p.dims()));
                vs.push(Tensor::zeros(p.dims()));
            }
            let m = &mut ms[idx];
            let v = &mut vs[idx];
            for i in 0..p.numel() {
                let gv = g.data()[i] + wd * p.data()[i];
                m.data_mut()[i] = b1 * m.data()[i] + (1.0 - b1) * gv;
                v.data_mut()[i] = b2 * v.data()[i] + (1.0 - b2) * gv * gv;
                let mhat = m.data()[i] / bc1;
                let vhat = v.data()[i] / bc2;
                p.data_mut()[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
            idx += 1;
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(w) = ||w - target||² with each optimizer.
    fn quadratic_descent(opt: &mut dyn FnMut(&mut Tensor, &Tensor)) -> f32 {
        let target = Tensor::vec1(&[3.0, -2.0, 0.5]);
        let mut w = Tensor::zeros(&[3]);
        for _ in 0..300 {
            let g = w.sub(&target).scale(2.0);
            opt(&mut w, &g);
        }
        w.sub(&target).norm()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut sgd = Sgd::new(0.05);
        sgd.weight_decay = 0.0;
        let d = quadratic_descent(&mut |w, g| {
            sgd.step(|f| f(w, g));
        });
        assert!(d < 1e-3, "dist {d}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut adam = Adam::new(0.05);
        let d = quadratic_descent(&mut |w, g| {
            adam.step(|f| f(w, g));
        });
        assert!(d < 1e-2, "dist {d}");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut sgd = Sgd::new(0.1);
        sgd.momentum = 0.0;
        sgd.weight_decay = 0.5;
        let mut w = Tensor::vec1(&[1.0]);
        let zero_g = Tensor::vec1(&[0.0]);
        for _ in 0..10 {
            sgd.step(|f| f(&mut w, &zero_g));
        }
        assert!(w.data()[0] < 0.7, "decay not applied: {}", w.data()[0]);
    }
}
