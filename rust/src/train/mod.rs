//! From-scratch training substrate: losses, optimizers, training loops and
//! checkpoint caching. Every experiment quantizes a model trained here —
//! the "pretrained FP model" ingredient of PTQ.

pub mod loss;
pub mod optim;
pub mod trainer;

pub use loss::{cross_entropy, CrossEntropy};
pub use optim::{Adam, Sgd};
pub use trainer::{train_bert, train_classifier, train_lm, trained_model_cached, TrainConfig, TrainReport};
