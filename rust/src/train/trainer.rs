//! Training loops for the three model families + checkpoint caching.
//!
//! Benches call [`trained_model_cached`], which trains once per
//! (architecture, dataset, seed) and reuses the checkpoint from
//! `artifacts/checkpoints/` afterwards, so regenerating a table does not
//! re-train six CNNs every time.

use super::loss::cross_entropy;
use super::optim::Sgd;
use crate::datasets::{accuracy, SynthImg};
use crate::models::{serialize, Model, TinyBert, TinyLm};
use std::path::PathBuf;

/// Training hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 600, batch: 32, lr: 0.05, log_every: 100 }
    }
}

/// Loss-curve + final-accuracy report (the e2e example logs this).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, loss) samples
    pub loss_curve: Vec<(usize, f32)>,
    pub final_train_acc: f64,
    pub final_val_acc: f64,
}

/// Train a CNN/MLP classifier on the synthetic image task.
pub fn train_classifier(model: &mut Model, data: &SynthImg, cfg: &TrainConfig) -> TrainReport {
    let mut opt = Sgd::new(cfg.lr);
    let mut report = TrainReport::default();
    for step in 0..cfg.steps {
        let b = data.batch(cfg.batch, 1_000 + step as u64);
        model.zero_grad();
        let logits = model.forward_train(&b.x);
        let ce = cross_entropy(&logits, &b.y);
        model.backward(&ce.dlogits);
        opt.step(|f| model.visit_params(f));
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            report.loss_curve.push((step, ce.loss));
            log::debug!("{} step {step} loss {:.4}", model.name, ce.loss);
        }
    }
    let train = data.batch(256, 1);
    let val = data.batch(256, 2);
    report.final_train_acc = accuracy(&model.forward(&train.x), &train.y);
    report.final_val_acc = accuracy(&model.forward(&val.x), &val.y);
    report
}

/// Train a TinyBert on a token classification task (entailment) or span
/// task. `batches` yields (tokens, labels) where span labels are encoded
/// per-token (2·T classes handled by the caller via per-token CE).
pub fn train_bert(
    model: &mut TinyBert,
    mut next_batch: impl FnMut(usize) -> (Vec<Vec<usize>>, Vec<usize>),
    cfg: &TrainConfig,
) -> TrainReport {
    let mut opt = Sgd::new(cfg.lr);
    let mut report = TrainReport::default();
    for step in 0..cfg.steps {
        let (tokens, labels) = next_batch(step);
        model.zero_grad();
        let logits = model.forward_train(&tokens);
        let ce = cross_entropy(&logits, &labels);
        model.backward(&ce.dlogits);
        opt.step(|f| model.visit_params(f));
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            report.loss_curve.push((step, ce.loss));
            log::debug!("tinybert step {step} loss {:.4}", ce.loss);
        }
    }
    report
}

/// Train the char LM on a token stream with next-char cross entropy.
pub fn train_lm(model: &mut TinyLm, stream: &[usize], cfg: &TrainConfig) -> TrainReport {
    let mut opt = Sgd::new(cfg.lr);
    let mut report = TrainReport::default();
    let seq = model.seq;
    let vocab = crate::datasets::charlm::CHAR_VOCAB;
    let mut cursor = 0usize;
    for step in 0..cfg.steps {
        // batch of contiguous windows
        let mut tokens = Vec::with_capacity(cfg.batch);
        for _ in 0..cfg.batch {
            if cursor + seq + 1 >= stream.len() {
                cursor = (cursor * 7 + 13) % seq.max(1); // wrap with a shifting phase
            }
            tokens.push(stream[cursor..cursor + seq].to_vec());
            cursor += seq / 2 + 1;
        }
        model.zero_grad();
        let logits = model.forward_train(&tokens);
        // next-char CE at positions 0..seq-1
        let sm = logits.softmax_rows();
        let mut dl = sm.clone();
        let mut loss = 0.0f32;
        let ls = logits.log_softmax_rows();
        let mut count = 0.0f32;
        for (s, seq_toks) in tokens.iter().enumerate() {
            for p in 0..seq - 1 {
                let row = s * seq + p;
                let next = seq_toks[p + 1];
                loss -= ls.at(&[row, next]);
                dl.data_mut()[row * vocab + next] -= 1.0;
                count += 1.0;
            }
            // no target at the last position
            let row = s * seq + seq - 1;
            for j in 0..vocab {
                dl.data_mut()[row * vocab + j] = 0.0;
            }
        }
        loss /= count;
        model.backward(&dl.scale(1.0 / count));
        opt.step(|f| model.visit_params(f));
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            report.loss_curve.push((step, loss));
            log::debug!("tinylm step {step} loss {loss:.4}");
        }
    }
    report
}

/// Checkpoint directory (gitignored, lives with the AOT artifacts).
pub fn checkpoint_dir() -> PathBuf {
    let root = std::env::var("FP_XINT_CKPT_DIR")
        .unwrap_or_else(|_| "artifacts/checkpoints".to_string());
    PathBuf::from(root)
}

/// Train-once-and-cache: returns the model with trained weights and its
/// validation accuracy. `build` must deterministically construct the
/// architecture (same seed ⇒ same shapes).
pub fn trained_model_cached(
    tag: &str,
    build: impl Fn() -> Model,
    data: &SynthImg,
    cfg: &TrainConfig,
) -> (Model, f64) {
    let path = checkpoint_dir().join(format!("{tag}.fpxw"));
    let mut model = build();
    if path.exists() {
        if serialize::load_model(&path, &mut model).is_ok() {
            let val = data.batch(256, 2);
            let acc = accuracy(&model.forward(&val.x), &val.y);
            log::info!("loaded cached {tag} (val acc {:.2}%)", acc * 100.0);
            return (model, acc);
        }
        log::warn!("stale checkpoint {path:?}; retraining");
        model = build();
    }
    let report = train_classifier(&mut model, data, cfg);
    // one extra train-mode pass is NOT needed; BN running stats accumulated
    serialize::save_model(&path, &mut model).expect("save checkpoint");
    log::info!(
        "trained {tag}: train acc {:.2}% val acc {:.2}%",
        report.final_train_acc * 100.0,
        report.final_val_acc * 100.0
    );
    (model, report.final_val_acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SynthImg;
    use crate::models::zoo;

    #[test]
    fn classifier_learns_synthimg() {
        // small budget: must beat chance (10%) clearly
        let data = SynthImg::new(4, 1, 12, 0.15, 5);
        let mut m = zoo::mlp(144, &[32], 4, 6);
        let cfg = TrainConfig { steps: 150, batch: 32, lr: 0.08, log_every: 50 };
        let rep = train_classifier(&mut m, &data, &cfg);
        assert!(rep.loss_curve.len() >= 3);
        assert!(
            rep.final_val_acc > 0.5,
            "val acc {:.2} too low (chance 0.25)",
            rep.final_val_acc
        );
        // loss must decrease overall
        let first = rep.loss_curve.first().unwrap().1;
        let last = rep.loss_curve.last().unwrap().1;
        assert!(last < first * 0.8, "loss {first} -> {last}");
    }

    #[test]
    fn cnn_learns_synthimg() {
        let data = SynthImg::new(4, 1, 12, 0.15, 7);
        let mut m = zoo::mini_resnet_a(4, 8);
        let cfg = TrainConfig { steps: 120, batch: 24, lr: 0.05, log_every: 40 };
        let rep = train_classifier(&mut m, &data, &cfg);
        assert!(rep.final_val_acc > 0.5, "cnn val acc {:.2}", rep.final_val_acc);
    }

    #[test]
    fn cache_roundtrip() {
        let data = SynthImg::new(3, 1, 8, 0.1, 9);
        let cfg = TrainConfig { steps: 30, batch: 16, lr: 0.05, log_every: 10 };
        let tag = format!("test_cache_{}", std::process::id());
        let build = || zoo::mlp(64, &[16], 3, 10);
        let (_m1, acc1) = trained_model_cached(&tag, build, &data, &cfg);
        // second call loads the cache and reports the same accuracy
        let (_m2, acc2) = trained_model_cached(&tag, build, &data, &cfg);
        assert_eq!(acc1, acc2);
        std::fs::remove_file(checkpoint_dir().join(format!("{tag}.fpxw"))).ok();
    }
}
