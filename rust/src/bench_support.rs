//! Shared harness for the table/figure benches (`rust/benches/`).
//!
//! Criterion is unavailable offline, so every bench is a `harness = false`
//! binary that prints the paper-style table via [`crate::util::Table`].
//! This module centralizes the trained-model suite (cached checkpoints),
//! the method registry, and the evaluation loop so each bench stays
//! focused on its table's rows.

use crate::baselines::{self, PtqMethod};
use crate::datasets::{accuracy, SynthImg};
use crate::models::{quantized, zoo, Model};
use crate::train::{trained_model_cached, TrainConfig};
use crate::util::json::Json;
use crate::xint::layer::LayerPolicy;
use std::path::PathBuf;

/// Where `BENCH_<tag>.json` files land: `$BENCH_JSON_DIR` when set,
/// else the current working directory.
pub fn bench_json_path(tag: &str) -> PathBuf {
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    PathBuf::from(dir).join(format!("BENCH_{tag}.json"))
}

/// Write a machine-trackable benchmark result (`BENCH_<tag>.json`) so
/// the perf trajectory is comparable across PRs; returns the path.
pub fn write_bench_json(tag: &str, json: &Json) -> std::io::Result<PathBuf> {
    let path = bench_json_path(tag);
    std::fs::write(&path, json.render())?;
    Ok(path)
}

/// The standard benchmark dataset (ImageNet stand-in).
pub fn bench_data() -> SynthImg {
    SynthImg::standard(42)
}

/// Harder variant for the ablation benches: more noise so FP accuracy
/// sits below 100% and quantization effects are visible (the standard
/// task saturates the bigger zoo models).
pub fn bench_data_hard() -> SynthImg {
    SynthImg::new(10, 1, 16, 0.55, 43)
}

/// Train (or load cached) on the hard dataset.
pub fn trained_hard(tag: &str, build: fn() -> Model) -> (Model, f64) {
    let data = bench_data_hard();
    let cfg = TrainConfig { steps: 500, batch: 32, lr: 0.05, log_every: 1_000 };
    let (m, acc) = trained_model_cached(&format!("{tag}_hard"), build, &data, &cfg);
    (m, acc * 100.0)
}

/// Ours on an explicit dataset.
pub fn ours_acc_on(
    data: &SynthImg,
    model: &Model,
    w_bits: u32,
    a_bits: u32,
    k: usize,
    t: usize,
) -> f64 {
    let val = data.batch(512, 2);
    let q = quantized::quantize_model(model, LayerPolicy::new(w_bits, a_bits).with_terms(k, t));
    accuracy(&q.forward(&val.x), &val.y) * 100.0
}

/// Baseline on an explicit dataset.
pub fn baseline_acc_on(
    data: &SynthImg,
    model: &Model,
    method: &dyn PtqMethod,
    w_bits: u32,
    a_bits: u32,
) -> f64 {
    let val = data.batch(512, 2);
    let calib = data.batch(32, 3).x;
    let q = method.quantize(model, w_bits, a_bits, &calib);
    accuracy(&q.forward(&val.x), &val.y) * 100.0
}

/// Table-1 suite: (paper name, stand-in tag, builder).
pub fn suite() -> Vec<(&'static str, &'static str, fn() -> Model)> {
    vec![
        ("ResNet-18", "mini_resnet_a", (|| zoo::mini_resnet_a(10, 1)) as fn() -> Model),
        ("ResNet-34", "mini_resnet_b", || zoo::mini_resnet_b(10, 2)),
        ("ResNet-50", "mini_resnet_c", || zoo::mini_resnet_c(10, 3)),
        ("ResNet-101", "mini_resnet_d", || zoo::mini_resnet_d(10, 4)),
        ("RegNetX-600MF", "regnet_style", || zoo::regnet_style(10, 5)),
        ("Inception-V3", "inception_style", || zoo::inception_style(10, 6)),
    ]
}

/// MobileNet stand-in (Table 3's second block).
pub fn mobilenet() -> (&'static str, &'static str, fn() -> Model) {
    ("MobileNetV2", "mobilenet_style", || zoo::mobilenet_style(10, 7))
}

/// Train (or load the cached) model; returns (model, fp val accuracy %).
pub fn trained(tag: &str, build: fn() -> Model) -> (Model, f64) {
    let data = bench_data();
    let cfg = TrainConfig { steps: 400, batch: 32, lr: 0.05, log_every: 1_000 };
    let (m, acc) = trained_model_cached(tag, build, &data, &cfg);
    (m, acc * 100.0)
}

/// Accuracy (%) of the paper's series-expansion PTQ at (w_bits, a_bits).
pub fn ours_acc(model: &Model, w_bits: u32, a_bits: u32) -> f64 {
    ours_acc_terms(model, w_bits, a_bits, 2, 4)
}

/// Ours with explicit term counts.
pub fn ours_acc_terms(model: &Model, w_bits: u32, a_bits: u32, k: usize, t: usize) -> f64 {
    let data = bench_data();
    let val = data.batch(512, 2);
    let q = quantized::quantize_model(model, LayerPolicy::new(w_bits, a_bits).with_terms(k, t));
    accuracy(&q.forward(&val.x), &val.y) * 100.0
}

/// Accuracy (%) of a baseline method at (w_bits, a_bits).
pub fn baseline_acc(model: &Model, method: &dyn PtqMethod, w_bits: u32, a_bits: u32) -> f64 {
    let data = bench_data();
    let val = data.batch(512, 2);
    let calib = data.batch(32, 3).x;
    let q = method.quantize(model, w_bits, a_bits, &calib);
    accuracy(&q.forward(&val.x), &val.y) * 100.0
}

/// The baseline registry used across tables.
pub fn methods() -> Vec<Box<dyn PtqMethod>> {
    vec![
        Box::new(baselines::Rtn),
        Box::new(baselines::Aciq),
        Box::new(baselines::MseClip),
        Box::new(baselines::BiasCorr),
        Box::new(baselines::AdaQuant::default()),
        Box::new(baselines::Lapq::default()),
    ]
}

/// Format a percentage cell.
pub fn pct(v: f64) -> String {
    format!("{v:.2}")
}

/// Paper-vs-measured footnote helper: benches print the paper's numbers
/// for orientation; absolute values are not expected to match (different
/// substrate), the *shape* is (see EXPERIMENTS.md).
pub fn shape_note() {
    log::info!(
        "note: absolute numbers come from the synthetic substrate (DESIGN.md §2); \
         compare SHAPE against the paper — who wins, by roughly what factor, \
         where methods collapse. Paper values are recorded in EXPERIMENTS.md."
    );
}
