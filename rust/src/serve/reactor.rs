//! Reactor substrate for the nonblocking serving plane: a minimal
//! readiness poller (epoll on Linux via a sanctioned FFI island, a
//! timeout-driven fallback elsewhere), a cross-thread wake pipe, and
//! the [`WakeLatch`]/[`WakeQueue`] handoff that carries scheduler
//! completions into the reactor thread without locks on the wake path.
//!
//! Ownership model: the reactor thread owns ALL connection state.
//! Scheduler-side completion sinks only push onto a [`WakeQueue`] and
//! (when the latch says so) write one byte to the [`Waker`] pipe; the
//! reactor drains the pipe, re-opens the wake window, and drains the
//! queue. The latch protocol is loom-modeled below
//! (`loom_model_wake_latch_never_strands_a_completion`) and stressed
//! under TSan in `tests/stress_sync.rs`; see CONCURRENCY.md.

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Mutex;
use std::collections::VecDeque;

/// Coalescing wake flag between completion producers and the reactor.
///
/// Producer: push your item, then call [`notify`](WakeLatch::notify) —
/// a `true` return means you must emit a wake signal (the pipe byte);
/// `false` means a signal is already in flight and covers your push.
/// Consumer: after consuming a wake signal, call
/// [`begin_drain`](WakeLatch::begin_drain) BEFORE draining the queue,
/// so a producer racing the drain either lands in it or wins a fresh
/// `notify` and emits the next signal. The buggy order (drain, then
/// clear) strands exactly the completion the loom model pins.
pub struct WakeLatch(AtomicBool);

impl WakeLatch {
    pub fn new() -> Self {
        WakeLatch(AtomicBool::new(false))
    }

    /// Producer side. Returns true when the caller must emit a wake
    /// signal.
    pub fn notify(&self) -> bool {
        // ordering: AcqRel — the Release half orders the caller's queue
        // push before this latch write, so the consumer's begin_drain
        // RMW (which reads the newest store) acquires it; the Acquire
        // half symmetrically picks up the consumer's window flip.
        !self.0.swap(true, Ordering::AcqRel)
    }

    /// Consumer side: open the next wake window. MUST run before the
    /// queue drain it guards.
    pub fn begin_drain(&self) {
        // ordering: AcqRel — deliberately an RMW, not a plain store: an
        // RMW reads the newest store in modification order, so it
        // synchronizes with the Release swap of every producer that
        // latched before this drain — including one whose notify()
        // returned false and therefore emitted no wake byte — making
        // that producer's queue push visible to the drain that follows.
        // A plain store would create no edge to that producer.
        let _ = self.0.swap(false, Ordering::AcqRel);
    }
}

impl Default for WakeLatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Multi-producer completion queue with a coalesced wake contract.
pub struct WakeQueue<T> {
    q: Mutex<VecDeque<T>>,
    latch: WakeLatch,
}

impl<T> WakeQueue<T> {
    pub fn new() -> Self {
        WakeQueue { q: Mutex::new(VecDeque::new()), latch: WakeLatch::new() }
    }

    /// Push one item. Returns true when the caller must emit a wake
    /// signal ([`Waker::signal`]).
    pub fn push(&self, item: T) -> bool {
        // a poisoned queue still holds coherent completions (pushes are
        // single appends); recover rather than cascade the panic
        self.q.lock().unwrap_or_else(|p| p.into_inner()).push_back(item);
        self.latch.notify()
    }

    /// Consumer side: open the next wake window, then take everything
    /// queued. Runs on the reactor thread after the pipe is drained.
    pub fn drain(&self) -> Vec<T> {
        self.latch.begin_drain();
        let mut q = self.q.lock().unwrap_or_else(|p| p.into_inner());
        q.drain(..).collect()
    }
}

impl<T> Default for WakeQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Producer half of the wake pipe (one byte per granted `notify`).
pub struct Waker {
    #[cfg(unix)]
    tx: std::os::unix::net::UnixStream,
}

/// Reactor half of the wake pipe: register its fd for readability and
/// [`clear`](WakeReceiver::clear) it on wakeup.
pub struct WakeReceiver {
    #[cfg(unix)]
    rx: std::os::unix::net::UnixStream,
}

impl Waker {
    pub fn pair() -> std::io::Result<(Waker, WakeReceiver)> {
        #[cfg(unix)]
        {
            let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
            tx.set_nonblocking(true)?;
            rx.set_nonblocking(true)?;
            Ok((Waker { tx }, WakeReceiver { rx }))
        }
        #[cfg(not(unix))]
        {
            // no pipe: the fallback poller's timeout bounds wake latency
            Ok((Waker {}, WakeReceiver {}))
        }
    }

    /// Emit one wake byte. Call only when [`WakeQueue::push`] returned
    /// true (or to force a reactor wakeup, e.g. on shutdown).
    pub fn signal(&self) {
        #[cfg(unix)]
        {
            use std::io::Write;
            // `impl Write for &UnixStream` lets many producer threads
            // write without a lock; a full pipe is fine — WouldBlock
            // means a byte is already in flight.
            let _ = (&self.tx).write(&[1u8]);
        }
    }
}

impl WakeReceiver {
    /// The fd to register for readability (-1 on non-unix targets,
    /// where the fallback poller's timeout stands in for the pipe).
    pub fn raw_fd(&self) -> i32 {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            self.rx.as_raw_fd()
        }
        #[cfg(not(unix))]
        {
            -1
        }
    }

    /// Drain pending wake bytes. Run before [`WakeQueue::drain`].
    pub fn clear(&mut self) {
        #[cfg(unix)]
        {
            use std::io::Read;
            let mut buf = [0u8; 64];
            while matches!(self.rx.read(&mut buf), Ok(k) if k > 0) {}
        }
    }
}

/// One readiness event from [`Poller::poll`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Minimal epoll FFI. The crate denies `unsafe_code`; this module is
/// the second sanctioned island (after `xint::kernel::micro`): four
/// syscall wrappers, linked through std's own libc dependency, with no
/// pointer lifetime subtleties — the kernel copies every struct we
/// pass during the call.
#[cfg(target_os = "linux")]
#[allow(unsafe_code)]
mod sys {
    pub const EPOLL_CLOEXEC: i32 = 0x8_0000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;

    /// x86_64 layout: 12 bytes, packed (the kernel ABI's struct).
    /// Packed fields must be copied out, never referenced.
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Epoll {
        fd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Epoll {
        pub fn new(capacity: usize) -> std::io::Result<Epoll> {
            // SAFETY: plain syscall, no pointers.
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Epoll { fd, buf: vec![EpollEvent { events: 0, data: 0 }; capacity.max(64)] })
        }

        pub fn ctl(&self, op: i32, fd: i32, events: u32, token: u64) -> std::io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            // SAFETY: `ev` is live for the duration of the call; the
            // kernel copies it and keeps no reference.
            let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<super::Event>,
            timeout_ms: i32,
        ) -> std::io::Result<()> {
            out.clear();
            // SAFETY: `buf` is a live writable array of `buf.len()`
            // events for the duration of the call.
            let n = unsafe {
                epoll_wait(self.fd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms)
            };
            if n < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in self.buf.iter().take(n as usize) {
                // copy fields out of the packed struct before use
                let (es, token) = (ev.events, ev.data);
                out.push(super::Event {
                    token,
                    readable: es & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: es & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            // SAFETY: the fd is owned by this struct and closed once.
            unsafe { close(self.fd) };
        }
    }
}

/// Readiness poller: level-triggered epoll on Linux.
#[cfg(target_os = "linux")]
pub struct Poller {
    ep: sys::Epoll,
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> std::io::Result<Poller> {
        Ok(Poller { ep: sys::Epoll::new(1024)? })
    }

    fn interest(read: bool, write: bool) -> u32 {
        let mut ev = 0;
        if read {
            ev |= sys::EPOLLIN;
        }
        if write {
            ev |= sys::EPOLLOUT;
        }
        ev
    }

    pub fn register(
        &mut self,
        fd: i32,
        token: u64,
        read: bool,
        write: bool,
    ) -> std::io::Result<()> {
        self.ep.ctl(sys::EPOLL_CTL_ADD, fd, Self::interest(read, write), token)
    }

    pub fn reregister(
        &mut self,
        fd: i32,
        token: u64,
        read: bool,
        write: bool,
    ) -> std::io::Result<()> {
        self.ep.ctl(sys::EPOLL_CTL_MOD, fd, Self::interest(read, write), token)
    }

    pub fn deregister(&mut self, fd: i32, token: u64) -> std::io::Result<()> {
        self.ep.ctl(sys::EPOLL_CTL_DEL, fd, 0, token)
    }

    /// Wait for readiness; `timeout_ms < 0` blocks indefinitely.
    pub fn poll(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> std::io::Result<()> {
        self.ep.wait(out, timeout_ms)
    }
}

/// Fallback poller for non-Linux targets: registration is by token
/// only; `poll` sleeps briefly and reports every registered token ready
/// at its registered interest. Spurious readiness composes with the
/// level-triggered, WouldBlock-tolerant connection state machines —
/// correctness is preserved, efficiency is Linux-only.
#[cfg(not(target_os = "linux"))]
pub struct Poller {
    interests: std::collections::HashMap<u64, (bool, bool)>,
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    pub fn new() -> std::io::Result<Poller> {
        Ok(Poller { interests: std::collections::HashMap::new() })
    }

    pub fn register(
        &mut self,
        _fd: i32,
        token: u64,
        read: bool,
        write: bool,
    ) -> std::io::Result<()> {
        self.interests.insert(token, (read, write));
        Ok(())
    }

    pub fn reregister(
        &mut self,
        _fd: i32,
        token: u64,
        read: bool,
        write: bool,
    ) -> std::io::Result<()> {
        self.interests.insert(token, (read, write));
        Ok(())
    }

    pub fn deregister(&mut self, _fd: i32, token: u64) -> std::io::Result<()> {
        self.interests.remove(&token);
        Ok(())
    }

    pub fn poll(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> std::io::Result<()> {
        out.clear();
        let cap_ms = if self.interests.is_empty() { 10 } else { 1 };
        let sleep_ms = if timeout_ms < 0 { cap_ms } else { (timeout_ms as u64).min(cap_ms) };
        crate::util::sync::thread::sleep(std::time::Duration::from_millis(sleep_ms));
        for (&token, &(read, write)) in &self.interests {
            out.push(Event { token, readable: read, writable: write });
        }
        Ok(())
    }
}

/// The fd of a socket-like object for poller registration (-1 off-unix,
/// where the fallback poller ignores fds anyway).
#[cfg(unix)]
pub fn raw_fd<T: std::os::unix::io::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}

#[cfg(not(unix))]
pub fn raw_fd<T>(_t: &T) -> i32 {
    -1
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn latch_coalesces_until_drained() {
        let l = WakeLatch::new();
        assert!(l.notify(), "first notify wins the wake");
        assert!(!l.notify(), "second notify coalesces");
        l.begin_drain();
        assert!(l.notify(), "post-drain notify wins again");
    }

    #[test]
    fn wake_queue_drains_everything_pushed() {
        let q = WakeQueue::new();
        assert!(q.push(1u32), "first push asks for a signal");
        assert!(!q.push(2), "second push coalesces");
        assert_eq!(q.drain(), vec![1, 2]);
        assert!(q.drain().is_empty());
        assert!(q.push(3), "drained window re-arms the signal");
    }

    #[test]
    fn waker_pipe_roundtrip() {
        let (waker, mut rx) = Waker::pair().unwrap();
        waker.signal();
        waker.signal();
        rx.clear(); // must not block with bytes pending or after drain
        rx.clear();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn poller_sees_wake_pipe_readability() {
        let (waker, rx) = Waker::pair().unwrap();
        let mut p = Poller::new().unwrap();
        p.register(rx.raw_fd(), 7, true, false).unwrap();
        let mut evs = Vec::new();
        p.poll(&mut evs, 0).unwrap();
        assert!(evs.is_empty(), "no readiness before the signal");
        waker.signal();
        p.poll(&mut evs, 1000).unwrap();
        assert!(evs.iter().any(|e| e.token == 7 && e.readable));
    }
}

/// Loom model for the wake-latch handoff. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_model_`
/// (see CONCURRENCY.md).
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use crate::util::sync::atomic::AtomicUsize;
    use crate::util::sync::{thread, Arc};

    /// Two producers race the consumer through the latch protocol. A
    /// wake "byte" is modeled as a Release increment the consumer
    /// acquires before each drain pass; after the producers join, each
    /// unconsumed byte buys exactly one more drain — the reactor's
    /// epoll loop does the same. Every pushed completion must surface.
    /// Reversing `begin_drain` and the queue take (drain-then-clear)
    /// strands a completion pushed between them whose `notify` lost,
    /// and this model finds that interleaving.
    #[test]
    fn loom_model_wake_latch_never_strands_a_completion() {
        loom::model(|| {
            let q = Arc::new(WakeQueue::new());
            let wakes = Arc::new(AtomicUsize::new(0));
            let producers: Vec<_> = (0..2u64)
                .map(|p| {
                    let q = Arc::clone(&q);
                    let wakes = Arc::clone(&wakes);
                    thread::spawn(move || {
                        if q.push(p) {
                            // ordering: Release — models the wake-pipe
                            // byte the consumer acquires before its
                            // drain pass.
                            wakes.fetch_add(1, Ordering::Release);
                        }
                    })
                })
                .collect();
            let consumer = {
                let q = Arc::clone(&q);
                let wakes = Arc::clone(&wakes);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut seen = 0usize;
                    for _ in 0..3 {
                        // ordering: Acquire — pairs with the producer's
                        // Release byte; a seen byte licenses one drain.
                        let w = wakes.load(Ordering::Acquire);
                        if w > seen {
                            seen = w;
                            got.append(&mut q.drain());
                        }
                        thread::yield_now();
                    }
                    (got, seen)
                })
            };
            for h in producers {
                h.join().expect("producer panicked");
            }
            let (mut got, mut seen) = consumer.join().expect("consumer panicked");
            // ordering: Acquire — final settle: observe every byte
            // emitted before the joins completed.
            let w = wakes.load(Ordering::Acquire);
            while seen < w {
                seen += 1;
                got.append(&mut q.drain());
            }
            got.sort_unstable();
            assert_eq!(got, vec![0, 1], "completion stranded without a wake signal");
        });
    }
}
