//! Model-backed [`BasisWorker`] implementations.
//!
//! Three execution backends, all plugging into the same coordinator:
//!
//! * [`QuantModelWorker`] — replication mode: each worker runs the whole
//!   layer-sync quantized model (accuracy-bearing mode; parallelism over
//!   *requests* comes from the batcher).
//! * [`mlp_basis_factory`] — Theorem-2 mode for the MLP: worker `i` holds
//!   term `i` of every layer's weight expansion; outputs AbelianAdd into
//!   the full prediction (the nonlinearity-interchange error is measured
//!   in EXPERIMENTS.md).
//! * [`PjrtMlpWorker`] — the same basis slice but executed through the
//!   AOT-compiled PJRT artifact (one PJRT client per worker thread).

use crate::coordinator::pool::{BasisWorker, BudgetedRun, WorkerFactory};
use crate::models::quantized::QuantModel;
use crate::tensor::Tensor;
use crate::util::sync::Arc;
use crate::xint::budget::BudgetPlan;
use crate::xint::expansion::{ExpandConfig, SeriesExpansion};
use crate::xint::quantizer::{channel_range, fake_quant, Clip, Symmetry};
use crate::xint::BitSpec;

/// The plain FP MLP weights exported to workers.
#[derive(Clone, Debug)]
pub struct MlpWeights {
    pub w1: Tensor,
    pub b1: Tensor,
    pub w2: Tensor,
    pub b2: Tensor,
}

/// Whole-quantized-model worker (replication mode). The input is assumed
/// to be a flattened (n, din) batch; image models reshape internally.
pub struct QuantModelWorker {
    pub model: QuantModel,
    /// reshape target per sample, e.g. [1, 16, 16] for image models
    pub sample_dims: Option<Vec<usize>>,
}

impl QuantModelWorker {
    fn shaped(&self, x: &Tensor) -> Tensor {
        match &self.sample_dims {
            Some(sd) => {
                let n = x.dims()[0];
                let mut dims = vec![n];
                dims.extend_from_slice(sd);
                x.reshape(&dims)
            }
            None => x.clone(),
        }
    }
}

impl BasisWorker for QuantModelWorker {
    fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
        let x = self.shaped(x);
        Ok(self.model.forward(&x))
    }

    /// Replication mode is where the budget plan bites: the whole
    /// layer-sync model truncates every expanded layer's Eq. 3 grid to
    /// the plan entry at its depth-first position (8-bit first/last
    /// layers stay exact) and reports the INT GEMMs actually executed.
    fn run_budgeted(&mut self, x: &Tensor, plan: &BudgetPlan) -> anyhow::Result<BudgetedRun> {
        let x = self.shaped(x);
        let (y, stats, layer_traces) = self.model.forward_traced(&x, plan);
        debug_assert_eq!(stats.layers, layer_traces.len());
        Ok(BudgetedRun { y, grid_terms: stats.grid_terms, layer_traces })
    }
}

/// One Theorem-2 basis slice of a 2-layer MLP: term `i` of each weight
/// expansion, activations quantized at one step, biases divided by the
/// basis count (the paper's "copy other layers and multiply 1/t²").
pub struct MlpBasisSlice {
    w1_term: Tensor,
    w2_term: Tensor,
    b1_frac: Tensor,
    b2_frac: Tensor,
    act_bits: u32,
}

impl MlpBasisSlice {
    fn quant_act(&self, x: &Tensor) -> Tensor {
        let r = channel_range(x.data(), Symmetry::Symmetric, Clip::None, self.act_bits);
        Tensor::from_vec(x.dims(), fake_quant(x.data(), r, BitSpec::int(self.act_bits)))
    }
}

impl BasisWorker for MlpBasisSlice {
    fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
        let a = self.quant_act(x);
        let h = crate::tensor::matmul_a_bt(&a, &self.w1_term)
            .add_row_bias(&self.b1_frac)
            .relu();
        let a2 = self.quant_act(&h);
        Ok(crate::tensor::matmul_a_bt(&a2, &self.w2_term).add_row_bias(&self.b2_frac))
    }
}

/// Where the FP biases live across the basis slices.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BiasPlacement {
    /// The paper's replication policy: every slice carries `b/terms`,
    /// so only the *full* reduction recovers the exact bias mass.
    #[default]
    Split,
    /// The whole bias rides slice 0; later slices carry zero bias. Any
    /// ⊎ prefix then carries the exact bias mass — the layout QoS
    /// truncation wants. At full reduction the two placements agree up
    /// to the nonlinearity-interchange error (both sum to the same
    /// bias), but under truncation `Split` loses `(t−n)/t` of the bias
    /// while `FirstTerm` loses none.
    FirstTerm,
}

/// Build the Theorem-2 worker factory: `terms` basis slices, slice `i`
/// carrying term `i` of both layers' expansions.
pub fn mlp_basis_factory(weights: &MlpWeights, bits: u32, terms: usize) -> WorkerFactory {
    mlp_basis_factory_with(weights, bits, terms, BiasPlacement::Split)
}

/// [`mlp_basis_factory`] with an explicit bias placement.
pub fn mlp_basis_factory_with(
    weights: &MlpWeights,
    bits: u32,
    terms: usize,
    bias: BiasPlacement,
) -> WorkerFactory {
    let cfg = ExpandConfig::symmetric(BitSpec::int(bits), terms);
    let e1 = SeriesExpansion::expand(&weights.w1, &cfg);
    let e2 = SeriesExpansion::expand(&weights.w2, &cfg);
    let bias_for = |b: &Tensor, i: usize| match bias {
        BiasPlacement::Split => b.scale(1.0 / terms as f32),
        BiasPlacement::FirstTerm if i == 0 => b.clone(),
        BiasPlacement::FirstTerm => b.scale(0.0),
    };
    let slices: Vec<MlpBasisSlice> = (0..terms)
        .map(|i| MlpBasisSlice {
            w1_term: e1.term_tensor(i),
            w2_term: e2.term_tensor(i),
            b1_frac: bias_for(&weights.b1, i),
            b2_frac: bias_for(&weights.b2, i),
            act_bits: bits,
        })
        .collect();
    let slices = Arc::new(slices);
    Arc::new(move |i: usize| {
        let s = &slices[i];
        Box::new(MlpBasisSlice {
            w1_term: s.w1_term.clone(),
            w2_term: s.w2_term.clone(),
            b1_frac: s.b1_frac.clone(),
            b2_frac: s.b2_frac.clone(),
            act_bits: s.act_bits,
        }) as Box<dyn BasisWorker>
    })
}

/// PJRT-backed basis worker: executes the `basis_mlp_b{N}` artifact with
/// this slice's weight plane. Constructed inside the worker thread (the
/// PJRT client is not Send) via [`pjrt_mlp_basis_factory`].
pub struct PjrtMlpWorker {
    runtime: crate::runtime::Runtime,
    exec_by_batch: std::collections::HashMap<usize, std::rc::Rc<crate::runtime::Exec>>,
    batches: Vec<usize>,
    w1_plane: Tensor,
    w1_scale: Tensor,
    w2_plane: Tensor,
    w2_scale: Tensor,
    b1_frac: Tensor,
    b2_frac: Tensor,
    din: usize,
}

impl PjrtMlpWorker {
    pub fn new(
        artifact_dir: std::path::PathBuf,
        w1_plane: Tensor,
        w1_scale: f32,
        w2_plane: Tensor,
        w2_scale: f32,
        b1_frac: Tensor,
        b2_frac: Tensor,
    ) -> anyhow::Result<PjrtMlpWorker> {
        let mut runtime = crate::runtime::Runtime::cpu(&artifact_dir)?;
        let manifest = runtime.manifest()?;
        let mut exec_by_batch = std::collections::HashMap::new();
        for &b in &manifest.batches {
            exec_by_batch.insert(b, runtime.load_key(&format!("basis_mlp_b{b}"))?);
        }
        Ok(PjrtMlpWorker {
            runtime,
            exec_by_batch,
            batches: manifest.batches.clone(),
            // artifacts expect planes with a leading term axis of 1
            w1_plane,
            w1_scale: Tensor::vec1(&[w1_scale]),
            w2_plane,
            w2_scale: Tensor::vec1(&[w2_scale]),
            b1_frac,
            b2_frac,
            din: manifest.din,
        })
    }
}

impl BasisWorker for PjrtMlpWorker {
    fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
        let _ = &self.runtime; // keeps the client alive alongside execs
        let n = x.dims()[0];
        anyhow::ensure!(x.dims()[1] == self.din, "din mismatch");
        // route to the smallest artifact batch ≥ n, padding with zeros
        let target = self
            .batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| anyhow::anyhow!("request larger than max artifact batch"))?;
        let exec = self.exec_by_batch[&target].clone();
        let mut xp = Tensor::zeros(&[target, self.din]);
        xp.data_mut()[..n * self.din].copy_from_slice(x.data());
        let y = exec.run1(&[
            xp,
            self.w1_plane.clone(),
            self.w1_scale.clone(),
            self.b1_frac.clone(),
            self.w2_plane.clone(),
            self.w2_scale.clone(),
            self.b2_frac.clone(),
        ])?;
        // strip padding rows
        let classes = y.dims()[1];
        Ok(Tensor::from_vec(&[n, classes], y.data()[..n * classes].to_vec()))
    }
}

/// Factory producing PJRT basis workers — slice `i` of the expansions.
pub fn pjrt_mlp_basis_factory(
    artifact_dir: std::path::PathBuf,
    weights: &MlpWeights,
    bits: u32,
    terms: usize,
) -> WorkerFactory {
    let cfg = ExpandConfig::symmetric(BitSpec::int(bits), terms);
    let e1 = SeriesExpansion::expand(&weights.w1, &cfg);
    let e2 = SeriesExpansion::expand(&weights.w2, &cfg);
    let hidden = weights.w1.dims()[0];
    let din = weights.w1.dims()[1];
    let classes = weights.w2.dims()[0];
    let payload: Vec<(Tensor, f32, Tensor, f32)> = (0..terms)
        .map(|i| {
            (
                e1.planes[i].to_f32().reshaped(&[1, hidden, din]),
                e1.scales[i][0],
                e2.planes[i].to_f32().reshaped(&[1, classes, hidden]),
                e2.scales[i][0],
            )
        })
        .collect();
    let payload = Arc::new(payload);
    let b1 = weights.b1.scale(1.0 / terms as f32);
    let b2 = weights.b2.scale(1.0 / terms as f32);
    Arc::new(move |i: usize| {
        let (w1p, w1s, w2p, w2s) = payload[i].clone();
        Box::new(
            PjrtMlpWorker::new(
                artifact_dir.clone(),
                w1p,
                w1s,
                w2p,
                w2s,
                b1.clone(),
                b2.clone(),
            )
            .expect("construct PJRT worker"),
        ) as Box<dyn BasisWorker>
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool};
    use crate::tensor::Rng;

    fn mlp_weights(seed: u64) -> MlpWeights {
        let mut rng = Rng::seed(seed);
        MlpWeights {
            w1: Tensor::randn(&[16, 32], 0.3, &mut rng),
            b1: Tensor::randn(&[16], 0.1, &mut rng),
            w2: Tensor::randn(&[10, 16], 0.3, &mut rng),
            b2: Tensor::randn(&[10], 0.1, &mut rng),
        }
    }

    fn fp_forward(w: &MlpWeights, x: &Tensor) -> Tensor {
        let h = crate::tensor::matmul_a_bt(x, &w.w1).add_row_bias(&w.b1).relu();
        crate::tensor::matmul_a_bt(&h, &w.w2).add_row_bias(&w.b2)
    }

    #[test]
    fn basis_slices_reduce_close_to_fp() {
        let w = mlp_weights(51);
        let terms = 4;
        let pool = WorkerPool::new(terms, mlp_basis_factory(&w, 8, terms));
        let sched = ExpansionScheduler::new(pool);
        let mut rng = Rng::seed(52);
        let x = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let y = sched.forward(x.clone()).unwrap();
        let fp = fp_forward(&w, &x);
        // model-parallel mode has nonlinearity-interchange error; with
        // 8-bit terms it must still track FP closely enough to rank classes
        let rel = fp.sub(&y).norm() / fp.norm();
        assert!(rel < 0.5, "basis AllReduce rel err {rel}");
        sched.shutdown();
    }

    #[test]
    fn full_coordinator_with_basis_workers() {
        let w = mlp_weights(53);
        let terms = 3;
        let pool = WorkerPool::new(terms, mlp_basis_factory(&w, 8, terms));
        let sched = ExpansionScheduler::new(pool);
        let coord = Coordinator::new(BatcherConfig::uniform(8, 500, 32), sched);
        let mut rng = Rng::seed(54);
        for _ in 0..4 {
            let x = Tensor::randn(&[2, 32], 1.0, &mut rng);
            let resp = coord.infer(x).unwrap();
            assert_eq!(resp.logits.dims(), &[2, 10]);
        }
        assert_eq!(coord.metrics.completed(), 4);
        coord.shutdown();
    }

    #[test]
    fn first_term_bias_placement_survives_truncation() {
        // bias-dominated MLP: truncating Split slices loses bias mass,
        // FirstTerm keeps it — the 1-term prefix must track FP better
        let mut rng = Rng::seed(57);
        let w = MlpWeights {
            w1: Tensor::randn(&[16, 32], 0.05, &mut rng),
            b1: Tensor::randn(&[16], 1.0, &mut rng),
            w2: Tensor::randn(&[10, 16], 0.05, &mut rng),
            b2: Tensor::randn(&[10], 1.0, &mut rng),
        };
        let terms = 4;
        let x = Tensor::randn(&[6, 32], 1.0, &mut rng);
        let fp = fp_forward(&w, &x);
        let err_for = |placement| {
            let pool = WorkerPool::new(
                terms,
                mlp_basis_factory_with(&w, 8, terms, placement),
            );
            let sched = ExpansionScheduler::new(pool);
            let y = sched.forward_truncated(x.clone(), 1).unwrap();
            let rel = fp.sub(&y).norm() / fp.norm();
            sched.shutdown();
            rel
        };
        let split = err_for(BiasPlacement::Split);
        let first = err_for(BiasPlacement::FirstTerm);
        assert!(first < split, "first-term {first} !< split {split}");
    }

    #[test]
    fn quant_model_worker_replication_mode() {
        let data = crate::datasets::SynthImg::new(4, 1, 12, 0.15, 55);
        let mut m = crate::models::zoo::mini_resnet_a(4, 56);
        let cfg = crate::train::TrainConfig { steps: 40, batch: 16, lr: 0.05, log_every: 1000 };
        crate::train::train_classifier(&mut m, &data, &cfg);
        let q = crate::models::quantized::quantize_model(
            &m,
            crate::xint::layer::LayerPolicy::new(4, 4),
        );
        let q2 = q.clone();
        let pool = WorkerPool::new(
            1,
            Arc::new(move |_| {
                Box::new(QuantModelWorker {
                    model: q2.clone(),
                    sample_dims: Some(vec![1, 12, 12]),
                }) as Box<dyn BasisWorker>
            }),
        );
        let sched = ExpansionScheduler::new(pool);
        let b = data.batch(4, 2);
        let n = b.x.dims()[0];
        let flat = b.x.reshape(&[n, 144]);
        let y = sched.forward(flat).unwrap();
        assert_eq!(y.dims(), &[4, 4]);
        sched.shutdown();
    }
}
