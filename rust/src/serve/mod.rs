//! Serving front-end: model-backed basis workers (native and PJRT), a
//! TCP server speaking a small binary protocol, and a trace-driven load
//! generator for the latency/throughput benches.

pub mod loadgen;
pub mod server;
pub mod workers;

pub use loadgen::{run_trace, LoadReport};
pub use server::{serve_tcp, TcpServerHandle};
pub use workers::{mlp_basis_factory, MlpWeights, PjrtMlpWorker, QuantModelWorker};
