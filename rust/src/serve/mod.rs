//! Serving front-end: model-backed basis workers (native and PJRT), a
//! TCP server speaking a small binary protocol (with a per-request QoS
//! tier field), and a trace-driven load generator for the
//! latency/throughput benches (mixed-tier traffic supported).

pub mod loadgen;
pub mod server;
pub mod workers;

pub use loadgen::{run_trace, run_trace_mix, LoadReport, TierReport};
pub use server::{
    client_infer, client_infer_tier, client_infer_traced, client_metrics, client_trace_json,
    serve_tcp, TcpServerHandle,
};
pub use workers::{
    mlp_basis_factory, mlp_basis_factory_with, BiasPlacement, MlpWeights, PjrtMlpWorker,
    QuantModelWorker,
};
