//! Serving front-end: model-backed basis workers (native and PJRT), a
//! nonblocking epoll-reactor TCP server speaking protocol v3 (per-tier
//! QoS, pipelining, progressive-refinement streaming), and trace-driven
//! load generators — closed-loop (one blocking client per connection)
//! and open-loop (fixed-rate arrivals over thousands of nonblocking
//! connections) — for the latency/throughput benches.

pub mod conn;
pub mod loadgen;
pub mod protocol;
pub mod reactor;
pub mod server;
pub mod workers;

pub use loadgen::{
    run_open_loop, run_trace, run_trace_mix, LoadReport, OpenLoopConfig, OpenLoopReport,
    TierReport,
};
pub use protocol::{client_infer_stream, StreamClient, StreamEvent, StreamReply};
pub use server::{
    client_infer, client_infer_tier, client_infer_traced, client_metrics, client_trace_json,
    serve_tcp, TcpServerHandle,
};
pub use workers::{
    mlp_basis_factory, mlp_basis_factory_with, BiasPlacement, MlpWeights, PjrtMlpWorker,
    QuantModelWorker,
};
