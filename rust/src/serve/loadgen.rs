//! Trace-driven load generator: replays a [`RequestTrace`] against the
//! in-process coordinator and reports latency/throughput — the harness
//! behind the §5.2 serving-speed claims.

use crate::coordinator::Coordinator;
use crate::datasets::trace::RequestTrace;
use crate::tensor::{Rng, Tensor};
use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Load-test outcome.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub latency: Summary,
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "offered {} completed {} shed {} wall {:.2}s thpt {:.1} rps p50 {:.2}ms p99 {:.2}ms",
            self.offered,
            self.completed,
            self.shed,
            self.wall_s,
            self.throughput_rps,
            self.latency.p50 * 1e3,
            self.latency.p99 * 1e3
        )
    }
}

/// Replay `trace` for `duration_s` seconds against `coord`, generating
/// feature vectors of width `din`. Arrival times are honored by sleeping
/// to each event's offset (compressed by `time_scale` for fast benches).
pub fn run_trace(
    coord: &Arc<Coordinator>,
    trace: &RequestTrace,
    duration_s: f64,
    din: usize,
    time_scale: f64,
) -> LoadReport {
    let events = trace.generate(duration_s);
    let offered = events.len();
    let shed = Arc::new(AtomicU64::new(0));
    let latencies = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rng = Rng::seed(0xBEE);
    for ev in events {
        let target = Duration::from_secs_f64(ev.at * time_scale);
        let elapsed = t0.elapsed();
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
        let x = Tensor::randn(&[ev.batch, din], 1.0, &mut rng);
        match coord.submit(x) {
            Ok(rx) => {
                let latencies = latencies.clone();
                let sent = Instant::now();
                pending.push(std::thread::spawn(move || {
                    if let Ok(_resp) = rx.recv() {
                        latencies.lock().unwrap().push(sent.elapsed().as_secs_f64());
                    }
                }));
            }
            Err(_) => {
                shed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    for h in pending {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();
    let lats = latencies.lock().unwrap().clone();
    LoadReport {
        offered,
        completed: lats.len(),
        shed: shed.load(Ordering::Relaxed) as usize,
        wall_s: wall,
        throughput_rps: lats.len() as f64 / wall.max(1e-9),
        latency: Summary::of(&lats),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        BasisWorker, BatcherConfig, ExpansionScheduler, WorkerPool,
    };

    struct Fast;
    impl BasisWorker for Fast {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.clone())
        }
    }

    #[test]
    fn trace_replay_completes_requests() {
        let pool = WorkerPool::new(2, Arc::new(|_| Box::new(Fast) as Box<dyn BasisWorker>));
        let coord = Arc::new(Coordinator::new(
            BatcherConfig { max_batch: 16, max_wait_us: 300, queue_cap: 128 },
            ExpansionScheduler::new(pool),
        ));
        let trace = RequestTrace::new(200.0, 5);
        let report = run_trace(&coord, &trace, 0.5, 8, 0.2);
        assert!(report.offered > 20, "trace too small: {}", report.offered);
        assert_eq!(report.completed + report.shed, report.offered);
        assert!(report.completed > 0);
        assert!(report.latency.p50 >= 0.0);
    }
}
