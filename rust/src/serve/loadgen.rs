//! Trace-driven load generator: replays a [`RequestTrace`] against the
//! in-process coordinator and reports latency/throughput — the harness
//! behind the §5.2 serving-speed claims. Supports mixed-tier traffic
//! (weighted tier draw per request) with per-tier latency reporting,
//! the workload shape the QoS benches sweep.

use crate::coordinator::{Coordinator, SubmitError};
use crate::datasets::trace::RequestTrace;
use crate::qos::{Tier, NUM_TIERS};
use crate::tensor::{Rng, Tensor};
use crate::util::stats::Summary;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{thread, Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-tier slice of a load-test outcome.
#[derive(Debug, Clone)]
pub struct TierReport {
    pub tier: Tier,
    pub completed: usize,
    /// requests refused at this tier's admission check
    pub shed: usize,
    pub latency: Summary,
    /// mean basis terms reduced per completed reply — the served
    /// precision, and the cross-tier isolation observable (a flood in
    /// another tier must not move this)
    pub mean_terms: f64,
    /// mean INT GEMM grid terms per completed reply (0 for backends
    /// that don't meter Eq. 3 grids)
    pub mean_grid_terms: f64,
}

/// One completed reply as the loadgen saw it.
struct Done {
    tier: Tier,
    latency_s: f64,
    terms: usize,
    grid_terms: usize,
}

/// Load-test outcome.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// accepted requests answered with an explicit error reply
    pub failed: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub latency: Summary,
    /// per-tier breakdown (only tiers that appeared in the mix)
    pub per_tier: Vec<TierReport>,
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "offered {} completed {} shed {} failed {} wall {:.2}s thpt {:.1} rps p50 {:.2}ms p99 {:.2}ms",
            self.offered,
            self.completed,
            self.shed,
            self.failed,
            self.wall_s,
            self.throughput_rps,
            self.latency.p50 * 1e3,
            self.latency.p99 * 1e3
        )
    }
}

/// Replay `trace` for `duration_s` seconds against `coord` at
/// [`Tier::Exact`] (the pre-QoS behavior).
pub fn run_trace(
    coord: &Arc<Coordinator>,
    trace: &RequestTrace,
    duration_s: f64,
    din: usize,
    time_scale: f64,
) -> LoadReport {
    run_trace_mix(coord, trace, duration_s, din, time_scale, &[(Tier::Exact, 1.0)])
}

/// Replay `trace` with each request's tier drawn from the weighted
/// `mix`. Arrival times are honored by sleeping to each event's offset
/// (compressed by `time_scale` for fast benches).
pub fn run_trace_mix(
    coord: &Arc<Coordinator>,
    trace: &RequestTrace,
    duration_s: f64,
    din: usize,
    time_scale: f64,
    mix: &[(Tier, f64)],
) -> LoadReport {
    assert!(!mix.is_empty(), "tier mix must name at least one tier");
    let total_w: f64 = mix.iter().map(|(_, w)| w.max(0.0)).sum();
    assert!(total_w > 0.0, "tier mix weights must sum > 0");
    let events = trace.generate(duration_s);
    let offered = events.len();
    let mut shed_by = [0usize; NUM_TIERS];
    let failed = Arc::new(AtomicU64::new(0));
    let done = Arc::new(Mutex::new(Vec::<Done>::new()));
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rng = Rng::seed(0xBEE);
    for ev in events {
        let target = Duration::from_secs_f64(ev.at * time_scale);
        let elapsed = t0.elapsed();
        if target > elapsed {
            thread::sleep(target - elapsed);
        }
        // weighted tier draw
        let mut pick = rng.f32() as f64 * total_w;
        let mut tier = mix[mix.len() - 1].0;
        for &(t, w) in mix {
            let w = w.max(0.0);
            if pick < w {
                tier = t;
                break;
            }
            pick -= w;
        }
        let x = Tensor::randn(&[ev.batch, din], 1.0, &mut rng);
        match coord.submit_tier(x, tier) {
            Ok(rx) => {
                let done = done.clone();
                let failed = failed.clone();
                let sent = Instant::now();
                pending.push(thread::spawn(move || match rx.recv() {
                    Ok(resp) if resp.error.is_none() => {
                        done.lock().unwrap().push(Done {
                            tier,
                            latency_s: sent.elapsed().as_secs_f64(),
                            terms: resp.terms,
                            grid_terms: resp.grid_terms,
                        });
                    }
                    Ok(_) | Err(_) => {
                        // ordering: Relaxed — plain event counter; the
                        // joins below publish it before the final load.
                        failed.fetch_add(1, Ordering::Relaxed);
                    }
                }));
            }
            // only admission-control refusals count as sheds; a closed
            // coordinator (e.g. a dead forming thread) is a failure —
            // conflating them would let a crash masquerade as healthy
            // load shedding in the per-tier reports and BENCH json
            Err(SubmitError::Busy(t)) => {
                shed_by[t.idx()] += 1;
            }
            Err(SubmitError::Closed) => {
                // ordering: Relaxed — same counter, same-thread bump.
                failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    for h in pending {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();
    let lats = done.lock().unwrap();
    let all: Vec<f64> = lats.iter().map(|d| d.latency_s).collect();
    let per_tier = mix
        .iter()
        .map(|&(t, _)| t)
        .map(|tier| {
            let slice: Vec<&Done> = lats.iter().filter(|d| d.tier == tier).collect();
            let tl: Vec<f64> = slice.iter().map(|d| d.latency_s).collect();
            let n = slice.len().max(1) as f64;
            TierReport {
                tier,
                completed: slice.len(),
                shed: shed_by[tier.idx()],
                latency: Summary::of(&tl),
                mean_terms: slice.iter().map(|d| d.terms as f64).sum::<f64>() / n,
                mean_grid_terms: slice.iter().map(|d| d.grid_terms as f64).sum::<f64>() / n,
            }
        })
        .collect();
    LoadReport {
        offered,
        completed: all.len(),
        shed: shed_by.iter().sum(),
        // ordering: Relaxed — all writers were joined above, so this
        // load observes every increment without extra synchronization.
        failed: failed.load(Ordering::Relaxed) as usize,
        wall_s: wall,
        throughput_rps: all.len() as f64 / wall.max(1e-9),
        latency: Summary::of(&all),
        per_tier,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        BasisWorker, BatcherConfig, ExpansionScheduler, WorkerPool,
    };

    struct Fast;
    impl BasisWorker for Fast {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.clone())
        }
    }

    fn fast_coordinator() -> Arc<Coordinator> {
        let pool = WorkerPool::new(2, Arc::new(|_| Box::new(Fast) as Box<dyn BasisWorker>));
        Arc::new(Coordinator::new(
            BatcherConfig::uniform(16, 300, 128),
            ExpansionScheduler::new(pool),
        ))
    }

    #[test]
    fn trace_replay_completes_requests() {
        let coord = fast_coordinator();
        let trace = RequestTrace::new(200.0, 5);
        let report = run_trace(&coord, &trace, 0.5, 8, 0.2);
        assert!(report.offered > 20, "trace too small: {}", report.offered);
        assert_eq!(report.completed + report.shed + report.failed, report.offered);
        assert!(report.completed > 0);
        assert!(report.latency.p50 >= 0.0);
        // single-tier mix: the per-tier slice covers everything
        assert_eq!(report.per_tier.len(), 1);
        assert_eq!(report.per_tier[0].tier, Tier::Exact);
        assert_eq!(report.per_tier[0].completed, report.completed);
        assert_eq!(report.per_tier[0].shed, report.shed, "all sheds were Exact");
    }

    #[test]
    fn mixed_tiers_split_the_traffic() {
        let coord = fast_coordinator();
        let trace = RequestTrace::new(300.0, 6);
        let mix = [(Tier::Exact, 0.5), (Tier::BestEffort, 0.5)];
        let report = run_trace_mix(&coord, &trace, 0.4, 8, 0.2, &mix);
        assert_eq!(report.per_tier.len(), 2);
        let by_tier: usize = report.per_tier.iter().map(|t| t.completed).sum();
        assert_eq!(by_tier, report.completed);
        // both tiers should see a fair share of a 50/50 draw
        for t in &report.per_tier {
            assert!(t.completed > 0, "tier {} starved", t.tier);
            // no controller: every reply reduced the full 2-worker pool,
            // and the MLP-free echo workers meter no grid
            assert!((t.mean_terms - 2.0).abs() < 1e-12, "{}: {}", t.tier, t.mean_terms);
            assert_eq!(t.mean_grid_terms, 0.0);
        }
        assert_eq!(coord.metrics.tier_completed(Tier::Balanced), 0);
    }
}
