//! Trace-driven load generators — the harness behind the §5.2
//! serving-speed claims.
//!
//! Two arrival models:
//! - **Closed loop** ([`run_trace`]/[`run_trace_mix`]): replays a
//!   [`RequestTrace`] against the in-process coordinator, one waiting
//!   thread per in-flight request. Supports mixed-tier traffic
//!   (weighted tier draw per request) with per-tier latency reporting,
//!   the workload shape the QoS benches sweep.
//! - **Open loop** ([`run_open_loop`]): a fixed-rate Poisson schedule
//!   over thousands of nonblocking TCP connections driven by one
//!   [`Poller`] — the connection-scale harness for the reactor server.
//!   Latency is measured from each request's *scheduled* send time, so
//!   a stalled server inflates the tail instead of silently slowing the
//!   arrival process (no coordinated omission).

use crate::coordinator::{Coordinator, SubmitError};
use crate::datasets::trace::RequestTrace;
use crate::qos::{Tier, NUM_TIERS};
use crate::serve::protocol::{
    encode_request, CODE_BATCH_FAILED, CODE_SHED, STREAM_END, STREAM_SENTINEL,
};
use crate::serve::reactor::{raw_fd, Event, Poller};
use crate::tensor::{Rng, Tensor};
use crate::util::stats::Summary;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{thread, Arc, Mutex};
use std::collections::HashMap;
use std::io::ErrorKind;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Per-tier slice of a load-test outcome.
#[derive(Debug, Clone)]
pub struct TierReport {
    pub tier: Tier,
    pub completed: usize,
    /// requests refused at this tier's admission check
    pub shed: usize,
    pub latency: Summary,
    /// mean basis terms reduced per completed reply — the served
    /// precision, and the cross-tier isolation observable (a flood in
    /// another tier must not move this)
    pub mean_terms: f64,
    /// mean INT GEMM grid terms per completed reply (0 for backends
    /// that don't meter Eq. 3 grids)
    pub mean_grid_terms: f64,
}

/// One completed reply as the loadgen saw it.
struct Done {
    tier: Tier,
    latency_s: f64,
    terms: usize,
    grid_terms: usize,
}

/// Load-test outcome.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// accepted requests answered with an explicit error reply
    pub failed: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub latency: Summary,
    /// per-tier breakdown (only tiers that appeared in the mix)
    pub per_tier: Vec<TierReport>,
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "offered {} completed {} shed {} failed {} wall {:.2}s thpt {:.1} rps p50 {:.2}ms p99 {:.2}ms",
            self.offered,
            self.completed,
            self.shed,
            self.failed,
            self.wall_s,
            self.throughput_rps,
            self.latency.p50 * 1e3,
            self.latency.p99 * 1e3
        )
    }
}

/// Replay `trace` for `duration_s` seconds against `coord` at
/// [`Tier::Exact`] (the pre-QoS behavior).
pub fn run_trace(
    coord: &Arc<Coordinator>,
    trace: &RequestTrace,
    duration_s: f64,
    din: usize,
    time_scale: f64,
) -> LoadReport {
    run_trace_mix(coord, trace, duration_s, din, time_scale, &[(Tier::Exact, 1.0)])
}

/// Replay `trace` with each request's tier drawn from the weighted
/// `mix`. Arrival times are honored by sleeping to each event's offset
/// (compressed by `time_scale` for fast benches).
pub fn run_trace_mix(
    coord: &Arc<Coordinator>,
    trace: &RequestTrace,
    duration_s: f64,
    din: usize,
    time_scale: f64,
    mix: &[(Tier, f64)],
) -> LoadReport {
    assert!(!mix.is_empty(), "tier mix must name at least one tier");
    let total_w: f64 = mix.iter().map(|(_, w)| w.max(0.0)).sum();
    assert!(total_w > 0.0, "tier mix weights must sum > 0");
    let events = trace.generate(duration_s);
    let offered = events.len();
    let mut shed_by = [0usize; NUM_TIERS];
    let failed = Arc::new(AtomicU64::new(0));
    let done = Arc::new(Mutex::new(Vec::<Done>::new()));
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rng = Rng::seed(0xBEE);
    for ev in events {
        let target = Duration::from_secs_f64(ev.at * time_scale);
        let elapsed = t0.elapsed();
        if target > elapsed {
            thread::sleep(target - elapsed);
        }
        // weighted tier draw
        let mut pick = rng.f32() as f64 * total_w;
        let mut tier = mix[mix.len() - 1].0;
        for &(t, w) in mix {
            let w = w.max(0.0);
            if pick < w {
                tier = t;
                break;
            }
            pick -= w;
        }
        let x = Tensor::randn(&[ev.batch, din], 1.0, &mut rng);
        match coord.submit_tier(x, tier) {
            Ok(rx) => {
                let done = done.clone();
                let failed = failed.clone();
                let sent = Instant::now();
                pending.push(thread::spawn(move || match rx.recv() {
                    Ok(resp) if resp.error.is_none() => {
                        done.lock().unwrap().push(Done {
                            tier,
                            latency_s: sent.elapsed().as_secs_f64(),
                            terms: resp.terms,
                            grid_terms: resp.grid_terms,
                        });
                    }
                    Ok(_) | Err(_) => {
                        // ordering: Relaxed — plain event counter; the
                        // joins below publish it before the final load.
                        failed.fetch_add(1, Ordering::Relaxed);
                    }
                }));
            }
            // only admission-control refusals count as sheds; a closed
            // coordinator (e.g. a dead forming thread) is a failure —
            // conflating them would let a crash masquerade as healthy
            // load shedding in the per-tier reports and BENCH json
            Err(SubmitError::Busy(t)) => {
                shed_by[t.idx()] += 1;
            }
            Err(SubmitError::Closed) => {
                // ordering: Relaxed — same counter, same-thread bump.
                failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    for h in pending {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();
    let lats = done.lock().unwrap();
    let all: Vec<f64> = lats.iter().map(|d| d.latency_s).collect();
    let per_tier = mix
        .iter()
        .map(|&(t, _)| t)
        .map(|tier| {
            let slice: Vec<&Done> = lats.iter().filter(|d| d.tier == tier).collect();
            let tl: Vec<f64> = slice.iter().map(|d| d.latency_s).collect();
            let n = slice.len().max(1) as f64;
            TierReport {
                tier,
                completed: slice.len(),
                shed: shed_by[tier.idx()],
                latency: Summary::of(&tl),
                mean_terms: slice.iter().map(|d| d.terms as f64).sum::<f64>() / n,
                mean_grid_terms: slice.iter().map(|d| d.grid_terms as f64).sum::<f64>() / n,
            }
        })
        .collect();
    LoadReport {
        offered,
        completed: all.len(),
        shed: shed_by.iter().sum(),
        // ordering: Relaxed — all writers were joined above, so this
        // load observes every increment without extra synchronization.
        failed: failed.load(Ordering::Relaxed) as usize,
        wall_s: wall,
        throughput_rps: all.len() as f64 / wall.max(1e-9),
        latency: Summary::of(&all),
        per_tier,
    }
}

// ---------------------------------------------------------------------
// Open-loop TCP load: fixed-rate Poisson arrivals over many
// nonblocking connections, one poller, no coordinated omission.

/// Configuration for [`run_open_loop`].
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    /// open TCP connections, driven round-robin by the arrival process
    pub connections: usize,
    /// aggregate request arrival rate (Poisson) across all connections
    pub rate_rps: f64,
    /// seconds of arrivals to schedule
    pub duration_s: f64,
    pub tier: Tier,
    /// set the tier word's STREAM_FLAG (progressive refinement)
    pub stream: bool,
    /// request feature width (`x` is `[1, din]`)
    pub din: usize,
    pub seed: u64,
    /// extra seconds to wait for in-flight replies after the last send
    pub drain_s: f64,
}

/// Outcome of an open-loop run. Latencies are measured from each
/// request's *scheduled* send time to the frame named below.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub connections: usize,
    pub offered: usize,
    /// requests whose final frame (classic reply or stream end) arrived
    pub completed: usize,
    pub shed: usize,
    pub failed: usize,
    /// still in flight when the drain window closed (or their
    /// connection died)
    pub timed_out: usize,
    pub wall_s: f64,
    /// scheduled send → final frame
    pub full_latency: Summary,
    /// scheduled send → first frame (the prefix, for streamed replies;
    /// identical to `full_latency` for classic single-frame replies)
    pub first_frame_latency: Summary,
}

impl std::fmt::Display for OpenLoopReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "conns {} offered {} completed {} shed {} failed {} timed_out {} wall {:.2}s \
             full p99 {:.2}ms first p99 {:.2}ms",
            self.connections,
            self.offered,
            self.completed,
            self.shed,
            self.failed,
            self.timed_out,
            self.wall_s,
            self.full_latency.p99 * 1e3,
            self.first_frame_latency.p99 * 1e3
        )
    }
}

/// One server→client frame boundary, as the open-loop reader needs it:
/// ids and byte extents only, payloads skipped.
enum RespEvent {
    Reply { trace_id: u64 },
    Shed { trace_id: u64 },
    Failed { trace_id: u64 },
    Malformed { trace_id: u64 },
    StreamData { trace_id: u64 },
    StreamEnd { trace_id: u64 },
}

/// Incremental response-frame splitter (client side of protocol v3).
#[derive(Default)]
struct RespDecoder {
    buf: Vec<u8>,
    pos: usize,
}

impl RespDecoder {
    fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    fn have(&self, n: usize) -> bool {
        self.buf.len() - self.pos >= n
    }

    fn u32_at(&self, off: usize) -> u32 {
        let p = self.pos + off;
        u32::from_le_bytes([self.buf[p], self.buf[p + 1], self.buf[p + 2], self.buf[p + 3]])
    }

    fn u64_at(&self, off: usize) -> u64 {
        let p = self.pos + off;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[p..p + 8]);
        u64::from_le_bytes(b)
    }

    fn consume(&mut self, n: usize) {
        self.pos += n;
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= 64 * 1024 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    fn next_event(&mut self) -> Option<RespEvent> {
        if !self.have(16) {
            return None;
        }
        let w0 = self.u32_at(0);
        if w0 == STREAM_SENTINEL {
            let kind = self.u32_at(4);
            let trace_id = self.u64_at(8);
            if kind == STREAM_END {
                if !self.have(20) {
                    return None;
                }
                self.consume(20);
                return Some(RespEvent::StreamEnd { trace_id });
            }
            if !self.have(28) {
                return None;
            }
            let body = (self.u32_at(16) as usize) * (self.u32_at(20) as usize) * 4;
            if !self.have(28 + body) {
                return None;
            }
            self.consume(28 + body);
            return Some(RespEvent::StreamData { trace_id });
        }
        let trace_id = self.u64_at(8);
        if w0 == 0 {
            return match self.u32_at(4) {
                CODE_SHED => {
                    if !self.have(20) {
                        return None;
                    }
                    self.consume(20);
                    Some(RespEvent::Shed { trace_id })
                }
                CODE_BATCH_FAILED => {
                    if !self.have(20) {
                        return None;
                    }
                    let len = self.u32_at(16) as usize;
                    if !self.have(20 + len) {
                        return None;
                    }
                    self.consume(20 + len);
                    Some(RespEvent::Failed { trace_id })
                }
                _ => {
                    self.consume(16);
                    Some(RespEvent::Malformed { trace_id })
                }
            };
        }
        let body = (w0 as usize) * (self.u32_at(4) as usize) * 4;
        if !self.have(16 + body) {
            return None;
        }
        self.consume(16 + body);
        Some(RespEvent::Reply { trace_id })
    }
}

struct OlConn {
    s: TcpStream,
    dec: RespDecoder,
    out: Vec<u8>,
    out_off: usize,
    wants_write: bool,
    dead: bool,
}

/// Flush a connection's pending request bytes until the socket blocks,
/// then fix up its poller write interest.
fn ol_flush(c: &mut OlConn, poller: &mut Poller, token: u64) {
    use std::io::Write;
    while c.out_off < c.out.len() && !c.dead {
        match c.s.write(&c.out[c.out_off..]) {
            Ok(0) => c.dead = true,
            Ok(k) => c.out_off += k,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => c.dead = true,
        }
    }
    if c.out_off >= c.out.len() {
        c.out.clear();
        c.out_off = 0;
    } else if c.out_off >= 64 * 1024 {
        c.out.drain(..c.out_off);
        c.out_off = 0;
    }
    let want_w = !c.out.is_empty() && !c.dead;
    if want_w != c.wants_write && poller.reregister(raw_fd(&c.s), token, true, want_w).is_ok() {
        c.wants_write = want_w;
    }
}

/// Drain a connection's socket into its frame splitter.
fn ol_read(c: &mut OlConn, scratch: &mut [u8]) {
    use std::io::Read;
    loop {
        match c.s.read(scratch) {
            Ok(0) => {
                c.dead = true;
                break;
            }
            Ok(k) => c.dec.feed(&scratch[..k]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                break;
            }
        }
    }
}

struct PendingReq {
    sched: Instant,
    first_seen: bool,
}

/// Drive a fixed-rate Poisson request schedule against a TCP server
/// over `cfg.connections` nonblocking connections on one poller (the
/// open-loop, coordinated-omission-free arrival model).
pub fn run_open_loop(
    addr: std::net::SocketAddr,
    cfg: &OpenLoopConfig,
) -> anyhow::Result<OpenLoopReport> {
    anyhow::ensure!(cfg.connections > 0, "open loop needs at least one connection");
    let mut rng = Rng::seed(cfg.seed);
    // pre-generated arrival schedule: exponential inter-arrival gaps
    let mut sched = Vec::new();
    let mut t = 0.0f64;
    loop {
        let u = (1.0 - rng.f32() as f64).max(1e-9);
        t += -u.ln() / cfg.rate_rps.max(1e-9);
        if t >= cfg.duration_s {
            break;
        }
        sched.push(t);
    }
    let offered = sched.len();
    // one request template; each send patches its own trace id into
    // bytes 12..20 of the header
    let x = Tensor::randn(&[1, cfg.din], 1.0, &mut rng);
    let template = encode_request(&x, cfg.tier, cfg.stream, 0);

    let mut poller = Poller::new()?;
    let mut conns: Vec<OlConn> = Vec::with_capacity(cfg.connections);
    for i in 0..cfg.connections {
        let s = TcpStream::connect(addr)?;
        s.set_nonblocking(true)?;
        let _ = s.set_nodelay(true);
        poller.register(raw_fd(&s), i as u64, true, false)?;
        conns.push(OlConn {
            s,
            dec: RespDecoder::default(),
            out: Vec::new(),
            out_off: 0,
            wants_write: false,
            dead: false,
        });
    }

    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs_f64(cfg.duration_s + cfg.drain_s.max(0.0));
    let mut next = 0usize;
    let mut trace_id = 1u64;
    let mut inflight: HashMap<u64, PendingReq> = HashMap::new();
    let mut firsts: Vec<f64> = Vec::new();
    let mut fulls: Vec<f64> = Vec::new();
    let (mut completed, mut shed, mut failed) = (0usize, 0usize, 0usize);
    let mut events: Vec<Event> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    loop {
        let now = Instant::now();
        if now >= deadline || (next >= sched.len() && inflight.is_empty()) {
            break;
        }
        // queue every due send at its SCHEDULED time: latency starts
        // here, not at the (possibly backlogged) socket write
        while next < sched.len() {
            let due = t0 + Duration::from_secs_f64(sched[next]);
            if Instant::now() < due {
                break;
            }
            let k = next % conns.len();
            let start = conns[k].out.len();
            conns[k].out.extend_from_slice(&template);
            conns[k].out[start + 12..start + 20].copy_from_slice(&trace_id.to_le_bytes());
            inflight.insert(trace_id, PendingReq { sched: due, first_seen: false });
            trace_id += 1;
            next += 1;
            ol_flush(&mut conns[k], &mut poller, k as u64);
        }
        let timeout_ms = if next < sched.len() {
            let due = t0 + Duration::from_secs_f64(sched[next]);
            due.saturating_duration_since(Instant::now()).as_millis().min(10) as i32
        } else {
            10
        };
        poller.poll(&mut events, timeout_ms)?;
        for ev in &events {
            let k = ev.token as usize;
            let Some(c) = conns.get_mut(k) else { continue };
            if c.dead {
                continue;
            }
            if ev.writable {
                ol_flush(c, &mut poller, ev.token);
            }
            if ev.readable {
                ol_read(c, &mut scratch);
                let t_now = Instant::now();
                while let Some(e) = c.dec.next_event() {
                    match e {
                        RespEvent::Reply { trace_id } | RespEvent::StreamEnd { trace_id } => {
                            if let Some(p) = inflight.remove(&trace_id) {
                                let l = t_now.saturating_duration_since(p.sched).as_secs_f64();
                                if !p.first_seen {
                                    firsts.push(l);
                                }
                                fulls.push(l);
                                completed += 1;
                            }
                        }
                        RespEvent::StreamData { trace_id } => {
                            if let Some(p) = inflight.get_mut(&trace_id) {
                                if !p.first_seen {
                                    p.first_seen = true;
                                    let l =
                                        t_now.saturating_duration_since(p.sched).as_secs_f64();
                                    firsts.push(l);
                                }
                            }
                        }
                        RespEvent::Shed { trace_id } => {
                            if inflight.remove(&trace_id).is_some() {
                                shed += 1;
                            }
                        }
                        RespEvent::Failed { trace_id } | RespEvent::Malformed { trace_id } => {
                            if inflight.remove(&trace_id).is_some() {
                                failed += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(OpenLoopReport {
        connections: cfg.connections,
        offered,
        completed,
        shed,
        failed,
        timed_out: inflight.len() + (offered - next),
        wall_s,
        full_latency: Summary::of(&fulls),
        first_frame_latency: Summary::of(&firsts),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        BasisWorker, BatcherConfig, ExpansionScheduler, WorkerPool,
    };

    struct Fast;
    impl BasisWorker for Fast {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.clone())
        }
    }

    fn fast_coordinator() -> Arc<Coordinator> {
        let pool = WorkerPool::new(2, Arc::new(|_| Box::new(Fast) as Box<dyn BasisWorker>));
        Arc::new(Coordinator::new(
            BatcherConfig::uniform(16, 300, 128),
            ExpansionScheduler::new(pool),
        ))
    }

    #[test]
    fn trace_replay_completes_requests() {
        let coord = fast_coordinator();
        let trace = RequestTrace::new(200.0, 5);
        let report = run_trace(&coord, &trace, 0.5, 8, 0.2);
        assert!(report.offered > 20, "trace too small: {}", report.offered);
        assert_eq!(report.completed + report.shed + report.failed, report.offered);
        assert!(report.completed > 0);
        assert!(report.latency.p50 >= 0.0);
        // single-tier mix: the per-tier slice covers everything
        assert_eq!(report.per_tier.len(), 1);
        assert_eq!(report.per_tier[0].tier, Tier::Exact);
        assert_eq!(report.per_tier[0].completed, report.completed);
        assert_eq!(report.per_tier[0].shed, report.shed, "all sheds were Exact");
    }

    #[test]
    fn mixed_tiers_split_the_traffic() {
        let coord = fast_coordinator();
        let trace = RequestTrace::new(300.0, 6);
        let mix = [(Tier::Exact, 0.5), (Tier::BestEffort, 0.5)];
        let report = run_trace_mix(&coord, &trace, 0.4, 8, 0.2, &mix);
        assert_eq!(report.per_tier.len(), 2);
        let by_tier: usize = report.per_tier.iter().map(|t| t.completed).sum();
        assert_eq!(by_tier, report.completed);
        // both tiers should see a fair share of a 50/50 draw
        for t in &report.per_tier {
            assert!(t.completed > 0, "tier {} starved", t.tier);
            // no controller: every reply reduced the full 2-worker pool,
            // and the MLP-free echo workers meter no grid
            assert!((t.mean_terms - 2.0).abs() < 1e-12, "{}: {}", t.tier, t.mean_terms);
            assert_eq!(t.mean_grid_terms, 0.0);
        }
        assert_eq!(coord.metrics.tier_completed(Tier::Balanced), 0);
    }

    #[test]
    fn open_loop_accounts_every_offered_request() {
        let coord = fast_coordinator();
        let handle = crate::serve::server::serve_tcp("127.0.0.1:0", coord).unwrap();
        let cfg = OpenLoopConfig {
            connections: 32,
            rate_rps: 400.0,
            duration_s: 0.3,
            tier: Tier::Exact,
            stream: false,
            din: 8,
            seed: 7,
            drain_s: 5.0,
        };
        let report = run_open_loop(handle.addr, &cfg).unwrap();
        handle.stop();
        assert!(report.offered > 20, "schedule too small: {}", report.offered);
        assert!(report.completed > 0);
        assert_eq!(
            report.completed + report.shed + report.failed + report.timed_out,
            report.offered
        );
        // classic single-frame replies: the first frame IS the reply
        assert_eq!(report.first_frame_latency.p50, report.full_latency.p50);
        assert_eq!(report.first_frame_latency.p99, report.full_latency.p99);
    }

    #[test]
    fn open_loop_streamed_first_frame_leads_the_full_reply() {
        struct Staggered(u64);
        impl BasisWorker for Staggered {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                thread::sleep(Duration::from_millis(self.0));
                Ok(x.clone())
            }
        }
        // sequential-fold refinement: term 1 lands after ~20ms, the end
        // frame only after both workers (~60ms) — a visible gap
        let pool = WorkerPool::new(
            2,
            Arc::new(|i| Box::new(Staggered(20 * (i as u64 + 1))) as Box<dyn BasisWorker>),
        );
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(16, 300, 128),
            ExpansionScheduler::new(pool),
        ));
        let handle = crate::serve::server::serve_tcp("127.0.0.1:0", coord).unwrap();
        let cfg = OpenLoopConfig {
            connections: 4,
            rate_rps: 30.0,
            duration_s: 0.3,
            tier: Tier::BestEffort,
            stream: true,
            din: 8,
            seed: 11,
            drain_s: 10.0,
        };
        let report = run_open_loop(handle.addr, &cfg).unwrap();
        handle.stop();
        assert!(report.completed > 0, "no streamed request completed: {report}");
        assert_eq!(report.timed_out, 0, "streamed replies stranded: {report}");
        assert!(
            report.first_frame_latency.p50 < report.full_latency.p50,
            "prefix frame should lead the end frame: {report}"
        );
    }
}
