//! Wire protocol v3 codec: the single home for frame layouts, the
//! incremental [`FrameDecoder`] the reactor feeds from nonblocking
//! reads, encoders for every server→client frame, and the blocking
//! convenience clients (tests, CLI, loadgen). This file is the one
//! place in `src/` allowed to issue blocking `std::net` reads/writes
//! (`check_invariants.py`, rule `blocking-io`) — the serving plane
//! itself is nonblocking and goes through the decoder/encoders only.
//!
//! All integers are little-endian. Client → server frames:
//!
//! request : `[u32 n][u32 d][u32 tier][u64 trace_id][n·d × f32]`
//!           the tier word's high bit ([`STREAM_FLAG`]) asks for
//!           progressive refinement (honored for Throughput/BestEffort;
//!           other tiers answer with a single classic frame)
//! control : `[u32::MAX][u32 code]` — code 1 metrics, 2 trace JSON
//! cancel  : `[u32::MAX-1][u32 0][u64 trace_id]` — stop refining
//!
//! Server → client frames:
//!
//! success : `[u32 n][u32 c][u64 trace_id][n·c × f32]`
//! error   : `[0][u32 code][u64 trace_id][payload]` — code 0 shed
//!           (payload `u32` tier), 1 batch failure (payload
//!           `[u32 len][len utf8]`), 2 malformed (no payload)
//! control : `[u32 len][len × u8]`
//! stream  : `[u32::MAX-1][u32 kind][u64 trace_id]` then, for kind 0
//!           (prefix) and 1 (delta): `[u32 rows][u32 cols][u32 terms]`
//!           `[rows·cols × f32]`; for kind 2 (end): `[u32 terms]`.
//!           The ⊎-fold of the prefix and every delta, in arrival
//!           order, is bit-identical to the non-streamed reply at the
//!           same term count ([`StreamReply::reconstruct`]).

use crate::qos::Tier;
use crate::tensor::Tensor;
use std::io::{Read, Write};
use std::net::TcpStream;

/// Error code in the `[0][code]` response header: per-tier shed frame
/// (payload = the refusing tier's wire encoding).
pub const CODE_SHED: u32 = 0;
/// Error code: batch failure (payload = length-prefixed UTF-8 message).
pub const CODE_BATCH_FAILED: u32 = 1;
/// Error code: malformed request header or unknown tier (no payload).
pub const CODE_MALFORMED: u32 = 2;

/// `n` sentinel marking a control frame; the `d` word carries the
/// control code and no tensor payload follows.
pub const CONTROL_SENTINEL: u32 = u32::MAX;
/// Control code: reply with the Prometheus-style metrics exposition.
pub const CTRL_METRICS: u32 = 1;
/// Control code: reply with the flight recorder's Chrome-trace JSON.
pub const CTRL_TRACE: u32 = 2;

/// First word of stream (server→client) and cancel (client→server)
/// frames. Distinct from real row counts: `n` is capped far below it by
/// [`MAX_ELEMS`].
pub const STREAM_SENTINEL: u32 = u32::MAX - 1;
/// High bit of the request tier word: ask for progressive refinement.
pub const STREAM_FLAG: u32 = 0x8000_0000;
/// Stream frame kind: first truncated-prefix result.
pub const STREAM_PREFIX: u32 = 0;
/// Stream frame kind: one later basis term, to be ⊎-added to the prefix.
pub const STREAM_DELTA: u32 = 1;
/// Stream frame kind: refinement finished (payload = total terms).
pub const STREAM_END: u32 = 2;

/// Upper bound on `n·d` for a request tensor — also what keeps real row
/// counts clear of the two sentinels above.
pub const MAX_ELEMS: u64 = 16 * 1024 * 1024;

/// One decoded client→server frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Request {
        n: usize,
        d: usize,
        tier: Tier,
        /// the tier word carried [`STREAM_FLAG`]
        stream: bool,
        trace_id: u64,
        data: Vec<f32>,
    },
    Control {
        code: u32,
    },
    Cancel {
        trace_id: u64,
    },
    /// Header parsed far enough to be rejected. `fatal` closes the
    /// connection (oversized `n·d`: the payload length itself is not
    /// trustworthy); non-fatal rejects echo the frame's `trace_id` and
    /// the connection keeps serving later pipelined frames.
    Malformed {
        trace_id: u64,
        fatal: bool,
    },
}

/// Incremental decoder: feed it whatever bytes the socket had, pull
/// complete frames out. Tolerates any split boundary, including one
/// byte at a time (property-pinned below).
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    pos: usize,
    /// unread payload bytes of a request already rejected (unknown
    /// tier): swallowed so the connection survives the error
    skip: usize,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append freshly read bytes to the decode buffer.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet decodable into a complete frame.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn u32_at(&self, off: usize) -> u32 {
        let b = &self.buf[self.pos + off..self.pos + off + 4];
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    fn u64_at(&self, off: usize) -> u64 {
        let b = &self.buf[self.pos + off..self.pos + off + 8];
        u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }

    fn consume(&mut self, n: usize) {
        self.pos += n;
        // compact once everything is consumed, or when the dead prefix
        // grows past a page — keeps the buffer from creeping under a
        // long-lived pipelined connection
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= 4096 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Decode the next complete frame if the buffer holds one.
    pub fn next_frame(&mut self) -> Option<Frame> {
        if self.skip > 0 {
            let eat = self.skip.min(self.pending());
            self.consume(eat);
            self.skip -= eat;
            if self.skip > 0 {
                return None;
            }
        }
        if self.pending() < 4 {
            return None;
        }
        let w0 = self.u32_at(0);
        if w0 == CONTROL_SENTINEL {
            if self.pending() < 8 {
                return None;
            }
            let code = self.u32_at(4);
            self.consume(8);
            return Some(Frame::Control { code });
        }
        if w0 == STREAM_SENTINEL {
            if self.pending() < 16 {
                return None;
            }
            let trace_id = self.u64_at(8);
            self.consume(16);
            return Some(Frame::Cancel { trace_id });
        }
        if self.pending() < 20 {
            return None;
        }
        // always parse the full header first so every reject below can
        // echo the request's trace id (frame and error span correlate)
        let n = w0 as u64;
        let d = self.u32_at(4) as u64;
        let tier_word = self.u32_at(8);
        let trace_id = self.u64_at(12);
        if n == 0 || d == 0 {
            self.consume(20);
            return Some(Frame::Malformed { trace_id, fatal: false });
        }
        if n * d > MAX_ELEMS {
            self.consume(20);
            return Some(Frame::Malformed { trace_id, fatal: true });
        }
        let stream = tier_word & STREAM_FLAG != 0;
        let tier = match Tier::from_u32(tier_word & !STREAM_FLAG) {
            Some(t) => t,
            None => {
                self.consume(20);
                self.skip = (n * d * 4) as usize;
                return Some(Frame::Malformed { trace_id, fatal: false });
            }
        };
        let payload = (n * d * 4) as usize;
        if self.pending() < 20 + payload {
            return None;
        }
        let data: Vec<f32> = self.buf[self.pos + 20..self.pos + 20 + payload]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        self.consume(20 + payload);
        Some(Frame::Request { n: n as usize, d: d as usize, tier, stream, trace_id, data })
    }
}

// ---------------------------------------------------------------------
// Encoders (server → client, plus the client-side request/cancel).

/// Encode a request frame; `stream` sets [`STREAM_FLAG`] on the tier.
pub fn encode_request(x: &Tensor, tier: Tier, stream: bool, trace_id: u64) -> Vec<u8> {
    let (n, d) = (x.dims()[0] as u32, x.dims()[1] as u32);
    let tw = tier.as_u32() | if stream { STREAM_FLAG } else { 0 };
    let mut out = Vec::with_capacity(20 + x.numel() * 4);
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&d.to_le_bytes());
    out.extend_from_slice(&tw.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    for &v in x.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Encode a success reply from raw rows (the reactor replies from row
/// slices without building a tensor).
pub fn encode_response_rows(trace_id: u64, rows: usize, cols: usize, data: &[f32]) -> Vec<u8> {
    debug_assert_eq!(rows * cols, data.len());
    let mut out = Vec::with_capacity(16 + data.len() * 4);
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&(cols as u32).to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    for &v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Encode a success reply.
pub fn encode_response(trace_id: u64, y: &Tensor) -> Vec<u8> {
    encode_response_rows(trace_id, y.dims()[0], y.dims()[1], y.data())
}

/// Encode an error frame with a code-specific payload.
pub fn encode_error(code: u32, trace_id: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + payload.len());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&code.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Encode a shed frame naming the refusing tier's queue.
pub fn encode_shed(trace_id: u64, tier: Tier) -> Vec<u8> {
    encode_error(CODE_SHED, trace_id, &tier.as_u32().to_le_bytes())
}

/// Encode a batch-failure frame carrying the cause.
pub fn encode_failure(trace_id: u64, msg: &str) -> Vec<u8> {
    let bytes = msg.as_bytes();
    let mut payload = Vec::with_capacity(4 + bytes.len());
    payload.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    payload.extend_from_slice(bytes);
    encode_error(CODE_BATCH_FAILED, trace_id, &payload)
}

/// Encode a control request frame.
pub fn encode_control(code: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(8);
    out.extend_from_slice(&CONTROL_SENTINEL.to_le_bytes());
    out.extend_from_slice(&code.to_le_bytes());
    out
}

/// Encode a control reply (length-prefixed body).
pub fn encode_control_reply(body: &str) -> Vec<u8> {
    let bytes = body.as_bytes();
    let mut out = Vec::with_capacity(4 + bytes.len());
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(bytes);
    out
}

/// Encode a cancel frame for an in-flight streamed request.
pub fn encode_cancel(trace_id: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(&STREAM_SENTINEL.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    out
}

/// Encode a stream prefix/delta frame from raw rows.
pub fn encode_stream_data(
    kind: u32,
    trace_id: u64,
    terms: usize,
    rows: usize,
    cols: usize,
    data: &[f32],
) -> Vec<u8> {
    debug_assert_eq!(rows * cols, data.len());
    let mut out = Vec::with_capacity(28 + data.len() * 4);
    out.extend_from_slice(&STREAM_SENTINEL.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&(cols as u32).to_le_bytes());
    out.extend_from_slice(&(terms as u32).to_le_bytes());
    for &v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Encode a stream end frame (total terms the reply reduced).
pub fn encode_stream_end(trace_id: u64, terms: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(20);
    out.extend_from_slice(&STREAM_SENTINEL.to_le_bytes());
    out.extend_from_slice(&STREAM_END.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    out.extend_from_slice(&(terms as u32).to_le_bytes());
    out
}

// ---------------------------------------------------------------------
// Blocking clients (tests, CLI, loadgen's closed loop).

/// Read one little-endian `u32` (blocking).
pub fn read_u32<R: Read>(s: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read one little-endian `u64` (blocking).
pub fn read_u64<R: Read>(s: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    s.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s<R: Read>(s: &mut R, count: usize) -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; count * 4];
    s.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Turn a received error frame (header already consumed) into an error.
fn read_error_frame<R: Read>(s: &mut R, code: u32) -> anyhow::Error {
    match code {
        CODE_SHED => match read_u32(s) {
            Ok(wire) => {
                let queue = Tier::from_u32(wire)
                    .map(|t| t.name().to_string())
                    .unwrap_or_else(|| format!("#{wire}"));
                anyhow::anyhow!("server shed the request: {queue} queue full")
            }
            Err(e) => anyhow::anyhow!("truncated shed frame: {e}"),
        },
        CODE_BATCH_FAILED => {
            let msg = read_u32(s)
                .and_then(|len| {
                    let mut buf = vec![0u8; (len as usize).min(4096)];
                    s.read_exact(&mut buf)?;
                    Ok(String::from_utf8_lossy(&buf).into_owned())
                })
                .unwrap_or_else(|e| format!("<truncated failure frame: {e}>"));
            anyhow::anyhow!("server error: {msg}")
        }
        CODE_MALFORMED => anyhow::anyhow!("server rejected the request as malformed"),
        other => anyhow::anyhow!("unknown error frame code {other}"),
    }
}

fn read_reply(s: &mut TcpStream) -> anyhow::Result<(Tensor, u64)> {
    let rn = read_u32(s)? as usize;
    let rc = read_u32(s)? as usize;
    // success and error frames both carry the trace id at bytes 8..16
    let echoed = read_u64(s)?;
    if rn == 0 {
        return Err(read_error_frame(s, rc as u32));
    }
    anyhow::ensure!(rc > 0, "empty response frame");
    let data = read_f32s(s, rn * rc)?;
    Ok((Tensor::from_vec(&[rn, rc], data), echoed))
}

/// Blocking client call at [`Tier::Exact`] (used by tests/loadgen).
pub fn client_infer(addr: std::net::SocketAddr, x: &Tensor) -> anyhow::Result<Tensor> {
    client_infer_tier(addr, x, Tier::Exact)
}

/// Blocking client call at an explicit service tier.
pub fn client_infer_tier(
    addr: std::net::SocketAddr,
    x: &Tensor,
    tier: Tier,
) -> anyhow::Result<Tensor> {
    Ok(client_infer_traced(addr, x, tier, 0)?.0)
}

/// Blocking client call carrying an explicit trace id (0 asks the
/// server to assign one). Returns the reply and the trace id echoed in
/// the response header — the key for joining this request onto the
/// flight recorder's spans (`trace` control frame or CLI subcommand).
pub fn client_infer_traced(
    addr: std::net::SocketAddr,
    x: &Tensor,
    tier: Tier,
    trace_id: u64,
) -> anyhow::Result<(Tensor, u64)> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(&encode_request(x, tier, false, trace_id))?;
    read_reply(&mut s)
}

fn client_control(addr: std::net::SocketAddr, code: u32) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(&encode_control(code))?;
    let len = read_u32(&mut s)? as usize;
    let mut buf = vec![0u8; len];
    s.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

/// Fetch the server's Prometheus-style metrics exposition over the
/// metrics control frame.
pub fn client_metrics(addr: std::net::SocketAddr) -> anyhow::Result<String> {
    client_control(addr, CTRL_METRICS)
}

/// Fetch the flight recorder's Chrome-trace JSON over the trace control
/// frame (`[]` when the server runs without a recorder).
pub fn client_trace_json(addr: std::net::SocketAddr) -> anyhow::Result<String> {
    client_control(addr, CTRL_TRACE)
}

/// One server frame as seen by a streaming client.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// Immediate truncated-prefix result (`terms` terms folded so far).
    Prefix { terms: usize, y: Tensor },
    /// One later basis term: ⊎-add onto the running reconstruction.
    Delta { terms: usize, y: Tensor },
    /// Refinement finished after `terms` total terms.
    End { terms: usize },
    /// The server declined to stream (tier not eligible) and sent one
    /// classic reply frame.
    Final { y: Tensor },
}

/// Blocking client for a progressive-refinement request.
pub struct StreamClient {
    s: TcpStream,
    /// trace id echoed by the server (updated on the first frame when
    /// the request asked the server to assign one)
    pub trace_id: u64,
}

impl StreamClient {
    /// Open a connection and send one streamed request.
    pub fn start(
        addr: std::net::SocketAddr,
        x: &Tensor,
        tier: Tier,
        trace_id: u64,
    ) -> anyhow::Result<Self> {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(&encode_request(x, tier, true, trace_id))?;
        Ok(StreamClient { s, trace_id })
    }

    /// Read the next server frame on this stream (blocking).
    pub fn recv(&mut self) -> anyhow::Result<StreamEvent> {
        let w0 = read_u32(&mut self.s)?;
        if w0 == STREAM_SENTINEL {
            let kind = read_u32(&mut self.s)?;
            self.trace_id = read_u64(&mut self.s)?;
            if kind == STREAM_END {
                let terms = read_u32(&mut self.s)? as usize;
                return Ok(StreamEvent::End { terms });
            }
            let rows = read_u32(&mut self.s)? as usize;
            let cols = read_u32(&mut self.s)? as usize;
            let terms = read_u32(&mut self.s)? as usize;
            let y = Tensor::from_vec(&[rows, cols], read_f32s(&mut self.s, rows * cols)?);
            return Ok(match kind {
                STREAM_PREFIX => StreamEvent::Prefix { terms, y },
                _ => StreamEvent::Delta { terms, y },
            });
        }
        let rc = read_u32(&mut self.s)? as usize;
        self.trace_id = read_u64(&mut self.s)?;
        if w0 == 0 {
            return Err(read_error_frame(&mut self.s, rc as u32));
        }
        anyhow::ensure!(rc > 0, "empty response frame");
        let y = Tensor::from_vec(&[w0 as usize, rc], read_f32s(&mut self.s, w0 as usize * rc)?);
        Ok(StreamEvent::Final { y })
    }

    /// Ask the server to stop refining this request; frames already in
    /// flight (and the end frame) still arrive.
    pub fn cancel(&mut self) -> anyhow::Result<()> {
        self.s.write_all(&encode_cancel(self.trace_id))?;
        Ok(())
    }
}

/// A fully collected streamed reply.
#[derive(Debug, Clone)]
pub struct StreamReply {
    /// false when the server declined to stream: `prefix` is then the
    /// complete classic reply and `terms_total` is 0 (unreported)
    pub streamed: bool,
    pub prefix: Tensor,
    pub deltas: Vec<Tensor>,
    pub terms_total: usize,
    pub trace_id: u64,
}

impl StreamReply {
    /// Fold the prefix and deltas in arrival order — the same left fold
    /// the scheduler used, so the result is bit-identical to the
    /// non-streamed reply at the same term count.
    pub fn reconstruct(&self) -> Tensor {
        let mut acc = self.prefix.clone();
        for d in &self.deltas {
            acc = acc.add(d);
        }
        acc
    }
}

/// Send one streamed request and collect every frame until the end.
pub fn client_infer_stream(
    addr: std::net::SocketAddr,
    x: &Tensor,
    tier: Tier,
    trace_id: u64,
) -> anyhow::Result<StreamReply> {
    let mut c = StreamClient::start(addr, x, tier, trace_id)?;
    let mut prefix: Option<Tensor> = None;
    let mut deltas = Vec::new();
    loop {
        match c.recv()? {
            StreamEvent::Prefix { y, .. } => prefix = Some(y),
            StreamEvent::Delta { y, .. } => deltas.push(y),
            StreamEvent::End { terms } => {
                let prefix =
                    prefix.ok_or_else(|| anyhow::anyhow!("stream ended without a prefix"))?;
                return Ok(StreamReply {
                    streamed: true,
                    prefix,
                    deltas,
                    terms_total: terms,
                    trace_id: c.trace_id,
                });
            }
            StreamEvent::Final { y } => {
                return Ok(StreamReply {
                    streamed: false,
                    prefix: y,
                    deltas,
                    terms_total: 0,
                    trace_id: c.trace_id,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// A mixed wire session: requests (plain + streamed), a control
    /// frame, a cancel, a zero-dim reject, and an unknown-tier reject
    /// whose payload must be swallowed.
    fn sample_session() -> (Vec<u8>, Vec<Frame>) {
        let mut rng = Rng::seed(0xC0DEC);
        let mut bytes = Vec::new();
        let mut expect = Vec::new();

        let x1 = Tensor::randn(&[2, 3], 1.0, &mut rng);
        bytes.extend_from_slice(&encode_request(&x1, Tier::Exact, false, 7));
        expect.push(Frame::Request {
            n: 2,
            d: 3,
            tier: Tier::Exact,
            stream: false,
            trace_id: 7,
            data: x1.data().to_vec(),
        });

        bytes.extend_from_slice(&encode_control(CTRL_METRICS));
        expect.push(Frame::Control { code: CTRL_METRICS });

        let x2 = Tensor::randn(&[1, 5], 1.0, &mut rng);
        bytes.extend_from_slice(&encode_request(&x2, Tier::BestEffort, true, 9));
        expect.push(Frame::Request {
            n: 1,
            d: 5,
            tier: Tier::BestEffort,
            stream: true,
            trace_id: 9,
            data: x2.data().to_vec(),
        });

        bytes.extend_from_slice(&encode_cancel(9));
        expect.push(Frame::Cancel { trace_id: 9 });

        // zero-dim header: rejected with its trace id, no payload
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&4u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&21u64.to_le_bytes());
        expect.push(Frame::Malformed { trace_id: 21, fatal: false });

        // unknown tier 99 with a 2·3 payload the decoder must skip
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&22u64.to_le_bytes());
        for i in 0..6 {
            bytes.extend_from_slice(&(i as f32).to_le_bytes());
        }
        expect.push(Frame::Malformed { trace_id: 22, fatal: false });

        // a valid request after the skipped payload proves survival
        let x3 = Tensor::randn(&[3, 2], 1.0, &mut rng);
        bytes.extend_from_slice(&encode_request(&x3, Tier::Throughput, false, 23));
        expect.push(Frame::Request {
            n: 3,
            d: 2,
            tier: Tier::Throughput,
            stream: false,
            trace_id: 23,
            data: x3.data().to_vec(),
        });

        (bytes, expect)
    }

    fn drain(dec: &mut FrameDecoder) -> Vec<Frame> {
        let mut out = Vec::new();
        while let Some(f) = dec.next_frame() {
            out.push(f);
        }
        out
    }

    #[test]
    fn decoder_matches_one_shot_decode() {
        let (bytes, expect) = sample_session();
        let mut dec = FrameDecoder::new();
        dec.feed(&bytes);
        assert_eq!(drain(&mut dec), expect);
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn decoder_handles_byte_at_a_time() {
        let (bytes, expect) = sample_session();
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for b in &bytes {
            dec.feed(std::slice::from_ref(b));
            got.extend(drain(&mut dec));
        }
        assert_eq!(got, expect);
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn decoder_handles_arbitrary_split_boundaries() {
        let (bytes, expect) = sample_session();
        let mut rng = Rng::seed(0x5117);
        for it in 0..200 {
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            let mut off = 0;
            while off < bytes.len() {
                let take = 1 + rng.below(64).min(bytes.len() - off - 1);
                dec.feed(&bytes[off..off + take]);
                off += take;
                got.extend(drain(&mut dec));
            }
            assert_eq!(got, expect, "iteration {it} diverged");
            assert_eq!(dec.pending(), 0, "iteration {it} left bytes behind");
        }
    }

    #[test]
    fn oversized_header_is_fatal() {
        let mut dec = FrameDecoder::new();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(u32::MAX - 2).to_le_bytes());
        bytes.extend_from_slice(&(u32::MAX - 2).to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&5u64.to_le_bytes());
        dec.feed(&bytes);
        assert_eq!(dec.next_frame(), Some(Frame::Malformed { trace_id: 5, fatal: true }));
    }

    #[test]
    fn streamed_reply_reconstructs_by_left_fold() {
        let prefix = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let d1 = Tensor::from_vec(&[1, 2], vec![0.5, 0.25]);
        let d2 = Tensor::from_vec(&[1, 2], vec![0.125, 0.0625]);
        let reply = StreamReply {
            streamed: true,
            prefix: prefix.clone(),
            deltas: vec![d1.clone(), d2.clone()],
            terms_total: 3,
            trace_id: 1,
        };
        let want = prefix.add(&d1).add(&d2);
        assert_eq!(reply.reconstruct().data(), want.data());
    }
}
