//! Nonblocking TCP front-end: a single-threaded epoll reactor serving
//! protocol v3 (see [`crate::serve::protocol`] for the wire format and
//! the blocking clients).
//!
//! One `tcp-reactor` thread owns every connection: nonblocking accept,
//! per-connection incremental frame decode
//! ([`FrameDecoder`](crate::serve::protocol::FrameDecoder) inside
//! [`Conn`]), request pipelining (any number of requests in flight per
//! connection, replies matched by `trace_id`), and progressive
//! refinement streaming for `Throughput`/`BestEffort` requests that set
//! the tier word's [`STREAM_FLAG`](crate::serve::protocol::STREAM_FLAG)
//! — an immediate truncated-prefix frame, then one ⊎ delta frame per
//! later basis term until the tier budget is consumed or the client
//! cancels.
//!
//! Replies are produced on the batcher thread and carried back by a
//! [`WakeQueue`] + wake-pipe handoff (loom-modeled in
//! [`crate::serve::reactor`]): the scheduler-side sinks only push a
//! [`Completion`] and poke the pipe; the reactor encodes and writes all
//! bytes itself, so connection state needs no locks. Write backpressure
//! is wired into admission control: a connection whose unflushed reply
//! backlog exceeds [`HIGH_WATER_BYTES`](crate::serve::conn::HIGH_WATER_BYTES)
//! sheds new requests at their own tier (`CODE_SHED`, counted in that
//! tier's shed statistics) instead of buffering without bound for a
//! slow reader.
//!
//! Error paths are connection-preserving where the frame boundary is
//! still trustworthy: malformed requests (zero dims, unknown tier) echo
//! the parsed `trace_id` in their `CODE_MALFORMED` frame and its error
//! span, and later pipelined frames on the same connection still serve.
//! Only an oversized `n·d` header (the payload length itself is a lie)
//! and unknown control codes close the connection.

use crate::coordinator::{Coordinator, RefineSink, ReplySink, Response, StreamFrame, SubmitError};
use crate::obs::{SpanKind, TraceRecorder};
use crate::qos::Tier;
use crate::serve::conn::{Conn, Inflight};
use crate::serve::protocol::{
    encode_control_reply, encode_error, encode_failure, encode_response, encode_shed,
    encode_stream_data, encode_stream_end, Frame, STREAM_DELTA, STREAM_PREFIX,
};
use crate::serve::reactor::{raw_fd, Event, Poller, WakeQueue, WakeReceiver, Waker};
use crate::tensor::Tensor;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{thread, Arc};
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};

pub use crate::serve::protocol::{
    client_infer, client_infer_tier, client_infer_traced, client_metrics, client_trace_json,
    CODE_BATCH_FAILED, CODE_MALFORMED, CODE_SHED, CONTROL_SENTINEL, CTRL_METRICS, CTRL_TRACE,
};

/// Poller token of the TCP listener.
const LISTENER_TOKEN: u64 = 0;
/// Poller token of the wake pipe's read end.
const WAKER_TOKEN: u64 = 1;
/// Connection slot `s` registers as token `TOKEN_BASE + s`.
const TOKEN_BASE: u64 = 2;
/// Poll timeout: a safety net under the wake pipe, bounding shutdown
/// latency even if a wake signal is lost to a platform quirk.
const POLL_TIMEOUT_MS: i32 = 500;

/// Handle to a running TCP server.
pub struct TcpServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    reactor_thread: Option<thread::JoinHandle<()>>,
}

impl TcpServerHandle {
    pub fn stop(mut self) {
        // ordering: SeqCst — lone on/off stop flag; not part of any
        // multi-location protocol, so the strongest ordering costs
        // nothing here and keeps the shutdown path trivially correct.
        self.stop.store(true, Ordering::SeqCst);
        // poke the reactor out of its poll
        self.waker.signal();
        if let Some(h) = self.reactor_thread.take() {
            let _ = h.join();
        }
    }
}

/// One scheduler-side result carried into the reactor thread. `token` +
/// `generation` name the connection the request arrived on; a stale
/// generation (slot reused after a close) drops the completion instead
/// of misdelivering it.
enum Completion {
    Reply { token: u64, generation: u64, resp: Response },
    Stream { token: u64, generation: u64, frame: StreamFrame },
}

struct ConnEntry {
    conn: Conn<TcpStream>,
    generation: u64,
    /// (read, write) interest currently registered with the poller
    interest: (bool, bool),
}

struct Reactor {
    listener: TcpListener,
    poller: Poller,
    wake_rx: WakeReceiver,
    completions: Arc<WakeQueue<Completion>>,
    waker: Arc<Waker>,
    conns: Vec<Option<ConnEntry>>,
    free: Vec<usize>,
    next_gen: u64,
    coord: Arc<Coordinator>,
    rec: Option<Arc<TraceRecorder>>,
    stop: Arc<AtomicBool>,
}

impl Reactor {
    fn now(&self) -> u64 {
        self.rec.as_ref().map_or(0, |r| r.now_ns())
    }

    /// Close the request-root span: every exit path of a parsed request
    /// — success, shed, batch failure — leaves a `Request` span so
    /// error traces are as complete as served ones.
    fn record_request(&self, trace_id: u64, tier: Tier, error: bool, t0: u64, detail: [u64; 3]) {
        if let Some(rec) = &self.rec {
            rec.record_span(trace_id, SpanKind::Request, tier, error, t0, rec.now_ns(), detail);
        }
    }

    fn run(&mut self) {
        let mut events: Vec<Event> = Vec::new();
        let mut scratch = vec![0u8; 64 * 1024];
        loop {
            // ordering: SeqCst — pairs with the SeqCst store in
            // `TcpServerHandle::stop`; see the rationale there.
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            if let Err(e) = self.poller.poll(&mut events, POLL_TIMEOUT_MS) {
                log::warn!("reactor poll error: {e}");
                continue;
            }
            for ev in &events {
                match ev.token {
                    LISTENER_TOKEN => self.accept_ready(),
                    WAKER_TOKEN => {} // cleared and drained below
                    token => self.conn_event(token, ev.readable, ev.writable, &mut scratch),
                }
            }
            // wake-latch protocol: drain the pipe, then the queue (the
            // queue's drain re-opens the wake window first). Draining
            // every pass also covers the fallback poller's timeouts.
            self.wake_rx.clear();
            for c in self.completions.drain() {
                self.complete(c);
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let t0 = self.now();
                    if let Err(e) = stream.set_nonblocking(true) {
                        log::warn!("set_nonblocking failed: {e}");
                        continue;
                    }
                    let slot = match self.free.pop() {
                        Some(s) => s,
                        None => {
                            self.conns.push(None);
                            self.conns.len() - 1
                        }
                    };
                    let token = TOKEN_BASE + slot as u64;
                    if let Err(e) = self.poller.register(raw_fd(&stream), token, true, false) {
                        log::warn!("poller register failed: {e}");
                        self.free.push(slot);
                        continue;
                    }
                    self.next_gen += 1;
                    self.conns[slot] = Some(ConnEntry {
                        conn: Conn::new(stream),
                        generation: self.next_gen,
                        interest: (true, false),
                    });
                    if let Some(r) = &self.rec {
                        let d = [token, 0, 0];
                        r.record_span(0, SpanKind::Accept, Tier::Exact, false, t0, r.now_ns(), d);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("accept error: {e}");
                    break;
                }
            }
        }
    }

    fn conn_event(&mut self, token: u64, readable: bool, writable: bool, scratch: &mut [u8]) {
        let slot = (token - TOKEN_BASE) as usize;
        let Some(mut entry) = self.conns.get_mut(slot).and_then(|o| o.take()) else {
            return; // stale event for a slot already torn down
        };
        let mut dead = false;
        if writable && entry.conn.wants_write() {
            dead = !self.flush(&mut entry);
        }
        if readable && !dead {
            match entry.conn.on_readable(scratch) {
                Ok((frames, eof)) => {
                    for f in frames {
                        self.handle_frame(&mut entry, token, f);
                    }
                    if eof {
                        entry.conn.closing = true;
                    }
                }
                Err(e) => {
                    log::debug!("connection read error: {e}");
                    dead = true;
                }
            }
        }
        if !dead && entry.conn.wants_write() {
            dead = !self.flush(&mut entry);
        }
        if dead || entry.conn.drained_for_close() {
            self.teardown(slot, token, entry);
        } else {
            self.update_interest(token, &mut entry);
            self.conns[slot] = Some(entry);
        }
    }

    /// Flush queued frames until the socket blocks; close each flushed
    /// frame's Write span. Returns false when the connection died.
    fn flush(&self, entry: &mut ConnEntry) -> bool {
        match entry.conn.on_writable() {
            Ok(done) => {
                if let Some(r) = &self.rec {
                    let t_end = r.now_ns();
                    let left = entry.conn.queued_frames() as u64;
                    for f in done {
                        let d = [f.bytes as u64, left, 0];
                        let k = SpanKind::Write;
                        r.record_span(f.trace_id, k, Tier::Exact, false, f.t_queued, t_end, d);
                    }
                }
                true
            }
            Err(e) => {
                log::debug!("connection write error: {e}");
                false
            }
        }
    }

    fn update_interest(&mut self, token: u64, entry: &mut ConnEntry) {
        let want = (true, entry.conn.wants_write());
        let fd = raw_fd(&entry.conn.stream);
        if want != entry.interest && self.poller.reregister(fd, token, want.0, want.1).is_ok() {
            entry.interest = want;
        }
    }

    fn teardown(&mut self, slot: usize, token: u64, entry: ConnEntry) {
        let _ = self.poller.deregister(raw_fd(&entry.conn.stream), token);
        self.free.push(slot);
        drop(entry);
    }

    fn handle_frame(&self, entry: &mut ConnEntry, token: u64, frame: Frame) {
        let t_req = self.now();
        match frame {
            Frame::Control { code } => {
                let body = match code {
                    CTRL_METRICS => self.coord.exposition(),
                    CTRL_TRACE => self.coord.trace_json(),
                    _ => {
                        entry.conn.queue_frame(encode_error(CODE_MALFORMED, 0, &[]), 0, t_req);
                        entry.conn.closing = true;
                        return;
                    }
                };
                entry.conn.queue_frame(encode_control_reply(&body), 0, t_req);
            }
            Frame::Cancel { trace_id } => entry.conn.cancel_inflight(trace_id),
            Frame::Malformed { trace_id, fatal } => {
                // the parsed trace id is echoed in both the frame and
                // its error span, so the client's correlation key still
                // joins onto the flight recorder
                if let Some(r) = &self.rec {
                    let d = [0, 0, 0];
                    let k = SpanKind::Decode;
                    r.record_span(trace_id, k, Tier::Exact, true, t_req, r.now_ns(), d);
                }
                let out = encode_error(CODE_MALFORMED, trace_id, &[]);
                entry.conn.queue_frame(out, trace_id, t_req);
                if fatal {
                    entry.conn.closing = true;
                }
            }
            Frame::Request { n, d, tier, stream, trace_id, data } => {
                self.handle_request(entry, token, t_req, (n, d), tier, stream, trace_id, data);
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_request(
        &self,
        entry: &mut ConnEntry,
        token: u64,
        t_req: u64,
        dims: (usize, usize),
        tier: Tier,
        stream: bool,
        wire_id: u64,
        data: Vec<f32>,
    ) {
        let (n, d) = dims;
        // 0 asks the server to assign; the reply header echoes the id
        let trace_id = if wire_id == 0 { self.coord.fresh_trace_id() } else { wire_id };
        if entry.conn.over_high_water() {
            // write backpressure feeds admission control: a reader too
            // slow for its own request rate sheds at its own tier
            self.coord.record_shed(tier);
            log::warn!(
                "request shed: write backlog {}B over high water ({tier})",
                entry.conn.write_backlog()
            );
            entry.conn.queue_frame(encode_shed(trace_id, tier), trace_id, t_req);
            self.record_request(trace_id, tier, true, t_req, [n as u64, 0, 0]);
            return;
        }
        let t_dec = self.now();
        let x = Tensor::from_vec(&[n, d], data);
        if let Some(r) = &self.rec {
            let detail = [n as u64, d as u64, 0];
            r.record_span(trace_id, SpanKind::Decode, tier, false, t_dec, r.now_ns(), detail);
        }
        // streaming is honored only for the tiers whose contract is
        // progressive (`Throughput`/`BestEffort`); others reply with one
        // classic frame even when the flag is set
        let streamed = stream && matches!(tier, Tier::Throughput | Tier::BestEffort);
        let generation = entry.generation;
        let q = self.completions.clone();
        let w = self.waker.clone();
        let sink = ReplySink::Callback(Arc::new(move |resp: Response| {
            if q.push(Completion::Reply { token, generation, resp }) {
                w.signal();
            }
        }));
        let mut cancel_flag = None;
        let refine = if streamed {
            let cancel = Arc::new(AtomicBool::new(false));
            cancel_flag = Some(cancel.clone());
            let q = self.completions.clone();
            let w = self.waker.clone();
            Some(RefineSink {
                emit: Arc::new(move |frame: StreamFrame| {
                    if q.push(Completion::Stream { token, generation, frame }) {
                        w.signal();
                    }
                }),
                cancel,
            })
        } else {
            None
        };
        let inf = Inflight { t_req, tier, rows: n, streamed, cancel: cancel_flag };
        entry.conn.register_inflight(trace_id, inf);
        match self.coord.submit_tier_callback(x, tier, trace_id, sink, refine) {
            Ok(()) => {}
            Err(SubmitError::Busy(full_tier)) => {
                entry.conn.take_inflight(trace_id);
                // surface the refusing tier's OWN control state: under
                // per-tier pressure a shed names exactly the tier whose
                // queue (and whose precision ladder) is saturated
                match &self.coord.qos {
                    Some(ctl) => log::warn!(
                        "request shed: {full_tier} queue full (tier pressure {})",
                        ctl.tier_pressure(full_tier)
                    ),
                    None => log::warn!("request shed: {full_tier} queue full"),
                }
                entry.conn.queue_frame(encode_shed(trace_id, full_tier), trace_id, t_req);
                self.record_request(trace_id, tier, true, t_req, [n as u64, 0, 0]);
            }
            Err(SubmitError::Closed) => {
                entry.conn.take_inflight(trace_id);
                let out = encode_failure(trace_id, "coordinator stopped");
                entry.conn.queue_frame(out, trace_id, t_req);
                self.record_request(trace_id, tier, true, t_req, [n as u64, 0, 0]);
            }
        }
    }

    fn complete(&mut self, c: Completion) {
        let (token, generation) = match &c {
            Completion::Reply { token, generation, .. } => (*token, *generation),
            Completion::Stream { token, generation, .. } => (*token, *generation),
        };
        let slot = match token.checked_sub(TOKEN_BASE) {
            Some(s) => s as usize,
            None => return,
        };
        let Some(mut entry) = self.conns.get_mut(slot).and_then(|o| o.take()) else {
            return; // connection closed before its completion arrived
        };
        if entry.generation != generation {
            self.conns[slot] = Some(entry); // slot reused: stale result
            return;
        }
        match c {
            Completion::Stream { frame, .. } => {
                let t0 = self.now();
                let kind = if frame.first { STREAM_PREFIX } else { STREAM_DELTA };
                let out = encode_stream_data(
                    kind,
                    frame.trace_id,
                    frame.terms,
                    frame.rows,
                    frame.cols,
                    &frame.data,
                );
                entry.conn.queue_frame(out, frame.trace_id, t0);
            }
            Completion::Reply { resp, .. } => {
                if let Some(inf) = entry.conn.take_inflight(resp.trace_id) {
                    self.finish_request(&mut entry, inf, resp);
                }
            }
        }
        let alive = if entry.conn.wants_write() { self.flush(&mut entry) } else { true };
        if !alive || entry.conn.drained_for_close() {
            self.teardown(slot, token, entry);
        } else {
            self.update_interest(token, &mut entry);
            self.conns[slot] = Some(entry);
        }
    }

    /// Encode the final reply for a completed request: a failure frame,
    /// a stream-end frame (streamed requests: the prefix/delta frames
    /// already went out), or a classic single response frame.
    fn finish_request(&self, entry: &mut ConnEntry, inf: Inflight, resp: Response) {
        let trace_id = resp.trace_id;
        if let Some(msg) = &resp.error {
            log::warn!("request failed: {msg}");
            let t0 = self.now();
            entry.conn.queue_frame(encode_failure(trace_id, msg), trace_id, t0);
            self.record_request(trace_id, inf.tier, true, inf.t_req, [inf.rows as u64, 0, 0]);
            return;
        }
        let t_rep = self.now();
        let out = if inf.streamed {
            encode_stream_end(trace_id, resp.terms)
        } else {
            encode_response(trace_id, &resp.logits)
        };
        let out_len = out.len() as u64;
        entry.conn.queue_frame(out, trace_id, t_rep);
        if let Some(r) = &self.rec {
            let d = [out_len, 0, 0];
            r.record_span(trace_id, SpanKind::Reply, inf.tier, false, t_rep, r.now_ns(), d);
        }
        let detail = [inf.rows as u64, resp.terms as u64, resp.grid_terms as u64];
        self.record_request(trace_id, inf.tier, false, inf.t_req, detail);
    }
}

/// Start serving on `addr` ("127.0.0.1:0" for an ephemeral port).
pub fn serve_tcp(addr: &str, coord: Arc<Coordinator>) -> anyhow::Result<TcpServerHandle> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let mut poller = Poller::new()?;
    poller.register(raw_fd(&listener), LISTENER_TOKEN, true, false)?;
    let (waker, wake_rx) = Waker::pair()?;
    poller.register(wake_rx.raw_fd(), WAKER_TOKEN, true, false)?;
    let waker = Arc::new(waker);
    let stop = Arc::new(AtomicBool::new(false));
    let rec = coord.recorder.clone();
    let mut reactor = Reactor {
        listener,
        poller,
        wake_rx,
        completions: Arc::new(WakeQueue::new()),
        waker: waker.clone(),
        conns: Vec::new(),
        free: Vec::new(),
        next_gen: 0,
        coord,
        rec,
        stop: stop.clone(),
    };
    let reactor_thread =
        thread::Builder::new().name("tcp-reactor".into()).spawn(move || reactor.run())?;
    log::info!("serving on {local} (reactor)");
    Ok(TcpServerHandle { addr: local, stop, waker, reactor_thread: Some(reactor_thread) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        BasisWorker, BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool,
    };
    use crate::serve::protocol::{
        client_infer_stream, encode_request, read_u32, read_u64, StreamClient, StreamEvent,
    };
    use crate::tensor::Rng;
    use std::io::{Read, Write};

    struct Double;
    impl BasisWorker for Double {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.scale(2.0))
        }
    }

    fn tiny_coordinator() -> Arc<Coordinator> {
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Double) as Box<dyn BasisWorker>));
        Arc::new(Coordinator::new(
            BatcherConfig::uniform(8, 200, 64),
            ExpansionScheduler::new(pool),
        ))
    }

    fn traced_coordinator(rec: Arc<TraceRecorder>) -> Arc<Coordinator> {
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Double) as Box<dyn BasisWorker>));
        Arc::new(Coordinator::new(
            BatcherConfig::uniform(8, 200, 64),
            ExpansionScheduler::new(pool).with_recorder(rec),
        ))
    }

    /// Worker `i` contributes term `x·(i+1)`: distinct per-term values
    /// make prefix/delta attribution visible in streamed replies.
    fn gain_coordinator(n: usize) -> Arc<Coordinator> {
        struct Gain(f32);
        impl BasisWorker for Gain {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                Ok(x.scale(self.0))
            }
        }
        let pool = WorkerPool::new(
            n,
            Arc::new(|i| Box::new(Gain((i + 1) as f32)) as Box<dyn BasisWorker>),
        );
        Arc::new(Coordinator::new(
            BatcherConfig::uniform(8, 200, 64),
            ExpansionScheduler::new(pool),
        ))
    }

    fn read_head(s: &mut TcpStream) -> (u32, u32, u64) {
        let a = read_u32(s).unwrap();
        let b = read_u32(s).unwrap();
        let id = read_u64(s).unwrap();
        (a, b, id)
    }

    /// One server frame, order-agnostic (pipelined replies interleave).
    enum Rf {
        Ok { id: u64, data: Vec<f32> },
        Err { code: u32, id: u64 },
    }

    fn read_frame(s: &mut TcpStream) -> Rf {
        let (n, c, id) = read_head(s);
        if n == 0 {
            match c {
                CODE_SHED => {
                    let _ = read_u32(s).unwrap();
                }
                CODE_BATCH_FAILED => {
                    let len = read_u32(s).unwrap() as usize;
                    let mut buf = vec![0u8; len];
                    s.read_exact(&mut buf).unwrap();
                }
                _ => {}
            }
            return Rf::Err { code: c, id };
        }
        let mut buf = vec![0u8; (n * c) as usize * 4];
        s.read_exact(&mut buf).unwrap();
        let data =
            buf.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect();
        Rf::Ok { id, data }
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut rng = Rng::seed(61);
        let x = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let y = client_infer(handle.addr, &x).unwrap();
        assert_eq!(y.dims(), &[3, 5]);
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a * 2.0 - b).abs() < 1e-5);
        }
        handle.stop();
    }

    #[test]
    fn tiered_requests_roundtrip() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
        let mut rng = Rng::seed(62);
        for tier in Tier::ALL {
            let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
            let y = client_infer_tier(handle.addr, &x, tier).unwrap();
            assert_eq!(y.dims(), &[2, 4]);
            assert_eq!(coord.metrics.tier_completed(tier), 1, "{tier}");
        }
        handle.stop();
    }

    #[test]
    fn multiple_clients_concurrently() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let addr = handle.addr;
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut rng = Rng::seed(70 + t);
                    for _ in 0..3 {
                        let x = Tensor::randn(&[1, 4], 1.0, &mut rng);
                        let y = client_infer(addr, &x).unwrap();
                        assert!((x.data()[0] * 2.0 - y.data()[0]).abs() < 1e-5);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        handle.stop();
    }

    #[test]
    fn malformed_header_echoes_trace_id_and_conn_survives() {
        let rec = Arc::new(TraceRecorder::default());
        let coord = traced_coordinator(rec.clone());
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        // n = 0 triggers the guard; the header still parses to trace 7
        let mut bad = Vec::new();
        bad.extend_from_slice(&0u32.to_le_bytes());
        bad.extend_from_slice(&5u32.to_le_bytes());
        bad.extend_from_slice(&Tier::Exact.as_u32().to_le_bytes());
        bad.extend_from_slice(&7u64.to_le_bytes());
        s.write_all(&bad).unwrap();
        let (z, code, id) = read_head(&mut s);
        assert_eq!((z, code), (0, CODE_MALFORMED));
        assert_eq!(id, 7, "parsed trace id must echo in the malformed frame");
        // non-fatal reject: the same connection still serves
        let x = Tensor::from_vec(&[1, 2], vec![1.0, -2.0]);
        s.write_all(&encode_request(&x, Tier::Exact, false, 8)).unwrap();
        match read_frame(&mut s) {
            Rf::Ok { id, data } => {
                assert_eq!(id, 8);
                assert_eq!(data, vec![2.0, -4.0]);
            }
            Rf::Err { code, id } => panic!("valid request rejected: code {code} id {id}"),
        }
        // the error span carries the parsed trace id too
        let evs = rec.events_for(7);
        assert!(
            evs.iter().any(|e| e.span == SpanKind::Decode && e.error),
            "malformed request must leave an error span under its trace id: {evs:?}"
        );
        handle.stop();
    }

    #[test]
    fn unknown_tier_rejected_conn_survives() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let mut bad = Vec::new();
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&99u32.to_le_bytes()); // no such tier
        bad.extend_from_slice(&5u64.to_le_bytes());
        bad.extend_from_slice(&1.0f32.to_le_bytes());
        s.write_all(&bad).unwrap();
        let (z, code, id) = read_head(&mut s);
        assert_eq!((z, code), (0, CODE_MALFORMED));
        assert_eq!(id, 5, "unknown-tier reject echoes the parsed trace id");
        // the payload was swallowed, so the next frame still decodes
        let x = Tensor::from_vec(&[1, 2], vec![3.0, 4.0]);
        s.write_all(&encode_request(&x, Tier::Exact, false, 6)).unwrap();
        match read_frame(&mut s) {
            Rf::Ok { id, data } => {
                assert_eq!(id, 6);
                assert_eq!(data, vec![6.0, 8.0]);
            }
            Rf::Err { code, id } => panic!("valid request rejected: code {code} id {id}"),
        }
        handle.stop();
    }

    #[test]
    fn pipelined_requests_one_segment_all_replied() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let xs: Vec<Tensor> = (0..3)
            .map(|k| Tensor::from_vec(&[1, 2], vec![k as f32, k as f32 + 0.5]))
            .collect();
        let mut seg = Vec::new();
        for (k, x) in xs.iter().enumerate() {
            seg.extend_from_slice(&encode_request(x, Tier::Exact, false, 11 + k as u64));
        }
        s.write_all(&seg).unwrap();
        let mut seen = std::collections::HashMap::new();
        for _ in 0..3 {
            match read_frame(&mut s) {
                Rf::Ok { id, data } => {
                    seen.insert(id, data);
                }
                Rf::Err { code, id } => panic!("pipelined request failed: code {code} id {id}"),
            }
        }
        for (k, x) in xs.iter().enumerate() {
            let want: Vec<f32> = x.data().iter().map(|v| v * 2.0).collect();
            assert_eq!(seen.get(&(11 + k as u64)), Some(&want), "reply {k}");
        }
        handle.stop();
    }

    #[test]
    fn pipelined_errors_interleaved_with_valid_requests() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        // one TCP segment: valid, malformed (n = 0), unknown tier, valid
        let mut seg = Vec::new();
        seg.extend_from_slice(&encode_request(&x, Tier::Exact, false, 21));
        seg.extend_from_slice(&0u32.to_le_bytes());
        seg.extend_from_slice(&1u32.to_le_bytes());
        seg.extend_from_slice(&Tier::Exact.as_u32().to_le_bytes());
        seg.extend_from_slice(&22u64.to_le_bytes());
        seg.extend_from_slice(&1u32.to_le_bytes());
        seg.extend_from_slice(&1u32.to_le_bytes());
        seg.extend_from_slice(&99u32.to_le_bytes());
        seg.extend_from_slice(&23u64.to_le_bytes());
        seg.extend_from_slice(&9.0f32.to_le_bytes());
        seg.extend_from_slice(&encode_request(&x, Tier::Exact, false, 24));
        s.write_all(&seg).unwrap();
        let mut oks = std::collections::HashMap::new();
        let mut errs = std::collections::HashMap::new();
        for _ in 0..4 {
            match read_frame(&mut s) {
                Rf::Ok { id, data } => {
                    oks.insert(id, data);
                }
                Rf::Err { code, id } => {
                    errs.insert(id, code);
                }
            }
        }
        assert_eq!(errs.get(&22), Some(&CODE_MALFORMED));
        assert_eq!(errs.get(&23), Some(&CODE_MALFORMED));
        let want = vec![2.0f32, 4.0];
        assert_eq!(oks.get(&21), Some(&want));
        assert_eq!(oks.get(&24), Some(&want));
        // non-fatal errors leave the connection serving
        s.write_all(&encode_request(&x, Tier::Exact, false, 25)).unwrap();
        match read_frame(&mut s) {
            Rf::Ok { id, .. } => assert_eq!(id, 25),
            Rf::Err { code, id } => panic!("follow-up rejected: code {code} id {id}"),
        }
        handle.stop();
    }

    #[test]
    fn pipelined_shed_interleaved_with_valid_requests() {
        struct Slow;
        impl BasisWorker for Slow {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                std::thread::sleep(std::time::Duration::from_millis(300));
                Ok(x.clone())
            }
        }
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Slow) as Box<dyn BasisWorker>));
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(1, 10, 2),
            ExpansionScheduler::new(pool),
        ));
        let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
        // saturate the Throughput queue in-process, keeping the worker busy
        let mut keep = Vec::new();
        let mut saturated = false;
        for _ in 0..16 {
            match coord.submit_tier(Tensor::zeros(&[1, 2]), Tier::Throughput) {
                Ok(rx) => keep.push(rx),
                Err(_) => {
                    saturated = true;
                    break;
                }
            }
        }
        assert!(saturated, "throughput queue must fill");
        // one segment: Exact request, Throughput request (shed), Exact
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let mut s = TcpStream::connect(handle.addr).unwrap();
        let mut seg = Vec::new();
        seg.extend_from_slice(&encode_request(&x, Tier::Exact, false, 31));
        seg.extend_from_slice(&encode_request(&x, Tier::Throughput, false, 32));
        seg.extend_from_slice(&encode_request(&x, Tier::Exact, false, 33));
        s.write_all(&seg).unwrap();
        let mut oks = Vec::new();
        let mut sheds = Vec::new();
        for _ in 0..3 {
            match read_frame(&mut s) {
                Rf::Ok { id, data } => oks.push((id, data)),
                Rf::Err { code, id } => {
                    assert_eq!(code, CODE_SHED);
                    sheds.push(id);
                }
            }
        }
        assert_eq!(sheds, vec![32], "only the saturated tier's request sheds");
        let mut ok_ids: Vec<u64> = oks.iter().map(|(id, _)| *id).collect();
        ok_ids.sort_unstable();
        assert_eq!(ok_ids, vec![31, 33], "other tiers still serve (per-tier admission)");
        assert!(coord.tier_shed(Tier::Throughput) >= 1);
        for rx in keep {
            let _ = rx.recv_timeout(std::time::Duration::from_secs(20));
        }
        handle.stop();
    }

    #[test]
    fn pipelined_batch_failures_keep_the_connection_serving() {
        struct Failing;
        impl BasisWorker for Failing {
            fn run(&mut self, _x: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("boom")
            }
        }
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Failing) as Box<dyn BasisWorker>));
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(4, 100, 16),
            ExpansionScheduler::new(pool),
        ));
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::zeros(&[1, 2]);
        let mut s = TcpStream::connect(handle.addr).unwrap();
        // one segment: failing request, malformed, failing request
        let mut seg = Vec::new();
        seg.extend_from_slice(&encode_request(&x, Tier::Exact, false, 41));
        seg.extend_from_slice(&0u32.to_le_bytes());
        seg.extend_from_slice(&1u32.to_le_bytes());
        seg.extend_from_slice(&Tier::Exact.as_u32().to_le_bytes());
        seg.extend_from_slice(&42u64.to_le_bytes());
        seg.extend_from_slice(&encode_request(&x, Tier::Exact, false, 43));
        s.write_all(&seg).unwrap();
        let mut errs = std::collections::HashMap::new();
        for _ in 0..3 {
            match read_frame(&mut s) {
                Rf::Ok { id, .. } => panic!("request {id} must not succeed"),
                Rf::Err { code, id } => {
                    errs.insert(id, code);
                }
            }
        }
        assert_eq!(errs.get(&41), Some(&CODE_BATCH_FAILED));
        assert_eq!(errs.get(&42), Some(&CODE_MALFORMED));
        assert_eq!(errs.get(&43), Some(&CODE_BATCH_FAILED));
        // batch failures are non-fatal to the connection
        s.write_all(&encode_request(&x, Tier::Exact, false, 44)).unwrap();
        match read_frame(&mut s) {
            Rf::Err { code, id } => {
                assert_eq!((code, id), (CODE_BATCH_FAILED, 44));
            }
            Rf::Ok { .. } => panic!("failing worker cannot succeed"),
        }
        handle.stop();
    }

    #[test]
    fn batch_failure_returns_error_frame() {
        struct Failing;
        impl BasisWorker for Failing {
            fn run(&mut self, _x: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("boom")
            }
        }
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Failing) as Box<dyn BasisWorker>));
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(4, 100, 16),
            ExpansionScheduler::new(pool),
        ));
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::zeros(&[1, 2]);
        let err = client_infer(handle.addr, &x).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom"), "error frame must carry the cause: {msg}");
        handle.stop();
    }

    #[test]
    fn streamed_reply_reconstructs_bit_identical_to_classic_reply() {
        // 2 workers: the tree reduction and the stream's left fold have
        // the same grouping, so the ⊎-sum of prefix + deltas must be
        // bit-identical to the non-streamed reply of the same request
        let coord = gain_coordinator(2);
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut rng = Rng::seed(90);
        let x = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let sr = client_infer_stream(handle.addr, &x, Tier::Throughput, 90).unwrap();
        assert!(sr.streamed, "throughput tier must honor the stream flag");
        assert_eq!(sr.terms_total, 2);
        assert_eq!(sr.deltas.len(), 1, "one delta after the prefix");
        // prefix is worker 0's term (x·1), the delta is worker 1's (x·2)
        for (p, v) in sr.prefix.data().iter().zip(x.data()) {
            assert_eq!(p.to_bits(), v.to_bits());
        }
        let y = client_infer_tier(handle.addr, &x, Tier::Throughput).unwrap();
        let r = sr.reconstruct();
        assert_eq!(r.dims(), y.dims());
        for (a, b) in r.data().iter().zip(y.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "⊎-sum must be bit-identical");
        }
        handle.stop();
    }

    #[test]
    fn exact_tier_ignores_stream_flag_single_frame_reply() {
        let coord = gain_coordinator(2);
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut rng = Rng::seed(91);
        let x = Tensor::randn(&[1, 4], 1.0, &mut rng);
        let sr = client_infer_stream(handle.addr, &x, Tier::Exact, 91).unwrap();
        assert!(!sr.streamed, "exact tier must decline to stream");
        assert!(sr.deltas.is_empty());
        let y = client_infer_tier(handle.addr, &x, Tier::Exact).unwrap();
        for (a, b) in sr.prefix.data().iter().zip(y.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "single-frame reply must be bit-identical");
        }
        handle.stop();
    }

    #[test]
    fn cancel_stops_refinement_before_the_budget() {
        // worker i sleeps (i+1)·250 ms, so terms arrive staggered and
        // the cancel lands well before the last term
        struct Staggered(u64);
        impl BasisWorker for Staggered {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                std::thread::sleep(std::time::Duration::from_millis(self.0));
                Ok(x.clone())
            }
        }
        let pool = WorkerPool::new(
            4,
            Arc::new(|i| Box::new(Staggered(250 * (i as u64 + 1))) as Box<dyn BasisWorker>),
        );
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(8, 200, 64),
            ExpansionScheduler::new(pool),
        ));
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let mut c = StreamClient::start(handle.addr, &x, Tier::BestEffort, 92).unwrap();
        match c.recv().unwrap() {
            StreamEvent::Prefix { terms, .. } => assert_eq!(terms, 1),
            other => panic!("expected the prefix first, got {other:?}"),
        }
        c.cancel().unwrap();
        let end_terms = loop {
            match c.recv().unwrap() {
                StreamEvent::Delta { .. } => {}
                StreamEvent::End { terms } => break terms,
                other => panic!("unexpected frame {other:?}"),
            }
        };
        assert!(end_terms < 4, "cancel must stop refinement early (got {end_terms}/4 terms)");
        handle.stop();
    }

    #[test]
    fn trace_id_echoed_and_request_spans_recorded() {
        let rec = Arc::new(TraceRecorder::default());
        let coord = traced_coordinator(rec.clone());
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::zeros(&[2, 3]);
        let (y, id) = client_infer_traced(handle.addr, &x, Tier::Balanced, 42).unwrap();
        assert_eq!(y.dims(), &[2, 3]);
        assert_eq!(id, 42, "caller-supplied trace id must echo back");
        let (_, assigned) = client_infer_traced(handle.addr, &x, Tier::Exact, 0).unwrap();
        assert_ne!(assigned, 0, "trace id 0 asks the server to assign one");
        // the Request/Reply/Write spans land just after the reply bytes,
        // so poll briefly for the reactor thread to record them
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let evs = rec.events_for(42);
            let has = |k: SpanKind| evs.iter().any(|e| e.span == k && !e.error);
            if has(SpanKind::Request)
                && has(SpanKind::Decode)
                && has(SpanKind::Admission)
                && has(SpanKind::Reply)
                && has(SpanKind::Write)
            {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "spans missing: {evs:?}");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        handle.stop();
    }

    #[test]
    fn control_frames_expose_metrics_and_trace() {
        let rec = Arc::new(TraceRecorder::default());
        let coord = traced_coordinator(rec);
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::zeros(&[1, 3]);
        let _ = client_infer_tier(handle.addr, &x, Tier::Throughput).unwrap();
        let metrics = client_metrics(handle.addr).unwrap();
        assert!(
            metrics.contains("# TYPE fpxint_requests_completed_total counter"),
            "missing completed-counter family:\n{metrics}"
        );
        assert!(
            metrics.contains("fpxint_request_latency_seconds_bucket"),
            "missing latency histogram:\n{metrics}"
        );
        assert!(
            metrics.contains("fpxint_trace_events_recorded_total"),
            "missing recorder series:\n{metrics}"
        );
        let trace = client_trace_json(handle.addr).unwrap();
        assert!(trace.trim_start().starts_with('['), "not a JSON array:\n{trace}");
        assert!(trace.contains("\"ph\""), "no trace events emitted:\n{trace}");
        handle.stop();
    }
}
