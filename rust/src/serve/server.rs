//! TCP front-end speaking a minimal binary protocol:
//!
//! request : [u32 n][u32 d][n·d × f32 LE]
//! response: [u32 n][u32 c][n·c × f32 LE]   (or [0][0] on shed/error)
//!
//! The server is a thin shim over the in-process [`Coordinator`]; one
//! OS thread per connection (std only — tokio is unavailable offline).

use crate::coordinator::Coordinator;
use crate::tensor::Tensor;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Handle to a running TCP server.
pub struct TcpServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn read_exact_u32(s: &mut TcpStream) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn handle_conn(mut stream: TcpStream, coord: Arc<Coordinator>) {
    loop {
        let n = match read_exact_u32(&mut stream) {
            Ok(v) => v as usize,
            Err(_) => return, // client closed
        };
        let d = match read_exact_u32(&mut stream) {
            Ok(v) => v as usize,
            Err(_) => return,
        };
        if n == 0 || d == 0 || n * d > 16 * 1024 * 1024 {
            let _ = stream.write_all(&0u32.to_le_bytes());
            let _ = stream.write_all(&0u32.to_le_bytes());
            return;
        }
        let mut buf = vec![0u8; n * d * 4];
        if stream.read_exact(&mut buf).is_err() {
            return;
        }
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let x = Tensor::from_vec(&[n, d], data);
        let reply = match coord.infer(x) {
            Ok(resp) => resp.logits,
            Err(e) => {
                log::warn!("request failed: {e:#}");
                let _ = stream.write_all(&0u32.to_le_bytes());
                let _ = stream.write_all(&0u32.to_le_bytes());
                continue;
            }
        };
        let (rn, rc) = (reply.dims()[0] as u32, reply.dims()[1] as u32);
        let mut out = Vec::with_capacity(8 + reply.numel() * 4);
        out.extend_from_slice(&rn.to_le_bytes());
        out.extend_from_slice(&rc.to_le_bytes());
        for &v in reply.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        if stream.write_all(&out).is_err() {
            return;
        }
    }
}

/// Start serving on `addr` ("127.0.0.1:0" for an ephemeral port).
pub fn serve_tcp(addr: &str, coord: Arc<Coordinator>) -> anyhow::Result<TcpServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept_thread = std::thread::Builder::new().name("tcp-accept".into()).spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let coord = coord.clone();
                    let _ = std::thread::Builder::new()
                        .name("tcp-conn".into())
                        .spawn(move || handle_conn(stream, coord));
                }
                Err(e) => log::warn!("accept error: {e}"),
            }
        }
    })?;
    log::info!("serving on {local}");
    Ok(TcpServerHandle { addr: local, stop, accept_thread: Some(accept_thread) })
}

/// Blocking client call against a running server (used by tests/loadgen).
pub fn client_infer(addr: std::net::SocketAddr, x: &Tensor) -> anyhow::Result<Tensor> {
    let mut s = TcpStream::connect(addr)?;
    let (n, d) = (x.dims()[0] as u32, x.dims()[1] as u32);
    let mut msg = Vec::with_capacity(8 + x.numel() * 4);
    msg.extend_from_slice(&n.to_le_bytes());
    msg.extend_from_slice(&d.to_le_bytes());
    for &v in x.data() {
        msg.extend_from_slice(&v.to_le_bytes());
    }
    s.write_all(&msg)?;
    let rn = read_exact_u32(&mut s)? as usize;
    let rc = read_exact_u32(&mut s)? as usize;
    anyhow::ensure!(rn > 0 && rc > 0, "server shed the request");
    let mut buf = vec![0u8; rn * rc * 4];
    s.read_exact(&mut buf)?;
    let data: Vec<f32> = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Tensor::from_vec(&[rn, rc], data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        BasisWorker, BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool,
    };
    use crate::tensor::Rng;

    struct Double;
    impl BasisWorker for Double {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.scale(2.0))
        }
    }

    fn tiny_coordinator() -> Arc<Coordinator> {
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Double) as Box<dyn BasisWorker>));
        Arc::new(Coordinator::new(
            BatcherConfig { max_batch: 8, max_wait_us: 200, queue_cap: 64 },
            ExpansionScheduler::new(pool),
        ))
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut rng = Rng::seed(61);
        let x = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let y = client_infer(handle.addr, &x).unwrap();
        assert_eq!(y.dims(), &[3, 5]);
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a * 2.0 - b).abs() < 1e-5);
        }
        handle.stop();
    }

    #[test]
    fn multiple_clients_concurrently() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let addr = handle.addr;
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut rng = Rng::seed(70 + t);
                    for _ in 0..3 {
                        let x = Tensor::randn(&[1, 4], 1.0, &mut rng);
                        let y = client_infer(addr, &x).unwrap();
                        assert!((x.data()[0] * 2.0 - y.data()[0]).abs() < 1e-5);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        handle.stop();
    }

    #[test]
    fn malformed_header_rejected() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        // n = 0 triggers the guard
        s.write_all(&0u32.to_le_bytes()).unwrap();
        s.write_all(&5u32.to_le_bytes()).unwrap();
        let mut reply = [0u8; 8];
        s.read_exact(&mut reply).unwrap();
        assert_eq!(reply, [0u8; 8]);
        handle.stop();
    }
}
