//! TCP front-end speaking a minimal binary protocol:
//!
//! request : [u32 n][u32 d][u32 tier][u64 trace_id][n·d × f32 LE]
//! response: [u32 n][u32 c][u64 trace_id][n·c × f32 LE]
//!           [0][0][u64 trace_id][u32 tier]  shed: that tier's bounded
//!                                           queue was full (per-tier
//!                                           admission control; `tier` is
//!                                           the [`Tier`] wire encoding
//!                                           of the queue that refused
//!                                           the request)
//!           [0][1][u64 trace_id][u32 len][len × u8]
//!                                           batch failure (UTF-8 message)
//!           [0][2][u64 trace_id]            malformed request (bad header
//!                                           or unknown tier; `trace_id`
//!                                           is 0 when the header never
//!                                           parsed far enough to carry
//!                                           one); the connection is
//!                                           closed
//! control : [u32::MAX][u32 code]  →  [u32 len][len × u8]
//!           code 1 = Prometheus-style metrics exposition (text)
//!           code 2 = flight-recorder dump as Chrome-trace JSON
//!
//! `tier` is the QoS service tier ([`Tier`] wire encoding): it selects
//! how many basis terms of the series the coordinator reduces for this
//! request, and which bounded queue admits it. `trace_id` correlates the
//! reply with the flight recorder's spans: 0 asks the server to assign a
//! fresh id (echoed in the response header), any other value is threaded
//! through verbatim. Malformed requests close the connection before a
//! trace id exists, so they are the one error path without a span. The
//! server is a thin shim over the in-process [`Coordinator`]; one OS
//! thread per connection (std only — tokio is unavailable offline).

use crate::coordinator::{Coordinator, SubmitError};
use crate::obs::{SpanKind, TraceRecorder};
use crate::qos::Tier;
use crate::tensor::Tensor;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{thread, Arc};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

/// Error code in the `[0][code]` response header: per-tier shed frame
/// (payload = the refusing tier's wire encoding).
pub const CODE_SHED: u32 = 0;
/// Error code: batch failure (payload = length-prefixed UTF-8 message).
pub const CODE_BATCH_FAILED: u32 = 1;
/// Error code: malformed request header or unknown tier (no payload).
pub const CODE_MALFORMED: u32 = 2;

/// `n` sentinel marking a control frame; the `d` word carries the
/// control code and no tensor payload follows.
pub const CONTROL_SENTINEL: u32 = u32::MAX;
/// Control code: reply with the Prometheus-style metrics exposition.
pub const CTRL_METRICS: u32 = 1;
/// Control code: reply with the flight recorder's Chrome-trace JSON.
pub const CTRL_TRACE: u32 = 2;

/// Handle to a running TCP server.
pub struct TcpServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl TcpServerHandle {
    pub fn stop(mut self) {
        // ordering: SeqCst — lone on/off stop flag; not part of any
        // multi-location protocol, so the strongest ordering costs
        // nothing here and keeps the shutdown path trivially correct.
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn read_exact_u32(s: &mut TcpStream) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_exact_u64(s: &mut TcpStream) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    s.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_error_frame(stream: &mut TcpStream, code: u32, trace_id: u64, payload: &[u8]) -> bool {
    let mut out = Vec::with_capacity(16 + payload.len());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&code.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    out.extend_from_slice(payload);
    stream.write_all(&out).is_ok()
}

fn write_shed_frame(stream: &mut TcpStream, trace_id: u64, tier: Tier) -> bool {
    write_error_frame(stream, CODE_SHED, trace_id, &tier.as_u32().to_le_bytes())
}

fn write_failure_frame(stream: &mut TcpStream, trace_id: u64, msg: &str) -> bool {
    let bytes = msg.as_bytes();
    let mut payload = Vec::with_capacity(4 + bytes.len());
    payload.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    payload.extend_from_slice(bytes);
    write_error_frame(stream, CODE_BATCH_FAILED, trace_id, &payload)
}

/// Close the request-root span: every exit path of a parsed request —
/// success, shed, batch failure — leaves a `Request` span so error
/// traces are as complete as served ones.
fn record_request(
    rec: &Option<Arc<TraceRecorder>>,
    trace_id: u64,
    tier: Tier,
    error: bool,
    t0: u64,
    detail: [u64; 3],
) {
    if let Some(rec) = rec {
        rec.record_span(trace_id, SpanKind::Request, tier, error, t0, rec.now_ns(), detail);
    }
}

fn handle_conn(mut stream: TcpStream, coord: Arc<Coordinator>) {
    let rec = coord.recorder.clone();
    loop {
        let n = match read_exact_u32(&mut stream) {
            Ok(v) => v,
            Err(_) => return, // client closed
        };
        // the request-root span opens at the first header byte of this
        // frame, so it encloses decode, admission and reply
        let t_req = rec.as_ref().map_or(0, |r| r.now_ns());
        let d = match read_exact_u32(&mut stream) {
            Ok(v) => v,
            Err(_) => return,
        };
        if n == CONTROL_SENTINEL {
            // control frames carry no tensor, so they are matched
            // before the n·d size guard
            let body = match d {
                CTRL_METRICS => coord.exposition(),
                CTRL_TRACE => coord.trace_json(),
                _ => {
                    let _ = write_error_frame(&mut stream, CODE_MALFORMED, 0, &[]);
                    return;
                }
            };
            let bytes = body.as_bytes();
            let mut out = Vec::with_capacity(4 + bytes.len());
            out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            out.extend_from_slice(bytes);
            if stream.write_all(&out).is_err() {
                return;
            }
            continue;
        }
        let (n, d) = (n as usize, d as usize);
        if n == 0 || d == 0 || n * d > 16 * 1024 * 1024 {
            let _ = write_error_frame(&mut stream, CODE_MALFORMED, 0, &[]);
            return;
        }
        let tier = match read_exact_u32(&mut stream).ok().and_then(Tier::from_u32) {
            Some(t) => t,
            None => {
                let _ = write_error_frame(&mut stream, CODE_MALFORMED, 0, &[]);
                return;
            }
        };
        let wire_id = match read_exact_u64(&mut stream) {
            Ok(v) => v,
            Err(_) => return,
        };
        // 0 asks the server to assign; the reply header echoes the id
        let trace_id = if wire_id == 0 { coord.fresh_trace_id() } else { wire_id };
        let t_dec = rec.as_ref().map_or(0, |r| r.now_ns());
        let mut buf = vec![0u8; n * d * 4];
        if stream.read_exact(&mut buf).is_err() {
            return;
        }
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let x = Tensor::from_vec(&[n, d], data);
        if let Some(r) = &rec {
            let detail = [n as u64, d as u64, 0];
            r.record_span(trace_id, SpanKind::Decode, tier, false, t_dec, r.now_ns(), detail);
        }
        let rx = match coord.submit_tier_traced(x, tier, trace_id) {
            Ok(rx) => rx,
            Err(SubmitError::Busy(full_tier)) => {
                // surface the refusing tier's OWN control state: under
                // per-tier pressure a shed names exactly the tier whose
                // queue (and whose precision ladder) is saturated
                match &coord.qos {
                    Some(ctl) => log::warn!(
                        "request shed: {full_tier} queue full (tier pressure {})",
                        ctl.tier_pressure(full_tier)
                    ),
                    None => log::warn!("request shed: {full_tier} queue full"),
                }
                let sent = write_shed_frame(&mut stream, trace_id, full_tier);
                record_request(&rec, trace_id, tier, true, t_req, [n as u64, 0, 0]);
                if !sent {
                    return;
                }
                continue;
            }
            Err(SubmitError::Closed) => {
                let sent = write_failure_frame(&mut stream, trace_id, "coordinator stopped");
                record_request(&rec, trace_id, tier, true, t_req, [n as u64, 0, 0]);
                if !sent {
                    return;
                }
                continue;
            }
        };
        let resp = match rx.recv() {
            Ok(resp) => resp,
            Err(_) => {
                // batcher died mid-request; tell the client explicitly
                let sent = write_failure_frame(&mut stream, trace_id, "coordinator stopped");
                record_request(&rec, trace_id, tier, true, t_req, [n as u64, 0, 0]);
                if !sent {
                    return;
                }
                continue;
            }
        };
        if let Some(msg) = &resp.error {
            log::warn!("request failed: {msg}");
            let sent = write_failure_frame(&mut stream, trace_id, msg);
            record_request(&rec, trace_id, tier, true, t_req, [n as u64, 0, 0]);
            if !sent {
                return;
            }
            continue;
        }
        let reply = &resp.logits;
        let t_rep = rec.as_ref().map_or(0, |r| r.now_ns());
        let (rn, rc) = (reply.dims()[0] as u32, reply.dims()[1] as u32);
        let mut out = Vec::with_capacity(16 + reply.numel() * 4);
        out.extend_from_slice(&rn.to_le_bytes());
        out.extend_from_slice(&rc.to_le_bytes());
        out.extend_from_slice(&resp.trace_id.to_le_bytes());
        for &v in reply.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let sent = stream.write_all(&out).is_ok();
        if let Some(r) = &rec {
            let detail = [out.len() as u64, 0, 0];
            r.record_span(trace_id, SpanKind::Reply, tier, !sent, t_rep, r.now_ns(), detail);
        }
        let detail = [n as u64, resp.terms as u64, resp.grid_terms as u64];
        record_request(&rec, trace_id, tier, !sent, t_req, detail);
        if !sent {
            return;
        }
    }
}

/// Start serving on `addr` ("127.0.0.1:0" for an ephemeral port).
pub fn serve_tcp(addr: &str, coord: Arc<Coordinator>) -> anyhow::Result<TcpServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept_thread = thread::Builder::new().name("tcp-accept".into()).spawn(move || {
        for conn in listener.incoming() {
            // ordering: SeqCst — pairs with the SeqCst store in
            // `TcpServerHandle::stop`; see the rationale there.
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let coord = coord.clone();
                    let _ = thread::Builder::new()
                        .name("tcp-conn".into())
                        .spawn(move || handle_conn(stream, coord));
                }
                Err(e) => log::warn!("accept error: {e}"),
            }
        }
    })?;
    log::info!("serving on {local}");
    Ok(TcpServerHandle { addr: local, stop, accept_thread: Some(accept_thread) })
}

/// Blocking client call at [`Tier::Exact`] (used by tests/loadgen).
pub fn client_infer(addr: std::net::SocketAddr, x: &Tensor) -> anyhow::Result<Tensor> {
    client_infer_tier(addr, x, Tier::Exact)
}

/// Blocking client call at an explicit service tier.
pub fn client_infer_tier(
    addr: std::net::SocketAddr,
    x: &Tensor,
    tier: Tier,
) -> anyhow::Result<Tensor> {
    Ok(client_infer_traced(addr, x, tier, 0)?.0)
}

/// Blocking client call carrying an explicit trace id (0 asks the
/// server to assign one). Returns the reply and the trace id echoed in
/// the response header — the key for joining this request onto the
/// flight recorder's spans (`trace` control frame or CLI subcommand).
pub fn client_infer_traced(
    addr: std::net::SocketAddr,
    x: &Tensor,
    tier: Tier,
    trace_id: u64,
) -> anyhow::Result<(Tensor, u64)> {
    let mut s = TcpStream::connect(addr)?;
    let (n, d) = (x.dims()[0] as u32, x.dims()[1] as u32);
    let mut msg = Vec::with_capacity(20 + x.numel() * 4);
    msg.extend_from_slice(&n.to_le_bytes());
    msg.extend_from_slice(&d.to_le_bytes());
    msg.extend_from_slice(&tier.as_u32().to_le_bytes());
    msg.extend_from_slice(&trace_id.to_le_bytes());
    for &v in x.data() {
        msg.extend_from_slice(&v.to_le_bytes());
    }
    s.write_all(&msg)?;
    let rn = read_exact_u32(&mut s)? as usize;
    let rc = read_exact_u32(&mut s)? as usize;
    // success and error frames both carry the trace id at bytes 8..16
    let echoed = read_exact_u64(&mut s)?;
    if rn == 0 {
        match rc as u32 {
            CODE_SHED => {
                let wire = read_exact_u32(&mut s)?;
                let queue = Tier::from_u32(wire)
                    .map(|t| t.name().to_string())
                    .unwrap_or_else(|| format!("#{wire}"));
                anyhow::bail!("server shed the request: {queue} queue full");
            }
            CODE_BATCH_FAILED => {
                let len = read_exact_u32(&mut s)? as usize;
                let mut buf = vec![0u8; len.min(4096)];
                s.read_exact(&mut buf)?;
                anyhow::bail!("server error: {}", String::from_utf8_lossy(&buf));
            }
            CODE_MALFORMED => anyhow::bail!("server rejected the request as malformed"),
            other => anyhow::bail!("unknown error frame code {other}"),
        }
    }
    anyhow::ensure!(rc > 0, "empty response frame");
    let mut buf = vec![0u8; rn * rc * 4];
    s.read_exact(&mut buf)?;
    let data: Vec<f32> = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((Tensor::from_vec(&[rn, rc], data), echoed))
}

fn client_control(addr: std::net::SocketAddr, code: u32) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(&CONTROL_SENTINEL.to_le_bytes())?;
    s.write_all(&code.to_le_bytes())?;
    let len = read_exact_u32(&mut s)? as usize;
    let mut buf = vec![0u8; len];
    s.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

/// Fetch the server's Prometheus-style metrics exposition over the
/// metrics control frame.
pub fn client_metrics(addr: std::net::SocketAddr) -> anyhow::Result<String> {
    client_control(addr, CTRL_METRICS)
}

/// Fetch the flight recorder's Chrome-trace JSON over the trace control
/// frame (`[]` when the server runs without a recorder).
pub fn client_trace_json(addr: std::net::SocketAddr) -> anyhow::Result<String> {
    client_control(addr, CTRL_TRACE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        BasisWorker, BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool,
    };
    use crate::tensor::Rng;

    struct Double;
    impl BasisWorker for Double {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.scale(2.0))
        }
    }

    fn tiny_coordinator() -> Arc<Coordinator> {
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Double) as Box<dyn BasisWorker>));
        Arc::new(Coordinator::new(
            BatcherConfig::uniform(8, 200, 64),
            ExpansionScheduler::new(pool),
        ))
    }

    fn traced_coordinator(rec: Arc<TraceRecorder>) -> Arc<Coordinator> {
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Double) as Box<dyn BasisWorker>));
        Arc::new(Coordinator::new(
            BatcherConfig::uniform(8, 200, 64),
            ExpansionScheduler::new(pool).with_recorder(rec),
        ))
    }

    fn frame_code(reply: &[u8; 8]) -> (u32, u32) {
        (
            u32::from_le_bytes(reply[0..4].try_into().unwrap()),
            u32::from_le_bytes(reply[4..8].try_into().unwrap()),
        )
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut rng = Rng::seed(61);
        let x = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let y = client_infer(handle.addr, &x).unwrap();
        assert_eq!(y.dims(), &[3, 5]);
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a * 2.0 - b).abs() < 1e-5);
        }
        handle.stop();
    }

    #[test]
    fn tiered_requests_roundtrip() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
        let mut rng = Rng::seed(62);
        for tier in Tier::ALL {
            let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
            let y = client_infer_tier(handle.addr, &x, tier).unwrap();
            assert_eq!(y.dims(), &[2, 4]);
            assert_eq!(coord.metrics.tier_completed(tier), 1, "{tier}");
        }
        handle.stop();
    }

    #[test]
    fn multiple_clients_concurrently() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let addr = handle.addr;
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut rng = Rng::seed(70 + t);
                    for _ in 0..3 {
                        let x = Tensor::randn(&[1, 4], 1.0, &mut rng);
                        let y = client_infer(addr, &x).unwrap();
                        assert!((x.data()[0] * 2.0 - y.data()[0]).abs() < 1e-5);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        handle.stop();
    }

    #[test]
    fn malformed_header_rejected() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        // n = 0 triggers the guard
        s.write_all(&0u32.to_le_bytes()).unwrap();
        s.write_all(&5u32.to_le_bytes()).unwrap();
        let mut reply = [0u8; 8];
        s.read_exact(&mut reply).unwrap();
        assert_eq!(frame_code(&reply), (0, CODE_MALFORMED));
        handle.stop();
    }

    #[test]
    fn unknown_tier_rejected() {
        let coord = tiny_coordinator();
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let mut s = TcpStream::connect(handle.addr).unwrap();
        s.write_all(&1u32.to_le_bytes()).unwrap();
        s.write_all(&1u32.to_le_bytes()).unwrap();
        s.write_all(&99u32.to_le_bytes()).unwrap(); // no such tier
        let mut reply = [0u8; 8];
        s.read_exact(&mut reply).unwrap();
        assert_eq!(frame_code(&reply), (0, CODE_MALFORMED));
        handle.stop();
    }

    #[test]
    fn shed_frame_names_the_full_tier_queue() {
        struct Slow;
        impl BasisWorker for Slow {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                std::thread::sleep(std::time::Duration::from_millis(500));
                Ok(x.clone())
            }
        }
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Slow) as Box<dyn BasisWorker>));
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(1, 10, 2),
            ExpansionScheduler::new(pool),
        ));
        let handle = serve_tcp("127.0.0.1:0", coord.clone()).unwrap();
        // saturate the Throughput queue in-process, keeping the worker busy
        let mut keep = Vec::new();
        let mut saturated = false;
        for _ in 0..16 {
            match coord.submit_tier(Tensor::zeros(&[1, 2]), Tier::Throughput) {
                Ok(rx) => keep.push(rx),
                Err(_) => {
                    saturated = true;
                    break;
                }
            }
        }
        assert!(saturated, "throughput queue must fill");
        // a TCP request at the saturated tier gets a shed frame naming it
        let err = client_infer_tier(handle.addr, &Tensor::zeros(&[1, 2]), Tier::Throughput)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("throughput queue full"), "shed reason missing tier: {msg}");
        assert!(coord.tier_shed(Tier::Throughput) >= 1);
        // other tiers are still admitted (per-tier admission control)
        let y = client_infer_tier(handle.addr, &Tensor::zeros(&[1, 2]), Tier::Exact).unwrap();
        assert_eq!(y.dims(), &[1, 2]);
        for rx in keep {
            let _ = rx.recv_timeout(std::time::Duration::from_secs(20));
        }
        handle.stop();
    }

    #[test]
    fn batch_failure_returns_error_frame() {
        struct Failing;
        impl BasisWorker for Failing {
            fn run(&mut self, _x: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("boom")
            }
        }
        let pool =
            WorkerPool::new(1, Arc::new(|_| Box::new(Failing) as Box<dyn BasisWorker>));
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(4, 100, 16),
            ExpansionScheduler::new(pool),
        ));
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::zeros(&[1, 2]);
        let err = client_infer(handle.addr, &x).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom"), "error frame must carry the cause: {msg}");
        handle.stop();
    }

    #[test]
    fn trace_id_echoed_and_request_spans_recorded() {
        let rec = Arc::new(TraceRecorder::default());
        let coord = traced_coordinator(rec.clone());
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::zeros(&[2, 3]);
        let (y, id) = client_infer_traced(handle.addr, &x, Tier::Balanced, 42).unwrap();
        assert_eq!(y.dims(), &[2, 3]);
        assert_eq!(id, 42, "caller-supplied trace id must echo back");
        let (_, assigned) = client_infer_traced(handle.addr, &x, Tier::Exact, 0).unwrap();
        assert_ne!(assigned, 0, "trace id 0 asks the server to assign one");
        // the Request/Reply spans land just after the reply bytes, so
        // poll briefly for the connection thread to record them
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let evs = rec.events_for(42);
            let has = |k: SpanKind| evs.iter().any(|e| e.span == k && !e.error);
            if has(SpanKind::Request)
                && has(SpanKind::Decode)
                && has(SpanKind::Admission)
                && has(SpanKind::Reply)
            {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "spans missing: {evs:?}");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        handle.stop();
    }

    #[test]
    fn control_frames_expose_metrics_and_trace() {
        let rec = Arc::new(TraceRecorder::default());
        let coord = traced_coordinator(rec);
        let handle = serve_tcp("127.0.0.1:0", coord).unwrap();
        let x = Tensor::zeros(&[1, 3]);
        let _ = client_infer_tier(handle.addr, &x, Tier::Throughput).unwrap();
        let metrics = client_metrics(handle.addr).unwrap();
        assert!(
            metrics.contains("# TYPE fpxint_requests_completed_total counter"),
            "missing completed-counter family:\n{metrics}"
        );
        assert!(
            metrics.contains("fpxint_request_latency_seconds_bucket"),
            "missing latency histogram:\n{metrics}"
        );
        assert!(
            metrics.contains("fpxint_trace_events_recorded_total"),
            "missing recorder series:\n{metrics}"
        );
        let trace = client_trace_json(handle.addr).unwrap();
        assert!(trace.trim_start().starts_with('['), "not a JSON array:\n{trace}");
        assert!(trace.contains("\"ph\""), "no trace events emitted:\n{trace}");
        handle.stop();
    }
}
