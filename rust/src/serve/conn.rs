//! Per-connection state machine for the reactor: an incremental
//! [`FrameDecoder`] on the read side, a queue of partially written
//! frames on the write side, and the in-flight request table that
//! matches completions (and cancels) back to their `trace_id`. All IO
//! here is nonblocking `read`/`write` — short reads and short writes
//! are the normal case, never an error.
//!
//! The type is generic over the stream so the state machine is testable
//! against scripted in-memory streams; the reactor instantiates it with
//! `TcpStream`.

use crate::qos::Tier;
use crate::serve::protocol::{Frame, FrameDecoder};
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};

/// Write-backlog high-water mark: a connection whose unflushed reply
/// bytes exceed this sheds new requests at their own tier instead of
/// buffering without bound for a reader slower than its request rate.
pub const HIGH_WATER_BYTES: usize = 256 * 1024;

/// One queued (possibly partially written) outbound frame.
struct OutFrame {
    bytes: Vec<u8>,
    off: usize,
    trace_id: u64,
    /// recorder timestamp when the frame was queued — the start of the
    /// Write span closed when the last byte is flushed
    t_queued: u64,
}

/// A fully flushed frame, reported so the reactor can close its Write
/// span.
pub struct Flushed {
    pub trace_id: u64,
    pub t_queued: u64,
    pub bytes: usize,
}

/// Book-keeping for one in-flight request on this connection.
pub struct Inflight {
    /// recorder timestamp of the request's first header byte
    pub t_req: u64,
    pub tier: Tier,
    pub rows: usize,
    pub streamed: bool,
    /// cancel flag shared with the scheduler's refinement loop
    pub cancel: Option<Arc<AtomicBool>>,
}

/// Per-connection reactor state.
pub struct Conn<S> {
    pub stream: S,
    pub decoder: FrameDecoder,
    out: VecDeque<OutFrame>,
    out_bytes: usize,
    inflight: HashMap<u64, Inflight>,
    /// set when the connection should close once the write queue drains
    pub closing: bool,
}

impl<S: Read + Write> Conn<S> {
    pub fn new(stream: S) -> Self {
        Conn {
            stream,
            decoder: FrameDecoder::new(),
            out: VecDeque::new(),
            out_bytes: 0,
            inflight: HashMap::new(),
            closing: false,
        }
    }

    /// Drain the socket until it would block, feeding the decoder.
    /// Returns the decoded frames and whether the peer closed (EOF).
    pub fn on_readable(&mut self, scratch: &mut [u8]) -> std::io::Result<(Vec<Frame>, bool)> {
        let mut frames = Vec::new();
        let mut eof = false;
        loop {
            match self.stream.read(scratch) {
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(k) => {
                    self.decoder.feed(&scratch[..k]);
                    while let Some(f) = self.decoder.next_frame() {
                        frames.push(f);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok((frames, eof))
    }

    /// Queue an encoded frame for writing.
    pub fn queue_frame(&mut self, bytes: Vec<u8>, trace_id: u64, t_queued: u64) {
        self.out_bytes += bytes.len();
        self.out.push_back(OutFrame { bytes, off: 0, trace_id, t_queued });
    }

    /// Flush queued frames until the socket would block. Returns the
    /// frames whose last byte went out (so their Write spans can close).
    pub fn on_writable(&mut self) -> std::io::Result<Vec<Flushed>> {
        let mut done = Vec::new();
        while let Some(front) = self.out.front_mut() {
            match self.stream.write(&front.bytes[front.off..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(k) => {
                    front.off += k;
                    self.out_bytes -= k;
                    if front.off == front.bytes.len() {
                        if let Some(f) = self.out.pop_front() {
                            done.push(Flushed {
                                trace_id: f.trace_id,
                                t_queued: f.t_queued,
                                bytes: f.bytes.len(),
                            });
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(done)
    }

    /// Unflushed outbound bytes.
    pub fn write_backlog(&self) -> usize {
        self.out_bytes
    }

    /// True when the write backlog says this reader is too slow for
    /// another reply to be queued — new requests shed at their own tier.
    pub fn over_high_water(&self) -> bool {
        self.out_bytes > HIGH_WATER_BYTES
    }

    pub fn wants_write(&self) -> bool {
        !self.out.is_empty()
    }

    /// Outbound frames still queued (fully or partially unwritten).
    pub fn queued_frames(&self) -> usize {
        self.out.len()
    }

    /// True once the connection is fully drained and flagged closing.
    pub fn drained_for_close(&self) -> bool {
        self.closing && self.out.is_empty()
    }

    pub fn register_inflight(&mut self, trace_id: u64, inf: Inflight) {
        self.inflight.insert(trace_id, inf);
    }

    pub fn take_inflight(&mut self, trace_id: u64) -> Option<Inflight> {
        self.inflight.remove(&trace_id)
    }

    pub fn inflight_count(&self) -> usize {
        self.inflight.len()
    }

    /// Flip the cancel flag of an in-flight streamed request; unknown
    /// ids (already completed, or never submitted) are ignored.
    pub fn cancel_inflight(&mut self, trace_id: u64) {
        if let Some(inf) = self.inflight.get(&trace_id) {
            if let Some(c) = &inf.cancel {
                // ordering: Relaxed — lone advisory stop flag polled by
                // the scheduler's refinement loop; nothing is published
                // through it, so atomicity alone is the contract.
                c.store(true, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::{encode_request, encode_response};
    use crate::tensor::Tensor;

    /// Scripted stream: reads pop from `input` chunks, writes accept at
    /// most `write_cap` bytes then claim WouldBlock.
    struct Scripted {
        input: VecDeque<Vec<u8>>,
        written: Vec<u8>,
        write_cap: usize,
        eof_after_input: bool,
    }

    impl Read for Scripted {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            match self.input.pop_front() {
                Some(chunk) => {
                    let k = chunk.len().min(buf.len());
                    buf[..k].copy_from_slice(&chunk[..k]);
                    if k < chunk.len() {
                        self.input.push_front(chunk[k..].to_vec());
                    }
                    Ok(k)
                }
                None if self.eof_after_input => Ok(0),
                None => Err(ErrorKind::WouldBlock.into()),
            }
        }
    }

    impl Write for Scripted {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.write_cap == 0 {
                return Err(ErrorKind::WouldBlock.into());
            }
            let k = buf.len().min(self.write_cap);
            self.written.extend_from_slice(&buf[..k]);
            self.write_cap -= k;
            Ok(k)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn scripted(chunks: Vec<Vec<u8>>, write_cap: usize) -> Conn<Scripted> {
        Conn::new(Scripted {
            input: chunks.into(),
            written: Vec::new(),
            write_cap,
            eof_after_input: false,
        })
    }

    #[test]
    fn pipelined_frames_in_one_segment_all_decode() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let mut seg = Vec::new();
        seg.extend_from_slice(&encode_request(&x, Tier::Exact, false, 1));
        seg.extend_from_slice(&encode_request(&x, Tier::BestEffort, false, 2));
        let mut conn = scripted(vec![seg], 0);
        let mut scratch = [0u8; 4096];
        let (frames, eof) = conn.on_readable(&mut scratch).unwrap();
        assert!(!eof);
        assert_eq!(frames.len(), 2);
    }

    #[test]
    fn partial_writes_resume_and_report_flushed_frames() {
        let y = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let frame = encode_response(7, &y);
        let total = frame.len();
        let mut conn = scripted(vec![], 5);
        conn.queue_frame(frame, 7, 100);
        // first pass: 5 bytes fit, frame stays queued
        assert!(conn.on_writable().unwrap().is_empty());
        assert!(conn.wants_write());
        assert_eq!(conn.write_backlog(), total - 5);
        // let the rest through
        conn.stream.write_cap = usize::MAX;
        let done = conn.on_writable().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].trace_id, 7);
        assert_eq!(done[0].t_queued, 100);
        assert_eq!(done[0].bytes, total);
        assert_eq!(conn.write_backlog(), 0);
        assert!(!conn.wants_write());
    }

    #[test]
    fn high_water_trips_and_recovers() {
        let mut conn = scripted(vec![], 0);
        conn.queue_frame(vec![0u8; HIGH_WATER_BYTES + 1], 1, 0);
        assert!(conn.over_high_water());
        conn.stream.write_cap = usize::MAX;
        conn.on_writable().unwrap();
        assert!(!conn.over_high_water());
    }

    #[test]
    fn cancel_flips_only_the_named_inflight() {
        let mut conn = scripted(vec![], 0);
        let c1 = Arc::new(AtomicBool::new(false));
        let c2 = Arc::new(AtomicBool::new(false));
        conn.register_inflight(
            1,
            Inflight {
                t_req: 0,
                tier: Tier::BestEffort,
                rows: 1,
                streamed: true,
                cancel: Some(c1.clone()),
            },
        );
        conn.register_inflight(
            2,
            Inflight {
                t_req: 0,
                tier: Tier::BestEffort,
                rows: 1,
                streamed: true,
                cancel: Some(c2.clone()),
            },
        );
        conn.cancel_inflight(1);
        conn.cancel_inflight(99); // unknown id is a no-op
        // ordering: Relaxed — test-side read of the advisory flag.
        assert!(c1.load(Ordering::Relaxed));
        assert!(!c2.load(Ordering::Relaxed));
        assert!(conn.take_inflight(1).is_some());
        assert_eq!(conn.inflight_count(), 1);
    }
}
