//! Elementwise / reduction / activation ops on `Tensor`.

use super::Tensor;

impl Tensor {
    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::from_vec(self.dims(), self.data().iter().map(|&v| f(v)).collect())
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in self.data_mut() {
            *v = f(*v);
        }
    }

    /// Elementwise zip of two same-shaped tensors.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.dims(), other.dims(), "zip shape mismatch");
        Tensor::from_vec(
            self.dims(),
            self.data().iter().zip(other.data()).map(|(&a, &b)| f(a, b)).collect(),
        )
    }

    pub fn add(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a + b)
    }

    pub fn sub(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a - b)
    }

    pub fn mul(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|v| v * s)
    }

    /// `self += alpha * o` in place (hot-loop friendly AXPY).
    pub fn axpy(&mut self, alpha: f32, o: &Tensor) {
        assert_eq!(self.dims(), o.dims(), "axpy shape mismatch");
        for (d, &s) in self.data_mut().iter_mut().zip(o.data()) {
            *d += alpha * s;
        }
    }

    pub fn relu(&self) -> Tensor {
        self.map(|v| v.max(0.0))
    }

    /// tanh-approximation GELU (the BERT variant).
    pub fn gelu(&self) -> Tensor {
        self.map(gelu_scalar)
    }

    /// Row-wise softmax over the last axis of a rank-2 tensor.
    pub fn softmax_rows(&self) -> Tensor {
        assert_eq!(self.shape().rank(), 2);
        let (r, c) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            let row = self.row(i);
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0;
            for j in 0..c {
                let e = (row[j] - m).exp();
                out[i * c + j] = e;
                sum += e;
            }
            for j in 0..c {
                out[i * c + j] /= sum;
            }
        }
        Tensor::from_vec(&[r, c], out)
    }

    /// Row-wise log-softmax over the last axis of a rank-2 tensor.
    pub fn log_softmax_rows(&self) -> Tensor {
        assert_eq!(self.shape().rank(), 2);
        let (r, c) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            let row = self.row(i);
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let lse = row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
            for j in 0..c {
                out[i * c + j] = row[j] - lse;
            }
        }
        Tensor::from_vec(&[r, c], out)
    }

    /// Argmax over the last axis of a rank-2 tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.shape().rank(), 2);
        let (r, _c) = (self.dims()[0], self.dims()[1]);
        (0..r)
            .map(|i| {
                let row = self.row(i);
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap()
            })
            .collect()
    }

    /// Sum over axis 0 of a rank-2 tensor → rank-1 of length `cols`.
    pub fn sum_axis0(&self) -> Tensor {
        assert_eq!(self.shape().rank(), 2);
        let (r, c) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += v;
            }
        }
        Tensor::from_vec(&[c], out)
    }

    /// Broadcast-add a length-`cols` bias to every row of a rank-2 tensor.
    pub fn add_row_bias(&self, bias: &Tensor) -> Tensor {
        assert_eq!(self.shape().rank(), 2);
        let (r, c) = (self.dims()[0], self.dims()[1]);
        assert_eq!(bias.numel(), c, "bias length");
        let mut out = self.data().to_vec();
        for i in 0..r {
            for j in 0..c {
                out[i * c + j] += bias.data()[j];
            }
        }
        Tensor::from_vec(&[r, c], out)
    }

    /// 2×2 max pooling (stride 2) on NCHW.
    pub fn maxpool2(&self) -> Tensor {
        let (n, c, h, w) = (self.dims()[0], self.dims()[1], self.dims()[2], self.dims()[3]);
        let (oh, ow) = (h / 2, w / 2);
        let mut out = Tensor::zeros(&[n, c, oh, ow]);
        for ni in 0..n {
            for ci in 0..c {
                for oi in 0..oh {
                    for oj in 0..ow {
                        let mut m = f32::NEG_INFINITY;
                        for di in 0..2 {
                            for dj in 0..2 {
                                m = m.max(self.at(&[ni, ci, oi * 2 + di, oj * 2 + dj]));
                            }
                        }
                        out.set(&[ni, ci, oi, oj], m);
                    }
                }
            }
        }
        out
    }

    /// Global average pool NCHW → (N, C).
    pub fn global_avg_pool(&self) -> Tensor {
        let (n, c, h, w) = (self.dims()[0], self.dims()[1], self.dims()[2], self.dims()[3]);
        let hw = (h * w) as f32;
        let mut out = Tensor::zeros(&[n, c]);
        for ni in 0..n {
            for ci in 0..c {
                let base = (ni * c + ci) * h * w;
                let s: f32 = self.data()[base..base + h * w].iter().sum();
                out.set(&[ni, ci], s / hw);
            }
        }
        out
    }
}

/// Scalar GELU, tanh approximation.
pub fn gelu_scalar(v: f32) -> f32 {
    0.5 * v * (1.0 + (0.7978845608 * (v + 0.044715 * v * v * v)).tanh())
}

/// Derivative of the tanh-approx GELU (trainer backward pass).
pub fn gelu_grad_scalar(v: f32) -> f32 {
    let c = 0.7978845608f32;
    let inner = c * (v + 0.044715 * v * v * v);
    let t = inner.tanh();
    let dinner = c * (1.0 + 3.0 * 0.044715 * v * v);
    0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * dinner
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_mul() {
        let a = Tensor::vec1(&[1., 2., 3.]);
        let b = Tensor::vec1(&[4., 5., 6.]);
        assert_eq!(a.add(&b).data(), &[5., 7., 9.]);
        assert_eq!(b.sub(&a).data(), &[3., 3., 3.]);
        assert_eq!(a.mul(&b).data(), &[4., 10., 18.]);
    }

    #[test]
    fn axpy_accumulates() {
        let mut a = Tensor::vec1(&[1., 1.]);
        a.axpy(2.0, &Tensor::vec1(&[3., 4.]));
        assert_eq!(a.data(), &[7., 9.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 100., 100., 100.]);
        let s = t.softmax_rows();
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        // large-value row must not overflow
        assert!((s.at(&[1, 0]) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_consistent() {
        let t = Tensor::from_vec(&[1, 4], vec![0.3, -1.2, 2.0, 0.0]);
        let ls = t.log_softmax_rows();
        let s = t.softmax_rows();
        for j in 0..4 {
            assert!((ls.at(&[0, j]).exp() - s.at(&[0, j])).abs() < 1e-6);
        }
    }

    #[test]
    fn argmax_rows_picks_max() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 5., 1., 9., 2., 3.]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn gelu_matches_known_values() {
        // gelu(0)=0, gelu(large)≈large, gelu(-large)≈0
        assert_eq!(gelu_scalar(0.0), 0.0);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu_scalar(-10.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for &v in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let eps = 1e-3;
            let fd = (gelu_scalar(v + eps) - gelu_scalar(v - eps)) / (2.0 * eps);
            assert!((fd - gelu_grad_scalar(v)).abs() < 1e-3, "at {v}");
        }
    }

    #[test]
    fn maxpool_and_gap() {
        let t = Tensor::from_vec(
            &[1, 1, 4, 4],
            vec![1., 2., 3., 4., 5., 6., 7., 8., 9., 10., 11., 12., 13., 14., 15., 16.],
        );
        let p = t.maxpool2();
        assert_eq!(p.data(), &[6., 8., 14., 16.]);
        let g = t.global_avg_pool();
        assert_eq!(g.data(), &[8.5]);
    }

    #[test]
    fn row_bias_broadcasts() {
        let t = Tensor::from_vec(&[2, 2], vec![0., 0., 1., 1.]);
        let b = Tensor::vec1(&[10., 20.]);
        assert_eq!(t.add_row_bias(&b).data(), &[10., 20., 11., 21.]);
    }

    #[test]
    fn sum_axis0_works() {
        let t = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.sum_axis0().data(), &[9., 12.]);
    }
}
