//! Blocked matmul kernels. These are the FP hot path that the paper's
//! expanded INT GEMM (xint::gemm) is benchmarked against, so they are
//! written for cache behaviour: i-k-j loop order (unit-stride inner loop)
//! with k-blocking. See `perf_gemm` bench and EXPERIMENTS.md §Perf.

use super::Tensor;

const KC: usize = 256; // k-dimension block: keeps a B panel in L1/L2

/// `C = A × B` for rank-2 tensors `(m,k)×(k,n)`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(a, b, &mut c);
    c
}

/// `C += A × B` into a preallocated output (hot-loop friendly: no alloc).
pub fn matmul_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let n = b.dims()[1];
    assert_eq!(b.dims()[0], k);
    assert_eq!(c.dims(), &[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for k0 in (0..k).step_by(KC) {
        let kend = (k0 + KC).min(k);
        for i in 0..m {
            let arow = &ad[i * k..(i + 1) * k];
            let crow = &mut cd[i * n..(i + 1) * n];
            for p in k0..kend {
                let av = arow[p];
                if av == 0.0 {
                    continue; // sparse M_sa planes hit this often
                }
                let brow = &bd[p * n..(p + 1) * n];
                // unit-stride FMA loop — autovectorizes
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// `C = Aᵀ × B` for `(k,m)ᵀ×(k,n)` without materializing the transpose.
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul_at_b inner dims");
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// `C = A × Bᵀ` for `(m,k)×(n,k)ᵀ` without materializing the transpose.
pub fn matmul_a_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul_a_bt inner dims");
    let mut c = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &bd[j * k..(j + 1) * k];
            // dot product, unit stride on both sides
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.dims()[0], a.dims()[1]);
        let n = b.dims()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at(&[i, p]) * b.at(&[p, j]);
                }
                c.set(&[i, j], s);
            }
        }
        c
    }

    fn assert_close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.dims(), b.dims());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_small_exact() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Rng::seed(123);
        for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (17, 33, 9), (64, 300, 31)] {
            let a = Tensor::rand(&[m, k], -1.0, 1.0, &mut rng);
            let b = Tensor::rand(&[k, n], -1.0, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-5);
        }
    }

    #[test]
    fn at_b_matches_transpose() {
        let mut rng = Rng::seed(5);
        let a = Tensor::rand(&[7, 4], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&[7, 6], -1.0, 1.0, &mut rng);
        assert_close(&matmul_at_b(&a, &b), &matmul(&a.transpose2(), &b), 1e-5);
    }

    #[test]
    fn a_bt_matches_transpose() {
        let mut rng = Rng::seed(6);
        let a = Tensor::rand(&[5, 8], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&[9, 8], -1.0, 1.0, &mut rng);
        assert_close(&matmul_a_bt(&a, &b), &matmul(&a, &b.transpose2()), 1e-5);
    }

    #[test]
    fn matmul_into_accumulates() {
        let a = Tensor::from_vec(&[1, 1], vec![2.0]);
        let b = Tensor::from_vec(&[1, 1], vec![3.0]);
        let mut c = Tensor::from_vec(&[1, 1], vec![10.0]);
        matmul_into(&a, &b, &mut c);
        assert_eq!(c.data(), &[16.0]);
    }
}
