//! From-scratch dense tensor substrate.
//!
//! The paper's algorithm is tensor algebra (series expansion of weights and
//! activations, Eq. 3's expanded GEMM), so the whole stack sits on this
//! module: a row-major `f32` tensor with the ops the models and quantizers
//! need (matmul, im2col conv, broadcasting elementwise ops, reductions) and
//! an integer-plane tensor for the low-bit basis terms.
//!
//! No external array crate is available offline; this is deliberately a
//! small, well-tested implementation rather than a general ndarray clone.

mod conv;
mod matmul;
mod ops;
mod rng;
mod shape;

pub use conv::{col2im, conv2d, conv2d_grad_input, conv2d_grad_weight, im2col, Conv2dSpec};
pub use matmul::{matmul, matmul_at_b, matmul_a_bt, matmul_into};
pub use ops::{gelu_grad_scalar as gelu_grad, gelu_scalar};
pub use rng::Rng;
pub use shape::Shape;

/// Row-major dense `f32` tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

/// Dense integer tensor used for low-bit basis planes (`M̃_i` in Theorem 1).
/// Values are *semantically* INT(X); stored as `i32` so any X ≤ 31 fits.
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Shape,
    data: Vec<i32>,
}

impl Tensor {
    /// Create a tensor of zeros with the given shape.
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Create a tensor filled with `v`.
    pub fn full(dims: &[usize], v: f32) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![v; n] }
    }

    /// Build from raw data; panics if the element count mismatches.
    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(
            shape.numel(),
            data.len(),
            "shape {:?} needs {} elements, got {}",
            dims,
            shape.numel(),
            data.len()
        );
        Tensor { shape, data }
    }

    /// 1-D tensor from a slice.
    pub fn vec1(data: &[f32]) -> Self {
        Tensor::from_vec(&[data.len()], data.to_vec())
    }

    /// Uniform random tensor in `[lo, hi)`.
    pub fn rand(dims: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        let data = (0..n).map(|_| rng.uniform(lo, hi)).collect();
        Tensor { shape, data }
    }

    /// Standard-normal random tensor scaled by `std`.
    pub fn randn(dims: &[usize], std: f32, rng: &mut Rng) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Tensor { shape, data }
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, dims: &[usize]) -> Tensor {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), self.data.len(), "reshape numel mismatch");
        Tensor { shape, data: self.data.clone() }
    }

    /// In-place reshape (no copy).
    pub fn reshaped(mut self, dims: &[usize]) -> Tensor {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), self.data.len(), "reshape numel mismatch");
        self.shape = shape;
        self
    }

    /// 2-D transpose.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.shape.rank(), 2, "transpose2 needs rank 2");
        let (r, c) = (self.dims()[0], self.dims()[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(&[c, r], out)
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.rank(), 2);
        let c = self.dims()[1];
        &self.data[i * c..(i + 1) * c]
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.shape.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.shape.offset(idx);
        self.data[o] = v;
    }

    /// Maximum absolute value (`‖·‖∞`), 0 for empty tensors.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Minimum and maximum element.
    pub fn min_max(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        (lo, hi)
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Population variance of all elements.
    pub fn var(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let m = self.mean();
        self.data.iter().map(|&v| (v - m) * (v - m)).sum::<f32>() / self.data.len() as f32
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&v| v * v).sum::<f32>().sqrt()
    }
}

impl IntTensor {
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        IntTensor { shape, data: vec![0; n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<i32>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), data.len(), "IntTensor shape/data mismatch");
        IntTensor { shape, data }
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    /// Convert to `f32` (integer values are exact in f32 for |v| < 2^24).
    pub fn to_f32(&self) -> Tensor {
        Tensor::from_vec(self.dims(), self.data.iter().map(|&v| v as f32).collect())
    }

    /// Maximum absolute element.
    pub fn max_abs(&self) -> i32 {
        self.data.iter().fold(0i32, |m, &v| m.max(v.abs()))
    }

    /// True iff every element fits in a signed `bits`-bit integer
    /// (symmetric range `[-2^{b-1}, 2^{b-1}-1]`... we allow the full
    /// `|v| ≤ 2^{b-1}` bound used by symmetric quantizers).
    pub fn fits_signed(&self, bits: u32) -> bool {
        let lim = 1i32 << (bits - 1);
        self.data.iter().all(|&v| -lim <= v && v <= lim)
    }

    /// True iff every element is in the unsigned `bits`-bit range `[0, 2^b)`.
    pub fn fits_unsigned(&self, bits: u32) -> bool {
        let lim = 1i64 << bits;
        self.data.iter().all(|&v| 0 <= v && (v as i64) < lim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_full() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        assert!(t.data().iter().all(|&v| v == 0.0));
        let f = Tensor::full(&[4], 2.5);
        assert!(f.data().iter().all(|&v| v == 2.5));
    }

    #[test]
    fn from_vec_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.at(&[0, 1]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn from_vec_bad_len_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn transpose2_involution() {
        let mut rng = Rng::seed(7);
        let t = Tensor::rand(&[3, 5], -1.0, 1.0, &mut rng);
        assert_eq!(t.transpose2().transpose2(), t);
    }

    #[test]
    fn min_max_mean_var() {
        let t = Tensor::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.min_max(), (1.0, 4.0));
        assert_eq!(t.mean(), 2.5);
        assert!((t.var() - 1.25).abs() < 1e-6);
    }

    #[test]
    fn int_tensor_fits() {
        let t = IntTensor::from_vec(&[3], vec![-8, 0, 7]);
        assert!(t.fits_signed(4));
        let t2 = IntTensor::from_vec(&[1], vec![9]);
        assert!(!t2.fits_signed(4));
        let u = IntTensor::from_vec(&[2], vec![0, 15]);
        assert!(u.fits_unsigned(4));
        assert!(!u.fits_unsigned(3));
    }

    #[test]
    fn int_to_f32_exact() {
        let t = IntTensor::from_vec(&[2], vec![-7, 123]);
        assert_eq!(t.to_f32().data(), &[-7.0, 123.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|v| v as f32).collect());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.dims(), &[3, 2]);
    }
}
