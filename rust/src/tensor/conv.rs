//! 2-D convolution via im2col + GEMM, plus the gradients the trainer needs.
//!
//! Layout: NCHW activations, OIHW weights (the PyTorch convention the paper's
//! models use). im2col routes every conv through the same GEMM that the
//! series expansion quantizes, so conv layers inherit Eq. 3's expanded
//! multiplication for free.

use super::{matmul, Tensor};

/// Static geometry of a conv layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dSpec {
    pub in_ch: usize,
    pub out_ch: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    /// groups=in_ch gives depthwise conv (MobileNet-style substrate)
    pub groups: usize,
}

impl Conv2dSpec {
    pub fn new(in_ch: usize, out_ch: usize, k: usize, stride: usize, pad: usize) -> Self {
        Conv2dSpec { in_ch, out_ch, kh: k, kw: k, stride, pad, groups: 1 }
    }

    pub fn depthwise(ch: usize, k: usize, stride: usize, pad: usize) -> Self {
        Conv2dSpec { in_ch: ch, out_ch: ch, kh: k, kw: k, stride, pad, groups: ch }
    }

    pub fn grouped(mut self, groups: usize) -> Self {
        assert_eq!(self.in_ch % groups, 0);
        assert_eq!(self.out_ch % groups, 0);
        self.groups = groups;
        self
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }
}

/// Unfold one image `(C,H,W)` into a `(C·kh·kw, OH·OW)` column matrix.
pub fn im2col(x: &[f32], c: usize, h: usize, w: usize, spec: &Conv2dSpec) -> Tensor {
    let (oh, ow) = spec.out_hw(h, w);
    let rows = c * spec.kh * spec.kw;
    let cols = oh * ow;
    let mut out = vec![0.0f32; rows * cols];
    for ci in 0..c {
        for ki in 0..spec.kh {
            for kj in 0..spec.kw {
                let r = (ci * spec.kh + ki) * spec.kw + kj;
                let orow = &mut out[r * cols..(r + 1) * cols];
                for oi in 0..oh {
                    let ii = (oi * spec.stride + ki) as isize - spec.pad as isize;
                    if ii < 0 || ii >= h as isize {
                        continue;
                    }
                    let xrow = &x[(ci * h + ii as usize) * w..(ci * h + ii as usize + 1) * w];
                    for oj in 0..ow {
                        let jj = (oj * spec.stride + kj) as isize - spec.pad as isize;
                        if jj < 0 || jj >= w as isize {
                            continue;
                        }
                        orow[oi * ow + oj] = xrow[jj as usize];
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[rows, cols], out)
}

/// Fold a `(C·kh·kw, OH·OW)` column matrix back into `(C,H,W)`,
/// accumulating overlaps — the adjoint of `im2col` (used by backprop).
pub fn col2im(cols: &Tensor, c: usize, h: usize, w: usize, spec: &Conv2dSpec) -> Vec<f32> {
    let (oh, ow) = spec.out_hw(h, w);
    let ncols = oh * ow;
    let mut out = vec![0.0f32; c * h * w];
    let cd = cols.data();
    for ci in 0..c {
        for ki in 0..spec.kh {
            for kj in 0..spec.kw {
                let r = (ci * spec.kh + ki) * spec.kw + kj;
                let crow = &cd[r * ncols..(r + 1) * ncols];
                for oi in 0..oh {
                    let ii = (oi * spec.stride + ki) as isize - spec.pad as isize;
                    if ii < 0 || ii >= h as isize {
                        continue;
                    }
                    for oj in 0..ow {
                        let jj = (oj * spec.stride + kj) as isize - spec.pad as isize;
                        if jj < 0 || jj >= w as isize {
                            continue;
                        }
                        out[(ci * h + ii as usize) * w + jj as usize] += crow[oi * ow + oj];
                    }
                }
            }
        }
    }
    out
}

/// Forward conv: x `(N,C,H,W)`, weight `(O,I/g,kh,kw)` → `(N,O,OH,OW)`.
pub fn conv2d(x: &Tensor, weight: &Tensor, bias: Option<&Tensor>, spec: &Conv2dSpec) -> Tensor {
    let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    assert_eq!(c, spec.in_ch, "conv2d in_ch");
    assert_eq!(weight.dims()[0], spec.out_ch);
    let (oh, ow) = spec.out_hw(h, w);
    let g = spec.groups;
    let icg = spec.in_ch / g;
    let ocg = spec.out_ch / g;
    let mut out = Tensor::zeros(&[n, spec.out_ch, oh, ow]);
    let chw = c * h * w;
    let kelem = icg * spec.kh * spec.kw;
    // weight viewed per group as (ocg, kelem)
    for ni in 0..n {
        let img = &x.data()[ni * chw..(ni + 1) * chw];
        for gi in 0..g {
            let gspec = Conv2dSpec { in_ch: icg, out_ch: ocg, groups: 1, ..*spec };
            let cols = im2col(&img[gi * icg * h * w..(gi + 1) * icg * h * w], icg, h, w, &gspec);
            let wg = Tensor::from_vec(
                &[ocg, kelem],
                weight.data()[gi * ocg * kelem..(gi + 1) * ocg * kelem].to_vec(),
            );
            let y = matmul(&wg, &cols); // (ocg, oh*ow)
            let base = (ni * spec.out_ch + gi * ocg) * oh * ow;
            out.data_mut()[base..base + ocg * oh * ow].copy_from_slice(y.data());
        }
    }
    if let Some(b) = bias {
        assert_eq!(b.numel(), spec.out_ch);
        let od = out.data_mut();
        for ni in 0..n {
            for oc in 0..spec.out_ch {
                let bval = b.data()[oc];
                let base = (ni * spec.out_ch + oc) * oh * ow;
                for v in &mut od[base..base + oh * ow] {
                    *v += bval;
                }
            }
        }
    }
    out
}

/// Gradient w.r.t. the weight: `dW = dY ⋆ X` (per group, via im2col GEMM).
pub fn conv2d_grad_weight(x: &Tensor, dy: &Tensor, spec: &Conv2dSpec) -> Tensor {
    let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let (oh, ow) = spec.out_hw(h, w);
    let g = spec.groups;
    let icg = c / g;
    let ocg = spec.out_ch / g;
    let kelem = icg * spec.kh * spec.kw;
    let mut dw = Tensor::zeros(&[spec.out_ch, icg, spec.kh, spec.kw]);
    let chw = c * h * w;
    for ni in 0..n {
        let img = &x.data()[ni * chw..(ni + 1) * chw];
        for gi in 0..g {
            let gspec = Conv2dSpec { in_ch: icg, out_ch: ocg, groups: 1, ..*spec };
            let cols = im2col(&img[gi * icg * h * w..(gi + 1) * icg * h * w], icg, h, w, &gspec);
            // dY slice (ocg, oh*ow)
            let base = (ni * spec.out_ch + gi * ocg) * oh * ow;
            let dyg = Tensor::from_vec(&[ocg, oh * ow], dy.data()[base..base + ocg * oh * ow].to_vec());
            // dW_g (ocg, kelem) = dY_g × colsᵀ
            let grad = super::matmul_a_bt(&dyg, &cols);
            let wbase = gi * ocg * kelem;
            for (dst, src) in dw.data_mut()[wbase..wbase + ocg * kelem].iter_mut().zip(grad.data()) {
                *dst += *src;
            }
        }
    }
    dw
}

/// Gradient w.r.t. the input: `dX = Wᵀ × dY` folded back with col2im.
pub fn conv2d_grad_input(weight: &Tensor, dy: &Tensor, x_dims: &[usize], spec: &Conv2dSpec) -> Tensor {
    let (n, c, h, w) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
    let (oh, ow) = spec.out_hw(h, w);
    let g = spec.groups;
    let icg = c / g;
    let ocg = spec.out_ch / g;
    let kelem = icg * spec.kh * spec.kw;
    let mut dx = Tensor::zeros(x_dims);
    let chw = c * h * w;
    for ni in 0..n {
        for gi in 0..g {
            let gspec = Conv2dSpec { in_ch: icg, out_ch: ocg, groups: 1, ..*spec };
            let base = (ni * spec.out_ch + gi * ocg) * oh * ow;
            let dyg = Tensor::from_vec(&[ocg, oh * ow], dy.data()[base..base + ocg * oh * ow].to_vec());
            let wg = Tensor::from_vec(
                &[ocg, kelem],
                weight.data()[gi * ocg * kelem..(gi + 1) * ocg * kelem].to_vec(),
            );
            // cols grad (kelem, oh*ow) = W_gᵀ × dY_g
            let dcols = super::matmul_at_b(&wg, &dyg);
            let img = col2im(&dcols, icg, h, w, &gspec);
            let dst = &mut dx.data_mut()[ni * chw + gi * icg * h * w..ni * chw + (gi + 1) * icg * h * w];
            for (d, s) in dst.iter_mut().zip(&img) {
                *d += *s;
            }
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn naive_conv(x: &Tensor, w: &Tensor, spec: &Conv2dSpec) -> Tensor {
        assert_eq!(spec.groups, 1);
        let (n, c, h, ww) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
        let (oh, ow) = spec.out_hw(h, ww);
        let mut out = Tensor::zeros(&[n, spec.out_ch, oh, ow]);
        for ni in 0..n {
            for oc in 0..spec.out_ch {
                for oi in 0..oh {
                    for oj in 0..ow {
                        let mut s = 0.0;
                        for ci in 0..c {
                            for ki in 0..spec.kh {
                                for kj in 0..spec.kw {
                                    let ii = (oi * spec.stride + ki) as isize - spec.pad as isize;
                                    let jj = (oj * spec.stride + kj) as isize - spec.pad as isize;
                                    if ii < 0 || jj < 0 || ii >= h as isize || jj >= ww as isize {
                                        continue;
                                    }
                                    s += x.at(&[ni, ci, ii as usize, jj as usize])
                                        * w.at(&[oc, ci, ki, kj]);
                                }
                            }
                        }
                        out.set(&[ni, oc, oi, oj], s);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_naive() {
        let mut rng = Rng::seed(21);
        for &(stride, pad) in &[(1usize, 0usize), (1, 1), (2, 1)] {
            let spec = Conv2dSpec::new(3, 4, 3, stride, pad);
            let x = Tensor::rand(&[2, 3, 8, 8], -1.0, 1.0, &mut rng);
            let w = Tensor::rand(&[4, 3, 3, 3], -1.0, 1.0, &mut rng);
            let got = conv2d(&x, &w, None, &spec);
            let want = naive_conv(&x, &w, &spec);
            assert_eq!(got.dims(), want.dims());
            for (a, b) in got.data().iter().zip(want.data()) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b} (stride {stride} pad {pad})");
            }
        }
    }

    #[test]
    fn depthwise_shapes_and_independence() {
        let mut rng = Rng::seed(22);
        let spec = Conv2dSpec::depthwise(4, 3, 1, 1);
        let x = Tensor::rand(&[1, 4, 6, 6], -1.0, 1.0, &mut rng);
        let w = Tensor::rand(&[4, 1, 3, 3], -1.0, 1.0, &mut rng);
        let y = conv2d(&x, &w, None, &spec);
        assert_eq!(y.dims(), &[1, 4, 6, 6]);
        // channel 0 output must not depend on channel 1 input
        let mut x2 = x.clone();
        for i in 0..36 {
            x2.data_mut()[36 + i] += 5.0; // perturb channel 1
        }
        let y2 = conv2d(&x2, &w, None, &spec);
        assert_eq!(&y.data()[0..36], &y2.data()[0..36]);
        assert_ne!(&y.data()[36..72], &y2.data()[36..72]);
    }

    #[test]
    fn bias_adds_per_channel() {
        let spec = Conv2dSpec::new(1, 2, 1, 1, 0);
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::from_vec(&[2, 1, 1, 1], vec![1.0, 0.0]);
        let b = Tensor::vec1(&[10.0, 20.0]);
        let y = conv2d(&x, &w, Some(&b), &spec);
        assert_eq!(y.data(), &[11., 12., 13., 14., 20., 20., 20., 20.]);
    }

    /// Finite-difference check of both conv gradients.
    #[test]
    fn conv_grads_match_fd() {
        let mut rng = Rng::seed(23);
        let spec = Conv2dSpec::new(2, 3, 3, 1, 1);
        let x = Tensor::rand(&[1, 2, 5, 5], -1.0, 1.0, &mut rng);
        let w = Tensor::rand(&[3, 2, 3, 3], -0.5, 0.5, &mut rng);
        // loss = sum(conv(x, w))
        let dy = Tensor::full(&[1, 3, 5, 5], 1.0);
        let dw = conv2d_grad_weight(&x, &dy, &spec);
        let dx = conv2d_grad_input(&w, &dy, x.dims(), &spec);
        let eps = 1e-2f32;
        let f = |x: &Tensor, w: &Tensor| conv2d(x, w, None, &spec).data().iter().sum::<f32>();
        for &idx in &[0usize, 7, 23, 53] {
            let mut wp = w.clone();
            wp.data_mut()[idx] += eps;
            let mut wm = w.clone();
            wm.data_mut()[idx] -= eps;
            let fd = (f(&x, &wp) - f(&x, &wm)) / (2.0 * eps);
            assert!((fd - dw.data()[idx]).abs() < 2e-2, "dw[{idx}] fd {fd} vs {}", dw.data()[idx]);
        }
        for &idx in &[0usize, 11, 31] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let fd = (f(&xp, &w) - f(&xm, &w)) / (2.0 * eps);
            assert!((fd - dx.data()[idx]).abs() < 2e-2, "dx[{idx}] fd {fd} vs {}", dx.data()[idx]);
        }
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> — the defining adjoint property
        let mut rng = Rng::seed(29);
        let spec = Conv2dSpec::new(2, 1, 3, 2, 1);
        let x = Tensor::rand(&[2, 7, 6], -1.0, 1.0, &mut rng);
        let cols = im2col(x.data(), 2, 7, 6, &spec);
        let y = Tensor::rand(cols.dims(), -1.0, 1.0, &mut rng);
        let lhs: f32 = cols.data().iter().zip(y.data()).map(|(a, b)| a * b).sum();
        let back = col2im(&y, 2, 7, 6, &spec);
        let rhs: f32 = x.data().iter().zip(&back).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }
}
