//! Deterministic RNG (xoshiro256++), implemented locally because no `rand`
//! crate is available offline. Every experiment seeds explicitly so tables
//! are reproducible run-to-run.

/// xoshiro256++ PRNG with a splitmix64 seeder.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> exactly representable uniform grid
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f32();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Laplace(0, b) sample — the activation model the paper's clip uses.
    pub fn laplace(&mut self, b: f32) -> f32 {
        let u = self.f32() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a derived, independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::seed(1);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Rng::seed(5);
        let n = 50_000;
        let b = 2.0f32;
        let xs: Vec<f32> = (0..n).map(|_| r.laplace(b)).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        // Var[Laplace(b)] = 2 b^2 = 8
        assert!((var - 8.0).abs() < 0.5, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::seed(11);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
