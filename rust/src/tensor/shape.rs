//! Shape and row-major stride arithmetic.

/// Immutable shape of a dense row-major tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    dims: Vec<usize>,
    strides: Vec<usize>,
}

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        let mut strides = vec![1usize; dims.len()];
        for i in (0..dims.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * dims[i + 1];
        }
        Shape { dims: dims.to_vec(), strides }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Row-major linear offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.dims.len(), "index rank mismatch");
        let mut o = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.dims).enumerate() {
            assert!(x < d, "index {x} out of bounds for dim {i} (size {d})");
            o += x * self.strides[i];
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.strides(), &[12, 4, 1]);
        assert_eq!(s.numel(), 24);
    }

    #[test]
    fn offset_matches_manual() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.offset(&[1, 2, 3]), 12 + 8 + 3);
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::new(&[]);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.offset(&[]), 0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn offset_oob_panics() {
        Shape::new(&[2, 2]).offset(&[2, 0]);
    }
}
