//! # FP=xINT — low-bit series expansion for post-training quantization
//!
//! Reproduction of "FP=xINT: A Low-Bit Series Expansion Algorithm for
//! Post-Training Quantization" (AAAI 2026) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **Layer 1** (build-time Python): Pallas kernels for residual series
//!   decomposition and the stacked xINT GEMM (`python/compile/kernels/`).
//! * **Layer 2** (build-time Python): JAX model graphs lowered AOT to HLO
//!   text (`python/compile/model.py`, `aot.py` → `artifacts/`).
//! * **Layer 3** (this crate): the serving coordinator — request routing,
//!   dynamic batching, basis-model scheduling, AbelianAdd AllReduce — plus
//!   the [`qos`] control plane, which rides the series structure itself:
//!   per-request [`qos::Tier`]s map to basis-term budgets (calibrated from
//!   §5.3 convergence data), the scheduler reduces only the prefix of the
//!   worker pool a tier needs (⊎ prefix sums are group elements), and
//!   under queue pressure the [`qos::TermController`] trades precision for
//!   availability instead of shedding. Every substrate the paper depends
//!   on is implemented from scratch: tensors, NN inference + training,
//!   quantizers, PTQ baselines, synthetic datasets, a PJRT runtime
//!   wrapper, and benchmark harnesses that regenerate every table and
//!   figure of the paper (see DESIGN.md §5).

// The crate is safe Rust with TWO sanctioned islands (module-scoped
// `allow`s): the AVX2 intrinsics in `xint::kernel::micro` (safe
// wrappers re-check CPU features, bit-identity pinned by property
// tests against the scalar kernel) and the four epoll syscall wrappers
// in `serve::reactor::sys` (no pointer lifetime subtleties — the
// kernel copies every struct during the call). Everything else stays
// safe; concurrency correctness is carried by types + the loom models
// (CONCURRENCY.md), not by unsafe cleverness — keep it that way.
#![deny(unsafe_code)]

pub mod analyze;
pub mod baselines;
pub mod bench_support;
pub mod coordinator;
pub mod datasets;
pub mod models;
pub mod obs;
pub mod qos;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
pub mod xint;

/// Crate version reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
