//! ASCII table renderer — every bench prints paper-style tables with it.

/// Column-aligned ASCII table with a title and header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = |w: &Vec<usize>| {
            let mut s = String::from("+");
            for wi in w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep(&widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float as a fixed-precision cell ("-" for NaN = not applicable).
pub fn cell(v: f64, prec: usize) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.prec$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["method", "acc"]);
        t.row_str(&["ours", "77.03"]);
        t.row_str(&["rtn", "0.1"]);
        let r = t.render();
        assert!(r.contains("| method | acc   |"));
        assert!(r.contains("| ours   | 77.03 |"));
        // all lines same width
        let widths: Vec<usize> = r.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{r}");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new("", &["a", "b"]).row_str(&["x"]);
    }

    #[test]
    fn nan_cell_dash() {
        assert_eq!(cell(f64::NAN, 2), "-");
        assert_eq!(cell(1.23456, 2), "1.23");
    }
}
