//! Micro property-testing harness (proptest is unavailable offline).
//!
//! [`forall`] draws `cases` random inputs from a generator and asserts the
//! property; on failure it greedily shrinks through caller-provided
//! candidates and reports the minimal counterexample. The xint invariants
//! in DESIGN.md §7 are tested through this.

use crate::tensor::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xF00D, max_shrink: 200 }
    }
}

/// Run `prop` on `cases` values drawn by `gen`; shrink failures via `shrink`.
///
/// `prop` returns `Err(msg)` to signal failure (so assertions carry context).
pub fn forall<T: Clone + std::fmt::Debug>(
    cfg: PropConfig,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::seed(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // shrink: repeatedly take the first failing candidate
            let mut cur = input.clone();
            let mut msg = first_msg;
            let mut budget = cfg.max_shrink;
            'outer: while budget > 0 {
                for cand in shrink(&cur) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x})\n  minimal input: {cur:?}\n  error: {msg}",
                cfg.seed
            );
        }
    }
}

/// Shrinker for `Vec<f32>`: halve length, zero elements, halve magnitudes.
pub fn shrink_vec_f32(v: &Vec<f32>) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    if v.iter().any(|&x| x != 0.0) {
        out.push(v.iter().map(|&x| x / 2.0).collect());
        for i in 0..v.len().min(8) {
            if v[i] != 0.0 {
                let mut w = v.clone();
                w[i] = 0.0;
                out.push(w);
            }
        }
    }
    out
}

/// No-op shrinker for types without a useful notion of "smaller".
pub fn no_shrink<T>(_: &T) -> Vec<T> {
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        use std::cell::Cell;
        let n = Cell::new(0usize);
        forall(
            PropConfig { cases: 10, ..Default::default() },
            |r| r.uniform(-1.0, 1.0),
            no_shrink,
            |_| {
                n.set(n.get() + 1);
                Ok(())
            },
        );
        assert_eq!(n.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        forall(
            PropConfig::default(),
            |r| r.uniform(0.0, 10.0),
            no_shrink,
            |&x| if x < 20.0 { Err(format!("x={x}")) } else { Ok(()) },
        );
    }

    #[test]
    fn shrinker_finds_small_counterexample() {
        // property: no element > 1.0 — the shrinker should isolate a small vec
        let result = std::panic::catch_unwind(|| {
            forall(
                PropConfig { cases: 20, seed: 3, max_shrink: 500 },
                |r| (0..16).map(|_| r.uniform(0.0, 2.0)).collect::<Vec<f32>>(),
                shrink_vec_f32,
                |v| {
                    if v.iter().all(|&x| x <= 1.0) {
                        Ok(())
                    } else {
                        Err("element > 1".into())
                    }
                },
            )
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("expected failure"),
        };
        // the minimal input should be much smaller than 16 elements
        let shown = msg.split("minimal input: ").nth(1).unwrap();
        let count = shown.split(',').count();
        assert!(count <= 8, "shrunk to {count} elems: {msg}");
    }

    #[test]
    fn shrink_vec_reduces() {
        let v = vec![1.0f32, 2.0, 3.0, 4.0];
        let cands = shrink_vec_f32(&v);
        assert!(cands.iter().any(|c| c.len() < v.len()));
        assert!(cands.iter().any(|c| c.len() == v.len() && c.iter().sum::<f32>() < 10.0));
    }
}
