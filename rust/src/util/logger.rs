//! Tiny `log`-facade backend: leveled, timestamped stderr logging.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();

struct StderrLogger {
    level: LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; `verbose` raises the filter to Debug.
/// Safe to call repeatedly (subsequent calls are no-ops).
pub fn init(verbose: bool) {
    let _ = START.get_or_init(Instant::now); // anchor t=0 at first init
    let level = if verbose { LevelFilter::Debug } else { LevelFilter::Info };
    let logger = Box::leak(Box::new(StderrLogger { level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_idempotent() {
        super::init(false);
        super::init(true); // must not panic
        log::info!("logger test line");
    }
}
