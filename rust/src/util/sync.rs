//! Synchronization shim: the one place this crate imports atomics and
//! threads from.
//!
//! Normal builds re-export `std::sync` / `std::thread` unchanged — zero
//! cost. Under `RUSTFLAGS="--cfg loom"` the same names resolve to the
//! vendored loom model checker's versions (`rust/vendor/loom`), so the
//! `loom_model_*` tests can explore thread interleavings and weak-memory
//! behaviors of the real production types. Everything concurrent in this
//! crate goes through here; `scripts/check_invariants.py` enforces that
//! no other non-test module imports `std::sync::atomic` or `std::thread`
//! directly (rule `sync-shim`), because a single unshimmed atomic would
//! silently escape every loom model.
//!
//! `Arc`, `mpsc`, and `OnceLock` are plain `std` types under both cfgs
//! (the vendored checker serializes real OS threads, so `std`'s versions
//! are already correct inside models); `Mutex`, `Condvar`, `atomic::*`,
//! and `thread` are the model-aware ones. Loom models must not call
//! blocking APIs the scheduler cannot see (`mpsc::Receiver::recv`,
//! `JoinHandle::join` is fine — the shim's version is scheduler-aware);
//! see `CONCURRENCY.md` for how to write and run models.

#[cfg(not(loom))]
pub use std::sync::{atomic, mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock};

#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::sync::{atomic, mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock};

#[cfg(loom)]
pub use loom::thread;
