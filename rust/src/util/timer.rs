//! Micro-benchmark timer (criterion is unavailable offline).
//!
//! Warmup + timed iterations with black-box result sinking; reports a
//! [`crate::util::stats::Summary`] of per-iteration wall times.

use super::stats::Summary;
use std::hint::black_box;
use std::time::Instant;

/// Bench runner with warmup and fixed iteration count.
pub struct BenchTimer {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchTimer {
    fn default() -> Self {
        BenchTimer { warmup: 3, iters: 10 }
    }
}

impl BenchTimer {
    pub fn new(warmup: usize, iters: usize) -> Self {
        BenchTimer { warmup, iters }
    }

    /// Time `f`, returning per-iteration seconds.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Summary {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        Summary::of(&times)
    }

    /// Time `f` and derive items/second from `items` per call.
    pub fn throughput<T>(&self, items: usize, f: impl FnMut() -> T) -> (Summary, f64) {
        let s = self.run(f);
        let thpt = if s.mean > 0.0 { items as f64 / s.mean } else { 0.0 };
        (s, thpt)
    }
}

/// One-shot wall-clock measurement.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_counts_iters() {
        let mut calls = 0usize;
        let t = BenchTimer::new(2, 5);
        let s = t.run(|| {
            calls += 1;
            calls
        });
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn throughput_positive() {
        let t = BenchTimer::new(0, 3);
        let (_, thpt) = t.throughput(100, || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(thpt > 0.0);
        assert!(thpt < 100.0 / 40e-6);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
