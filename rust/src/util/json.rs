//! Minimal JSON parser + writer (serde is unavailable offline) — enough
//! for the AOT `manifest.json`, the serve protocol, and the
//! `BENCH_*.json` perf-trajectory files: objects, arrays, strings,
//! numbers, bools, null; no exotic escapes beyond \" \\ \/ \n \t \r \u.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_num().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Number value (NaN/∞ have no JSON spelling; they serialize as null).
    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    /// Serialize to compact JSON text. `parse(render(j)) == j` for all
    /// finite values.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(text.as_bytes()) {
            self.i += text.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u hex")?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "din": 256, "bits": 4, "batches": [1, 8, 32],
            "artifacts": {"fp_mlp_b1": "fp_mlp_b1.hlo.txt"},
            "nested": {"x": true, "y": null, "z": -1.5e2}
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("din").unwrap().as_usize(), Some(256));
        assert_eq!(j.get("batches").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("artifacts").unwrap().get("fp_mlp_b1").unwrap().as_str(),
            Some("fp_mlp_b1.hlo.txt")
        );
        assert_eq!(j.get("nested").unwrap().get("z").unwrap().as_num(), Some(-150.0));
        assert_eq!(j.get("nested").unwrap().get("y"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\nd\u{41}"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn render_roundtrips() {
        let j = Json::obj([
            ("name", Json::str("qos \"bench\"\n")),
            ("p99_ms", Json::num(1.25)),
            ("n", Json::num(400.0)),
            ("tiers", Json::Arr(vec![Json::str("exact"), Json::str("best-effort")])),
            ("flag", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = j.render();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // integers render without a trailing fraction
        assert!(text.contains("\"n\":400"), "{text}");
    }

    #[test]
    fn render_nonfinite_as_null() {
        assert_eq!(Json::num(f64::NAN).render(), "null");
        assert_eq!(Json::num(f64::INFINITY).render(), "null");
    }
}
