//! Descriptive statistics for benchmark reporting.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, `p ∈ [0, 100]`.
///
/// Total-order sort (`f64::total_cmp`): a NaN sample sorts above +∞
/// instead of panicking the sort comparator — one NaN latency in a
/// metrics reservoir must degrade that quantile, not crash a snapshot
/// mid-serve.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Five-number-ish summary used by the serve/bench reports.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: if xs.is_empty() { 0.0 } else { min },
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            max: if xs.is_empty() { 0.0 } else { max },
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} std={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            self.n, self.mean, self.std, self.p50, self.p95, self.p99, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p99 - 99.01).abs() < 0.02);
    }

    #[test]
    fn nan_inputs_never_panic_the_percentile() {
        // regression: sort_by(partial_cmp().unwrap()) panicked on the
        // first NaN latency, taking the whole metrics snapshot with it
        let xs = [1.0, f64::NAN, 2.0];
        let p50 = percentile(&xs, 50.0);
        assert_eq!(p50, 2.0, "NaN sorts above +inf; the finite median is s[1]");
        assert!(percentile(&xs, 0.0).is_finite());
        // a quantile that lands ON the NaN reports NaN rather than lying
        assert!(percentile(&xs, 100.0).is_nan());
        // all-NaN input: still no panic
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
        // Summary over a reservoir containing a NaN stays usable
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.p50.is_finite());
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.min, 0.0);
    }
}
