//! Descriptive statistics for benchmark reporting.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, `p ∈ [0, 100]`.
///
/// Total-order sort (`f64::total_cmp`): a NaN sample sorts above +∞
/// instead of panicking the sort comparator — one NaN latency in a
/// metrics reservoir must degrade that quantile, not crash a snapshot
/// mid-serve.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Five-number-ish summary used by the serve/bench reports.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: if xs.is_empty() { 0.0 } else { min },
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            max: if xs.is_empty() { 0.0 } else { max },
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} std={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            self.n, self.mean, self.std, self.p50, self.p95, self.p99, self.max
        )
    }
}

/// Fixed-bucket histogram for metrics exposition.
///
/// Where [`Summary`] keeps a raw reservoir (exact quantiles, unbounded
/// precision, but unscrapeable), a `Histogram` is the export-friendly
/// form: a fixed ascending ladder of bucket upper bounds plus an
/// implicit `+Inf` overflow bucket, mergeable across shards and
/// renderable as Prometheus `_bucket`/`_sum`/`_count` series. Quantiles
/// are estimates (linear interpolation inside the covering bucket), so
/// accuracy is set by the bucket ladder, not the sample count.
///
/// NaN observations follow the PR 5 `total_cmp` convention — a NaN
/// latency must degrade the metric, never poison it: NaN lands in the
/// overflow bucket and is counted, but is excluded from `sum` so the
/// mean of the finite mass stays finite.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// ascending, finite bucket upper bounds (`le` values)
    bounds: Vec<f64>,
    /// per-bucket counts; `counts[bounds.len()]` is the `+Inf` bucket
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// A histogram over the given ascending finite upper bounds.
    pub fn new(bounds: Vec<f64>) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite and strictly ascending"
        );
        let counts = vec![0; bounds.len() + 1];
        Histogram { bounds, counts, sum: 0.0, count: 0 }
    }

    /// The default request-latency ladder (seconds): log-ish 1/2.5/5
    /// steps from 100 µs to 30 s, matching the tier SLO range
    /// (25 ms / 100 ms / 500 ms targets all land mid-ladder).
    pub fn latency_seconds() -> Histogram {
        Histogram::new(vec![
            0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
            1.0, 2.5, 5.0, 10.0, 30.0,
        ])
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        if v.is_nan() {
            // counted (the observation happened) but excluded from the
            // sum and binned as overflow — degrade, don't poison
            let last = self.counts.len() - 1;
            self.counts[last] += 1;
            return;
        }
        self.sum += v;
        let idx = self.bounds.partition_point(|b| v > *b);
        self.counts[idx] += 1;
    }

    /// Fold another histogram (same bounds) into this one.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "can only merge histograms with equal bounds");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Non-cumulative per-bucket counts (last entry = `+Inf` bucket).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total observations (including NaN).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of the finite observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Estimated quantile, `q ∈ [0, 1]`: linear interpolation inside
    /// the bucket covering rank `q·count` (Prometheus
    /// `histogram_quantile` semantics). Mass in the `+Inf` bucket
    /// reports the largest finite bound — an explicit floor, not a
    /// fabricated value. 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cumulative = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let before = cumulative as f64;
            cumulative += c;
            if (cumulative as f64) < target || c == 0 {
                continue;
            }
            if i == self.bounds.len() {
                return self.bounds[self.bounds.len() - 1];
            }
            let lower = if i == 0 { 0.0 } else { self.bounds[i - 1] };
            let frac = ((target - before) / c as f64).clamp(0.0, 1.0);
            return lower + frac * (self.bounds[i] - lower);
        }
        self.bounds[self.bounds.len() - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p99 - 99.01).abs() < 0.02);
    }

    #[test]
    fn nan_inputs_never_panic_the_percentile() {
        // regression: sort_by(partial_cmp().unwrap()) panicked on the
        // first NaN latency, taking the whole metrics snapshot with it
        let xs = [1.0, f64::NAN, 2.0];
        let p50 = percentile(&xs, 50.0);
        assert_eq!(p50, 2.0, "NaN sorts above +inf; the finite median is s[1]");
        assert!(percentile(&xs, 0.0).is_finite());
        // a quantile that lands ON the NaN reports NaN rather than lying
        assert!(percentile(&xs, 100.0).is_nan());
        // all-NaN input: still no panic
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
        // Summary over a reservoir containing a NaN stays usable
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.p50.is_finite());
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.min, 0.0);
    }

    #[test]
    fn histogram_bucket_boundaries_are_le_inclusive() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        for v in [0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 9.0] {
            h.observe(v);
        }
        // a value ON a bound lands in that bound's bucket (le semantics)
        assert_eq!(h.bucket_counts(), &[2, 2, 2, 1]);
        assert_eq!(h.count(), 7);
        assert!((h.sum() - 21.0).abs() < 1e-12);
        assert_eq!(h.bounds(), &[1.0, 2.0, 4.0]);
    }

    #[test]
    fn histogram_merge_adds_mass() {
        let mut a = Histogram::new(vec![1.0, 10.0]);
        let mut b = Histogram::new(vec![1.0, 10.0]);
        a.observe(0.5);
        a.observe(5.0);
        b.observe(5.0);
        b.observe(50.0);
        a.merge(&b);
        assert_eq!(a.bucket_counts(), &[1, 2, 1]);
        assert_eq!(a.count(), 4);
        assert!((a.sum() - 60.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "equal bounds")]
    fn histogram_merge_rejects_mismatched_bounds() {
        let mut a = Histogram::new(vec![1.0]);
        a.merge(&Histogram::new(vec![2.0]));
    }

    #[test]
    fn histogram_quantiles_track_exact_percentiles() {
        // fine linear ladder over [0, 1): estimate error is bounded by
        // one bucket width, so compare against the exact reservoir
        // percentile within a few bucket widths
        let bounds: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        let mut h = Histogram::new(bounds);
        let mut rng_state = 0x243F_6A88_85A3_08D3u64; // deterministic LCG
        let mut xs = Vec::new();
        for _ in 0..5000 {
            rng_state = rng_state.wrapping_mul(6364136223846793005);
            rng_state = rng_state.wrapping_add(1442695040888963407);
            let v = (rng_state >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
            h.observe(v);
            xs.push(v);
        }
        for p in [10.0, 50.0, 90.0, 99.0] {
            let exact = percentile(&xs, p);
            let est = h.quantile(p / 100.0);
            assert!((est - exact).abs() < 0.03, "p{p}: histogram {est} vs exact {exact}");
        }
    }

    #[test]
    fn histogram_nan_degrades_without_poisoning() {
        // same contract as the percentile total_cmp fix: one NaN
        // latency must not corrupt the whole export
        let mut h = Histogram::latency_seconds();
        h.observe(0.01);
        h.observe(f64::NAN);
        h.observe(0.02);
        assert_eq!(h.count(), 3);
        assert!(h.sum().is_finite());
        assert!((h.sum() - 0.03).abs() < 1e-12);
        // NaN is visible as overflow mass, not silently dropped
        assert_eq!(*h.bucket_counts().last().unwrap(), 1);
        assert!(h.quantile(0.5).is_finite());
    }

    #[test]
    fn histogram_quantile_edges() {
        let mut h = Histogram::new(vec![1.0, 2.0]);
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        h.observe(0.5);
        assert!(h.quantile(0.0) >= 0.0 && h.quantile(0.0) <= 1.0);
        assert!(h.quantile(1.0) <= 1.0, "single in-range sample stays in its bucket");
        // overflow mass floors at the largest finite bound
        let mut o = Histogram::new(vec![1.0, 2.0]);
        o.observe(100.0);
        assert_eq!(o.quantile(0.99), 2.0);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn histogram_rejects_unsorted_bounds() {
        let _ = Histogram::new(vec![2.0, 1.0]);
    }
}
