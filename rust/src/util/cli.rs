//! Minimal GNU-style CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! typed accessors with defaults. Unknown-flag detection is the caller's
//! choice via [`Args::finish`].

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator (tests).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// String option with default.
    pub fn get(&mut self, key: &str, default: &str) -> String {
        self.consumed.push(key.to_string());
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&mut self, key: &str) -> Option<String> {
        self.consumed.push(key.to_string());
        self.opts.get(key).cloned()
    }

    /// Typed numeric option with default; panics with a clear message on
    /// unparseable input (surface config errors early).
    pub fn get_num<T: std::str::FromStr>(&mut self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        self.consumed.push(key.to_string());
        match self.opts.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key}={v}: not a valid number: {e:?}")),
            None => default,
        }
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.push(key.to_string());
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.opts.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Positional argument by index.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// Subcommand = first positional.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional(0)
    }

    /// Error out on unrecognized options (call after all accessors).
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown options: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_both_syntaxes() {
        let mut a = args("--bits 4 --terms=3");
        assert_eq!(a.get_num::<u32>("bits", 0), 4);
        assert_eq!(a.get_num::<u32>("terms", 0), 3);
    }

    #[test]
    fn flags_and_defaults() {
        let mut a = args("serve --verbose");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("model", "mlp"), "mlp");
        assert_eq!(a.subcommand(), Some("serve"));
    }

    #[test]
    fn finish_flags_unknown() {
        let mut a = args("--known 1 --unknown 2");
        let _ = a.get_num::<u32>("known", 0);
        assert!(a.finish().is_err());
        let _ = a.get_num::<u32>("unknown", 0);
        assert!(a.finish().is_ok());
    }

    #[test]
    #[should_panic(expected = "not a valid number")]
    fn bad_number_panics() {
        let mut a = args("--bits four");
        let _: u32 = a.get_num("bits", 0);
    }

    #[test]
    fn negative_numbers_as_values() {
        let mut a = args("--clip=-2.5");
        assert_eq!(a.get_num::<f32>("clip", 0.0), -2.5);
    }
}
