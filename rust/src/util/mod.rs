//! Cross-cutting substrates built from scratch (no clap/serde/criterion
//! offline): CLI parsing, config files, logging, statistics, ASCII table
//! rendering, a micro property-testing harness, a bench timer, and the
//! `sync` shim every concurrent module must use (see `CONCURRENCY.md`).

pub mod cli;
pub mod config;
pub mod json;
pub mod logger;
pub mod prop;
pub mod stats;
pub mod sync;
pub mod table;
pub mod timer;

pub use cli::Args;
pub use config::Config;
pub use stats::{mean, percentile, stddev, Histogram, Summary};
pub use table::Table;
pub use timer::BenchTimer;
