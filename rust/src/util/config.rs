//! Flat key/value config-file substrate (TOML subset; serde unavailable).
//!
//! Format: `key = value` lines, `[section]` headers flattening to
//! `section.key`, `#` comments, quoted strings, bools, ints, floats and
//! comma lists. Enough to drive experiment configs reproducibly.

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config file.
#[derive(Debug, Default, Clone)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn empty() -> Self {
        Config::default()
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {}: unterminated section header", lineno + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, unquote(v.trim()));
        }
        Ok(Config { map })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("read {:?}: {e}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.map.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn boolean(&self, key: &str, default: bool) -> bool {
        match self.map.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    /// Comma-separated list of numbers.
    pub fn num_list<T: std::str::FromStr>(&self, key: &str) -> Vec<T> {
        self.map
            .get(key)
            .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
            .unwrap_or_default()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quotes
    let mut in_quote = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(
            r#"
            name = "xint"   # quoted string with comment
            [quant]
            bits = 4
            act_terms = 4
            clip = 1.5
            saturate = true
            layers = 1, 2, 3
            "#,
        )
        .unwrap();
        assert_eq!(c.str("name", ""), "xint");
        assert_eq!(c.num::<u32>("quant.bits", 0), 4);
        assert_eq!(c.num::<f32>("quant.clip", 0.0), 1.5);
        assert!(c.boolean("quant.saturate", false));
        assert_eq!(c.num_list::<u32>("quant.layers"), vec![1, 2, 3]);
    }

    #[test]
    fn missing_keys_fall_back() {
        let c = Config::parse("a = 1").unwrap();
        assert_eq!(c.num::<u32>("b", 7), 7);
        assert_eq!(c.str("c", "dflt"), "dflt");
    }

    #[test]
    fn bad_line_errors() {
        assert!(Config::parse("no equals here").is_err());
        assert!(Config::parse("[unterminated").is_err());
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let c = Config::parse(r#"tag = "a#b""#).unwrap();
        assert_eq!(c.str("tag", ""), "a#b");
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::parse("a = 1").unwrap();
        c.set("a", 2);
        assert_eq!(c.num::<u32>("a", 0), 2);
    }
}
