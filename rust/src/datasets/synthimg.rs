//! Procedural image classification dataset — the ImageNet stand-in.
//!
//! Each class is a parametric template: an oriented bar, disk, ring,
//! checker, gradient, cross, blob mixture or stripe field, composed with a
//! per-sample random affine jitter, amplitude jitter, texture noise and
//! additive Gaussian noise. Classes overlap enough that a linear model
//! cannot solve the task but a small CNN reaches >90% — which is exactly
//! the regime where low-bit quantization noise shows up as an accuracy
//! cliff (the phenomenon the paper's tables measure).

use super::Batch;
use crate::tensor::{Rng, Tensor};

/// Procedural image task generator.
#[derive(Clone, Debug)]
pub struct SynthImg {
    pub classes: usize,
    pub channels: usize,
    pub size: usize,
    pub noise: f32,
    seed: u64,
}

impl SynthImg {
    pub fn new(classes: usize, channels: usize, size: usize, noise: f32, seed: u64) -> Self {
        assert!(classes >= 2 && classes <= 16, "2..=16 classes supported");
        SynthImg { classes, channels, size, noise, seed }
    }

    /// The default benchmark task: 10 classes, 1 channel, 16×16.
    pub fn standard(seed: u64) -> Self {
        SynthImg::new(10, 1, 16, 0.25, seed)
    }

    /// Deterministic split: `which=0` train, `1` val, `2` test.
    pub fn batch(&self, n: usize, which: u64) -> Batch {
        let mut rng = Rng::seed(self.seed ^ (which.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
        let s = self.size;
        let mut x = Tensor::zeros(&[n, self.channels, s, s]);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let cls = rng.below(self.classes);
            y.push(cls);
            let img = self.render(cls, &mut rng);
            let base = i * self.channels * s * s;
            x.data_mut()[base..base + self.channels * s * s].copy_from_slice(&img);
        }
        Batch { x, y }
    }

    /// Render one sample of class `cls` into `channels × size × size`.
    fn render(&self, cls: usize, rng: &mut Rng) -> Vec<f32> {
        let s = self.size;
        let sf = s as f32;
        // per-sample geometric jitter
        let cx = sf / 2.0 + rng.uniform(-2.0, 2.0);
        let cy = sf / 2.0 + rng.uniform(-2.0, 2.0);
        let rot = rng.uniform(-0.5, 0.5);
        let amp = rng.uniform(0.7, 1.3);
        let phase = rng.uniform(0.0, std::f32::consts::TAU);
        let mut plane = vec![0.0f32; s * s];
        for py in 0..s {
            for px in 0..s {
                let dx = px as f32 - cx;
                let dy = py as f32 - cy;
                let rx = dx * rot.cos() - dy * rot.sin();
                let ry = dx * rot.sin() + dy * rot.cos();
                let r = (rx * rx + ry * ry).sqrt();
                let v = match cls % 8 {
                    // oriented bar
                    0 => (-(ry * ry) / 3.0).exp(),
                    // filled disk
                    1 => {
                        if r < sf / 4.0 {
                            1.0
                        } else {
                            0.0
                        }
                    }
                    // ring
                    2 => (-((r - sf / 4.0) * (r - sf / 4.0)) / 2.0).exp(),
                    // checkerboard
                    3 => {
                        if ((px / 2) + (py / 2)) % 2 == 0 {
                            1.0
                        } else {
                            -1.0
                        }
                    }
                    // diagonal gradient
                    4 => (rx + ry) / sf,
                    // cross
                    5 => (-(rx * rx) / 2.0).exp() + (-(ry * ry) / 2.0).exp(),
                    // two-blob mixture
                    6 => {
                        let d1 = (rx - sf / 5.0).powi(2) + ry * ry;
                        let d2 = (rx + sf / 5.0).powi(2) + ry * ry;
                        (-d1 / 6.0).exp() + (-d2 / 6.0).exp()
                    }
                    // sinusoidal stripes
                    _ => (rx * std::f32::consts::TAU / 5.0 + phase).sin(),
                };
                // classes ≥ 8 reuse templates at a finer spatial frequency
                let v = if cls >= 8 {
                    v * ((rx * 1.7).cos() * (ry * 1.7).cos())
                } else {
                    v
                };
                plane[py * s + px] = amp * v + self.noise * rng.normal();
            }
        }
        // replicate across channels with a per-channel gain so multi-channel
        // models see correlated but non-identical planes
        let mut out = Vec::with_capacity(self.channels * s * s);
        for c in 0..self.channels {
            let gain = 1.0 - 0.15 * c as f32;
            out.extend(plane.iter().map(|&v| v * gain));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_labels() {
        let ds = SynthImg::standard(1);
        let b = ds.batch(32, 0);
        assert_eq!(b.x.dims(), &[32, 1, 16, 16]);
        assert_eq!(b.y.len(), 32);
        assert!(b.y.iter().all(|&y| y < 10));
    }

    #[test]
    fn deterministic_per_seed_and_split() {
        let ds = SynthImg::standard(7);
        let a = ds.batch(8, 0);
        let b = ds.batch(8, 0);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = ds.batch(8, 1);
        assert_ne!(a.x, c.x, "different splits must differ");
    }

    #[test]
    fn all_classes_appear() {
        let ds = SynthImg::standard(3);
        let b = ds.batch(500, 0);
        let mut seen = vec![false; 10];
        for &y in &b.y {
            seen[y] = true;
        }
        assert!(seen.iter().all(|&s| s), "seen {seen:?}");
    }

    #[test]
    fn classes_are_distinguishable_by_template() {
        // noiseless class means should differ meaningfully between classes
        let ds = SynthImg::new(4, 1, 16, 0.0, 9);
        let b = ds.batch(400, 0);
        let s = 16 * 16;
        let mut means = vec![vec![0.0f32; s]; 4];
        let mut counts = vec![0usize; 4];
        for i in 0..b.len() {
            let cls = b.y[i];
            counts[cls] += 1;
            for j in 0..s {
                means[cls][j] += b.x.data()[i * s + j];
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        for a in 0..4 {
            for b2 in (a + 1)..4 {
                let d: f32 = means[a]
                    .iter()
                    .zip(&means[b2])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(d.sqrt() > 0.5, "classes {a},{b2} too close: {}", d.sqrt());
            }
        }
    }
}
