//! Character-level LM corpus + synthetic-MMLU evaluation — the LLM
//! stand-in for Table 6 (W4A16 weight-only expansion).
//!
//! The corpus is a generated "fact base": templated sentences over four
//! subjects (the paper reports Humanities/STEM/Social/Other). A causal LM
//! is trained on the corpus; the MMLU stand-in asks it to complete held-in
//! facts against 3 distractors, scored by sequence log-likelihood — the
//! same protocol MMLU uses for base models.

use crate::tensor::Rng;

/// Character vocabulary: lowercase letters, space, period = 28 symbols.
pub const CHAR_VOCAB: usize = 28;

pub fn encode_char(c: u8) -> usize {
    match c {
        b'a'..=b'z' => (c - b'a') as usize,
        b' ' => 26,
        _ => 27, // '.'
    }
}

pub fn decode_char(t: usize) -> char {
    match t {
        0..=25 => (b'a' + t as u8) as char,
        26 => ' ',
        _ => '.',
    }
}

/// The four MMLU-style subjects.
pub const SUBJECTS: [&str; 4] = ["hums", "stem", "social", "other"];

const ENTITIES: [&[&str]; 4] = [
    &["plato", "homer", "dante", "ovid", "sappho", "virgil"],
    &["quark", "proton", "vector", "tensor", "prime", "graph"],
    &["market", "treaty", "senate", "tribe", "guild", "census"],
    &["recipe", "harbor", "violin", "garden", "bridge", "lantern"],
];

const ATTRIBUTES: [&[&str]; 4] = [
    &["wrote epics", "taught logic", "sang odes", "shaped myth"],
    &["carries charge", "spans space", "divides evenly", "links nodes"],
    &["sets prices", "binds states", "passes laws", "keeps records"],
    &["feeds guests", "shelters ships", "makes music", "grows herbs"],
];

/// One multiple-choice question: a stem plus 4 candidate completions.
#[derive(Clone, Debug)]
pub struct McQuestion {
    pub subject: usize,
    pub stem: String,
    pub choices: [String; 4],
    pub answer: usize,
}

/// Char-LM training corpus + MMLU-style eval set.
#[derive(Clone, Debug)]
pub struct CharLmTask {
    /// (entity, attribute-idx) ground-truth pairing per subject
    truth: Vec<Vec<usize>>,
    seed: u64,
}

impl CharLmTask {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::seed(seed);
        // fix a random but consistent entity→attribute map per subject
        let truth = ENTITIES
            .iter()
            .enumerate()
            .map(|(s, ents)| (0..ents.len()).map(|_| rng.below(ATTRIBUTES[s].len())).collect())
            .collect();
        CharLmTask { truth, seed }
    }

    fn fact(&self, subject: usize, ent: usize) -> String {
        format!(
            "the {} {}.",
            ENTITIES[subject][ent],
            ATTRIBUTES[subject][self.truth[subject][ent]]
        )
    }

    /// Full training corpus: every fact repeated with connective noise.
    pub fn corpus(&self) -> String {
        let mut rng = Rng::seed(self.seed ^ 0xC0FFEE);
        let fillers = ["note that ", "recall ", "clearly ", "we know ", ""];
        let mut out = String::new();
        for _ in 0..40 {
            for s in 0..4 {
                for e in 0..ENTITIES[s].len() {
                    out.push_str(fillers[rng.below(fillers.len())]);
                    out.push_str(&self.fact(s, e));
                    out.push(' ');
                }
            }
        }
        out
    }

    /// Corpus as token ids.
    pub fn tokens(&self) -> Vec<usize> {
        self.corpus().bytes().map(encode_char).collect()
    }

    /// MMLU-style eval: for each (subject, entity), the true attribute vs
    /// 3 distractor attributes.
    pub fn questions(&self) -> Vec<McQuestion> {
        let mut rng = Rng::seed(self.seed ^ 0xE7A1_5EED);
        let mut qs = Vec::new();
        for s in 0..4 {
            for e in 0..ENTITIES[s].len() {
                let gold = self.truth[s][e];
                let natt = ATTRIBUTES[s].len();
                let mut distract: Vec<usize> = (0..natt).filter(|&a| a != gold).collect();
                rng.shuffle(&mut distract);
                let answer = rng.below(4);
                let mut choices: Vec<String> = Vec::with_capacity(4);
                let mut d = distract.into_iter();
                for slot in 0..4 {
                    let att = if slot == answer { gold } else { d.next().unwrap_or(gold) };
                    choices.push(format!("{}.", ATTRIBUTES[s][att]));
                }
                qs.push(McQuestion {
                    subject: s,
                    stem: format!("the {} ", ENTITIES[s][e]),
                    choices: [
                        choices[0].clone(),
                        choices[1].clone(),
                        choices[2].clone(),
                        choices[3].clone(),
                    ],
                    answer,
                });
            }
        }
        qs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        for c in b'a'..=b'z' {
            assert_eq!(decode_char(encode_char(c)) as u8, c);
        }
        assert_eq!(decode_char(encode_char(b' ')), ' ');
        assert_eq!(decode_char(encode_char(b'.')), '.');
    }

    #[test]
    fn corpus_is_deterministic_and_encodable() {
        let t1 = CharLmTask::new(3);
        let t2 = CharLmTask::new(3);
        assert_eq!(t1.corpus(), t2.corpus());
        assert!(t1.tokens().iter().all(|&t| t < CHAR_VOCAB));
        assert!(t1.tokens().len() > 5000, "corpus too small");
    }

    #[test]
    fn questions_have_unique_gold() {
        let t = CharLmTask::new(3);
        let qs = t.questions();
        assert_eq!(qs.len(), 24);
        for q in &qs {
            assert!(q.answer < 4);
            // gold choice text appears exactly once in the corpus context
            let gold = &q.choices[q.answer];
            for (i, c) in q.choices.iter().enumerate() {
                if i != q.answer {
                    assert_ne!(c, gold, "distractor equals gold in {q:?}");
                }
            }
            // the concatenated stem+gold must literally appear in the corpus
            let fact = format!("{}{}", q.stem, gold);
            assert!(t.corpus().contains(&fact), "missing fact {fact}");
        }
    }

    #[test]
    fn subjects_covered() {
        let t = CharLmTask::new(4);
        let mut seen = [false; 4];
        for q in t.questions() {
            seen[q.subject] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
