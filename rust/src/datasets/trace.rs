//! Synthetic serving workload traces — stand-in for production request
//! logs. Poisson arrivals with bursty episodes (Markov-modulated rate),
//! mixed batch sizes, used by the coordinator benches and `serve_xint`.

use crate::tensor::Rng;

/// One request arrival event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// arrival time in seconds from trace start
    pub at: f64,
    /// number of samples in the request
    pub batch: usize,
    /// stable request id
    pub id: u64,
}

/// Workload generator: Poisson arrivals at `rate_rps`, switching into a
/// `burst_factor`× episode with probability `burst_prob` per event.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub rate_rps: f64,
    pub burst_factor: f64,
    pub burst_prob: f64,
    pub max_batch: usize,
    seed: u64,
}

impl RequestTrace {
    pub fn new(rate_rps: f64, seed: u64) -> Self {
        RequestTrace { rate_rps, burst_factor: 4.0, burst_prob: 0.05, max_batch: 8, seed }
    }

    /// Generate events covering `duration` seconds.
    pub fn generate(&self, duration: f64) -> Vec<TraceEvent> {
        let mut rng = Rng::seed(self.seed);
        let mut events = Vec::new();
        let mut t = 0.0f64;
        let mut id = 0u64;
        let mut bursting = false;
        while t < duration {
            let rate = if bursting { self.rate_rps * self.burst_factor } else { self.rate_rps };
            // exponential inter-arrival
            let u = (rng.f32() as f64).max(1e-9);
            t += -u.ln() / rate;
            if t >= duration {
                break;
            }
            // burst state flip
            if rng.f32() < self.burst_prob as f32 {
                bursting = !bursting;
            }
            let batch = 1 + rng.below(self.max_batch);
            events.push(TraceEvent { at: t, batch, id });
            id += 1;
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_sorted_and_in_range() {
        let tr = RequestTrace::new(100.0, 1);
        let ev = tr.generate(2.0);
        assert!(!ev.is_empty());
        assert!(ev.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(ev.iter().all(|e| e.at < 2.0 && e.batch >= 1 && e.batch <= 8));
    }

    #[test]
    fn rate_roughly_matches() {
        let tr = RequestTrace::new(200.0, 2);
        let ev = tr.generate(5.0);
        let per_sec = ev.len() as f64 / 5.0;
        // bursts push the realized rate above nominal; sanity band only
        assert!(per_sec > 120.0 && per_sec < 1000.0, "rate {per_sec}");
    }

    #[test]
    fn deterministic() {
        let a = RequestTrace::new(50.0, 3).generate(1.0);
        let b = RequestTrace::new(50.0, 3).generate(1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn ids_unique_and_sequential() {
        let ev = RequestTrace::new(100.0, 4).generate(1.0);
        for (i, e) in ev.iter().enumerate() {
            assert_eq!(e.id, i as u64);
        }
    }
}
