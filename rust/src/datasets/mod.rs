//! Synthetic dataset substrates — the ImageNet / MNLI / SQuAD / MMLU
//! stand-ins described in DESIGN.md §2. Everything is procedurally
//! generated from a seed, so every table is exactly reproducible and no
//! external data is required.

pub mod charlm;
pub mod synthimg;
pub mod textgen;
pub mod trace;

pub use charlm::CharLmTask;
pub use synthimg::SynthImg;
pub use textgen::{EntailTask, SpanTask, VOCAB};
pub use trace::{RequestTrace, TraceEvent};

use crate::tensor::Tensor;

/// A labelled classification batch: `x` (N,...) and integer labels.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Tensor,
    pub y: Vec<usize>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// Top-1 accuracy of logits (N, K) against labels.
pub fn accuracy(logits: &Tensor, labels: &[usize]) -> f64 {
    let pred = logits.argmax_rows();
    let correct = pred.iter().zip(labels).filter(|(p, y)| p == y).count();
    correct as f64 / labels.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_matches() {
        let logits = Tensor::from_vec(&[3, 2], vec![1., 0., 0., 1., 1., 0.]);
        assert!((accuracy(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-9);
    }
}
