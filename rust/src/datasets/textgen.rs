//! Synthetic token-sequence tasks — the MNLI / SQuAD stand-ins.
//!
//! A small formal grammar over a 32-token vocabulary generates premise /
//! hypothesis pairs with a latent entailment relation (EntailTask → MNLI
//! accuracy) and passages with an answer span marked by latent key tokens
//! (SpanTask → SQuAD F1). Both need attention over token interactions to
//! solve, so they exercise the transformer quantization path.

use crate::tensor::Rng;

/// Vocabulary: 0=PAD, 1=CLS, 2=SEP, 3..=30 content, 31=QUERY marker.
pub const VOCAB: usize = 32;
pub const PAD: usize = 0;
pub const CLS: usize = 1;
pub const SEP: usize = 2;
pub const QUERY: usize = 31;
const CONTENT_LO: usize = 3;
const CONTENT_HI: usize = 30; // inclusive

/// One tokenized example with a sequence label.
#[derive(Clone, Debug)]
pub struct SeqExample {
    pub tokens: Vec<usize>,
    pub label: usize,
}

/// One span-extraction example: find `[start, end]` of the answer.
#[derive(Clone, Debug)]
pub struct SpanExample {
    pub tokens: Vec<usize>,
    pub start: usize,
    pub end: usize,
}

/// 3-way entailment classification (entail / neutral / contradict).
///
/// Construction: premise = random content tokens. Entail hypothesis = a
/// contiguous subsequence of the premise. Contradict hypothesis = the
/// subsequence with each token mapped through a fixed involution (so the
/// model must compare token identities across the SEP). Neutral = fresh
/// random tokens.
#[derive(Clone, Debug)]
pub struct EntailTask {
    pub seq_len: usize,
    seed: u64,
}

impl EntailTask {
    pub fn new(seq_len: usize, seed: u64) -> Self {
        assert!(seq_len >= 12);
        EntailTask { seq_len, seed }
    }

    /// Involution on content tokens ("antonym map").
    fn antonym(t: usize) -> usize {
        CONTENT_LO + (CONTENT_HI - t)
    }

    pub fn batch(&self, n: usize, which: u64) -> Vec<SeqExample> {
        let mut rng = Rng::seed(self.seed ^ which.wrapping_mul(0xA24B_AED4_963E_E407));
        (0..n).map(|_| self.sample(&mut rng)).collect()
    }

    fn sample(&self, rng: &mut Rng) -> SeqExample {
        let label = rng.below(3);
        let prem_len = (self.seq_len - 4) * 2 / 3;
        let hyp_len = self.seq_len - 4 - prem_len;
        let premise: Vec<usize> =
            (0..prem_len).map(|_| CONTENT_LO + rng.below(CONTENT_HI - CONTENT_LO + 1)).collect();
        let start = rng.below(prem_len - hyp_len + 1);
        let hypothesis: Vec<usize> = match label {
            0 => premise[start..start + hyp_len].to_vec(), // entail
            1 => (0..hyp_len)
                .map(|_| CONTENT_LO + rng.below(CONTENT_HI - CONTENT_LO + 1))
                .collect(), // neutral
            _ => premise[start..start + hyp_len].iter().map(|&t| Self::antonym(t)).collect(),
        };
        let mut tokens = Vec::with_capacity(self.seq_len);
        tokens.push(CLS);
        tokens.extend(&premise);
        tokens.push(SEP);
        tokens.extend(&hypothesis);
        tokens.push(SEP);
        while tokens.len() < self.seq_len {
            tokens.push(PAD);
        }
        SeqExample { tokens, label }
    }
}

/// Span extraction: a passage contains a QUERY token followed by a key
/// token `k`; the answer is the (unique) earlier run of tokens bracketed
/// by two copies of `k`. F1 is computed over token overlap as in SQuAD.
#[derive(Clone, Debug)]
pub struct SpanTask {
    pub seq_len: usize,
    seed: u64,
}

impl SpanTask {
    pub fn new(seq_len: usize, seed: u64) -> Self {
        assert!(seq_len >= 16);
        SpanTask { seq_len, seed }
    }

    pub fn batch(&self, n: usize, which: u64) -> Vec<SpanExample> {
        let mut rng = Rng::seed(self.seed ^ which.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        (0..n).map(|_| self.sample(&mut rng)).collect()
    }

    fn sample(&self, rng: &mut Rng) -> SpanExample {
        let body = self.seq_len - 3; // CLS ... QUERY key
        let key = CONTENT_LO + rng.below(CONTENT_HI - CONTENT_LO + 1);
        // fill passage with content tokens != key
        let mut tokens: Vec<usize> = vec![CLS];
        for _ in 0..body {
            let mut t = CONTENT_LO + rng.below(CONTENT_HI - CONTENT_LO + 1);
            while t == key {
                t = CONTENT_LO + rng.below(CONTENT_HI - CONTENT_LO + 1);
            }
            tokens.push(t);
        }
        // choose answer span [start, end] inside the passage, bracket with key
        let span_len = 1 + rng.below(3);
        let start = 2 + rng.below(body.saturating_sub(span_len + 4));
        let end = start + span_len - 1;
        tokens[start - 1] = key;
        tokens[end + 1] = key;
        tokens.push(QUERY);
        tokens.push(key);
        SpanExample { tokens, start, end }
    }
}

/// SQuAD-style token-overlap F1 between predicted and gold span.
pub fn span_f1(pred: (usize, usize), gold: (usize, usize)) -> f64 {
    let (ps, pe) = (pred.0.min(pred.1), pred.0.max(pred.1));
    let (gs, ge) = gold;
    let inter = (pe.min(ge) + 1).saturating_sub(ps.max(gs));
    if inter == 0 {
        return 0.0;
    }
    let p = inter as f64 / (pe - ps + 1) as f64;
    let r = inter as f64 / (ge - gs + 1) as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entail_batch_well_formed() {
        let t = EntailTask::new(24, 5);
        for ex in t.batch(64, 0) {
            assert_eq!(ex.tokens.len(), 24);
            assert_eq!(ex.tokens[0], CLS);
            assert!(ex.label < 3);
            assert!(ex.tokens.iter().all(|&t| t < VOCAB));
        }
    }

    #[test]
    fn entail_labels_balanced_and_deterministic() {
        let t = EntailTask::new(24, 5);
        let b1 = t.batch(300, 0);
        let b2 = t.batch(300, 0);
        assert_eq!(b1.len(), b2.len());
        assert!(b1.iter().zip(&b2).all(|(a, b)| a.tokens == b.tokens && a.label == b.label));
        let counts = b1.iter().fold([0usize; 3], |mut c, e| {
            c[e.label] += 1;
            c
        });
        for c in counts {
            assert!(c > 60, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn entail_signal_exists() {
        // entailed hypothesis tokens must appear in the premise
        let t = EntailTask::new(24, 9);
        for ex in t.batch(100, 1) {
            if ex.label == 0 {
                let sep = ex.tokens.iter().position(|&t| t == SEP).unwrap();
                let premise = &ex.tokens[1..sep];
                let hyp: Vec<usize> = ex.tokens[sep + 1..]
                    .iter()
                    .cloned()
                    .take_while(|&t| t != SEP)
                    .collect();
                for h in hyp {
                    assert!(premise.contains(&h));
                }
            }
        }
    }

    #[test]
    fn span_batch_keys_bracket_answer() {
        let t = SpanTask::new(32, 11);
        for ex in t.batch(64, 0) {
            assert_eq!(ex.tokens.len(), 32);
            let key = *ex.tokens.last().unwrap();
            assert_eq!(ex.tokens[ex.start - 1], key);
            assert_eq!(ex.tokens[ex.end + 1], key);
            assert!(ex.start <= ex.end);
            // answer span itself must not contain the key
            for i in ex.start..=ex.end {
                assert_ne!(ex.tokens[i], key);
            }
        }
    }

    #[test]
    fn f1_known_values() {
        assert_eq!(span_f1((3, 5), (3, 5)), 1.0);
        assert_eq!(span_f1((0, 1), (5, 6)), 0.0);
        let f = span_f1((3, 4), (4, 5)); // overlap 1, both len 2
        assert!((f - 0.5).abs() < 1e-9);
    }
}
