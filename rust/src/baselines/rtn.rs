//! Round-to-nearest (RTN): the naive PTQ floor every paper compares
//! against. Per-tensor symmetric min/max weights, min/max activations,
//! no calibration beyond the activation range observation.

use super::{baseline_pipeline, PtqMethod};
use crate::models::Model;
use crate::tensor::Tensor;
use crate::xint::quantizer::Clip;

pub struct Rtn;

impl PtqMethod for Rtn {
    fn name(&self) -> &'static str {
        "RTN"
    }

    fn quantize(&self, fp: &Model, w_bits: u32, a_bits: u32, calib: &Tensor) -> Model {
        baseline_pipeline(fp, calib, a_bits, Clip::None, &mut |w, first_last| {
            let bits = if first_last { 8 } else { w_bits };
            super::quant_weight_per_tensor(w, bits, Clip::None)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn rtn_weights_live_on_a_grid() {
        let mut rng = Rng::seed(81);
        let w = Tensor::randn(&[4, 8], 0.5, &mut rng);
        let q = super::super::quant_weight_per_tensor(&w, 4, Clip::None);
        // infer the step from the max and check all values are multiples
        let step = w.max_abs() / 8.0;
        for v in q.data() {
            let k = v / step;
            assert!((k - k.round()).abs() < 1e-4, "{v} not on grid (step {step})");
        }
    }

    #[test]
    fn lower_bits_mean_higher_weight_error() {
        let mut rng = Rng::seed(82);
        let w = Tensor::randn(&[4, 64], 0.5, &mut rng);
        let err = |bits| {
            let q = super::super::quant_weight_per_tensor(&w, bits, Clip::None);
            w.sub(&q).norm()
        };
        assert!(err(2) > err(4));
        assert!(err(4) > err(8));
    }
}
