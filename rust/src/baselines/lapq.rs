//! LAPQ (Nahshan et al., 2021): loss-aware PTQ — optimize a global clip
//! fraction against the *network output* error on calibration data
//! (golden-section over the 1-D clip parameter, the paper's L_p-space
//! insight reduced to its core).

use super::{count_quantizable, insert_act_quant, PtqMethod};
use crate::models::quantized::ActObserver;
use crate::models::Model;
use crate::tensor::Tensor;
use crate::xint::quantizer::{fake_quant, Clip, Range, Symmetry};
use crate::xint::BitSpec;

pub struct Lapq {
    pub iters: usize,
}

impl Default for Lapq {
    fn default() -> Self {
        Lapq { iters: 10 }
    }
}

fn quantize_all(fp_folded: &Model, frac: f32, w_bits: u32, total: usize) -> Model {
    let mut m = fp_folded.clone();
    super::transform_weights(&mut m, total, &mut |w, idx| {
        let bits = if super::is_first_or_last(idx, total) { 8 } else { w_bits };
        let spec = BitSpec::int(bits);
        let out_ch = w.dims()[0];
        let chlen = w.numel() / out_ch;
        let mut data = Vec::with_capacity(w.numel());
        for c in 0..out_ch {
            let xs = &w.data()[c * chlen..(c + 1) * chlen];
            let maxabs = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let r = Range { bias: 0.0, half_width: maxabs * frac };
            data.extend(fake_quant(xs, r, spec));
        }
        Tensor::from_vec(w.dims(), data)
    });
    m
}

/// Golden-section search for the loss-minimizing global clip fraction.
pub fn search_clip_frac(
    folded: &Model,
    calib: &Tensor,
    w_bits: u32,
    total: usize,
    iters: usize,
) -> f32 {
    let y_fp = folded.forward(calib);
    let loss = |frac: f32| {
        let q = quantize_all(folded, frac, w_bits, total);
        y_fp.sub(&q.forward(calib)).norm()
    };
    // the loss landscape is not reliably unimodal at very low bits, so use
    // a coarse grid (LAPQ's multi-point initialization) and refine locally
    let mut best = (loss(1.0), 1.0f32);
    let coarse = iters.max(4);
    for i in 0..coarse {
        let frac = 0.3 + 0.7 * i as f32 / (coarse - 1) as f32;
        let l = loss(frac);
        if l < best.0 {
            best = (l, frac);
        }
    }
    // local refinement around the winner
    for &d in &[-0.05f32, -0.02, 0.02, 0.05] {
        let frac = (best.1 + d).clamp(0.3, 1.0);
        let l = loss(frac);
        if l < best.0 {
            best = (l, frac);
        }
    }
    best.1
}

impl PtqMethod for Lapq {
    fn name(&self) -> &'static str {
        "LAPQ"
    }

    fn quantize(&self, fp: &Model, w_bits: u32, a_bits: u32, calib: &Tensor) -> Model {
        let mut folded = fp.clone();
        folded.fold_bn();
        let total = count_quantizable(&folded.layers);
        let best = search_clip_frac(&folded, calib, w_bits, total, self.iters);
        let mut m = quantize_all(&folded, best, w_bits, total);
        let obs = ActObserver::observe(&m, calib, Symmetry::Asymmetric, Clip::Laplace, a_bits);
        insert_act_quant(&mut m, &obs.ranges, a_bits, total);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn searched_clip_beats_full_range_weight_only() {
        // apples-to-apples: weight-only quantization at the searched clip
        // fraction vs the full range (no activation quantization on either)
        let (m, calib) = super::super::tests::trained_small();
        let mut folded = m.clone();
        folded.fold_bn();
        let total = count_quantizable(&folded.layers);
        let y_fp = folded.forward(&calib);
        let best = search_clip_frac(&folded, &calib, 2, total, 10);
        let e_best = y_fp.sub(&quantize_all(&folded, best, 2, total).forward(&calib)).norm();
        let e_full = y_fp.sub(&quantize_all(&folded, 1.0, 2, total).forward(&calib)).norm();
        assert!(e_best <= e_full * 1.001, "searched {e_best} vs full {e_full}");
    }
}
