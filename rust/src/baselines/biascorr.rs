//! DFQ-style bias correction (Nagel et al., 2019): after weight
//! quantization, restore each layer's expected output by absorbing the
//! mean quantization-error shift into the bias — data-free in the
//! original; we use the calibration batch as the expectation estimate.

use super::{count_quantizable, insert_act_quant, is_first_or_last, PtqMethod};
use crate::models::graph::{Layer, Model};
use crate::models::quantized::ActObserver;
use crate::tensor::Tensor;
use crate::xint::quantizer::{Clip, Symmetry};

pub struct BiasCorr;

impl PtqMethod for BiasCorr {
    fn name(&self) -> &'static str {
        "DFQ-BiasCorr"
    }

    fn quantize(&self, fp: &Model, w_bits: u32, a_bits: u32, calib: &Tensor) -> Model {
        let mut m = fp.clone();
        m.fold_bn();
        let total = count_quantizable(&m.layers);
        fn walk(layers: &mut [Layer], h: &Tensor, idx: &mut usize, total: usize, w_bits: u32) -> Tensor {
            let mut h = h.clone();
            for l in layers {
                match l {
                    Layer::Residual(main, short) => {
                        let hm = walk(main, &h, idx, total, w_bits);
                        let hs = walk(short, &h, idx, total, w_bits);
                        h = hm.add(&hs);
                    }
                    Layer::Branches(bs) => {
                        let outs: Vec<Tensor> =
                            bs.iter_mut().map(|b| walk(b, &h, idx, total, w_bits)).collect();
                        h = crate::models::graph::concat_channels_pub(&outs);
                    }
                    Layer::Conv(c) => {
                        let bits = if is_first_or_last(*idx, total) { 8 } else { w_bits };
                        *idx += 1;
                        let fp_out = c.forward(&h);
                        c.w = super::quant_weight_per_channel(&c.w, bits, Clip::None);
                        let q_out = c.forward(&h);
                        // per-channel mean error over batch and spatial dims
                        let (n, oc, oh, ow) =
                            (q_out.dims()[0], q_out.dims()[1], q_out.dims()[2], q_out.dims()[3]);
                        let mut bias = c.b.clone().unwrap_or_else(|| Tensor::zeros(&[oc]));
                        for ch in 0..oc {
                            let mut err = 0.0f64;
                            for ni in 0..n {
                                let base = (ni * oc + ch) * oh * ow;
                                for p in 0..oh * ow {
                                    err += (fp_out.data()[base + p] - q_out.data()[base + p]) as f64;
                                }
                            }
                            bias.data_mut()[ch] += (err / (n * oh * ow) as f64) as f32;
                        }
                        c.b = Some(bias);
                        h = fp_out;
                    }
                    Layer::Linear(lin) => {
                        let bits = if is_first_or_last(*idx, total) { 8 } else { w_bits };
                        *idx += 1;
                        let fp_out = lin.forward(&h);
                        lin.w = super::quant_weight_per_channel(&lin.w, bits, Clip::None);
                        let q_out = lin.forward(&h);
                        let err = fp_out.sub(&q_out).sum_axis0().scale(1.0 / h.dims()[0] as f32);
                        let mut bias =
                            lin.b.clone().unwrap_or_else(|| Tensor::zeros(&[fp_out.dims()[1]]));
                        bias.axpy(1.0, &err);
                        lin.b = Some(bias);
                        h = fp_out;
                    }
                    other => {
                        h = other.forward(&h);
                    }
                }
            }
            h
        }
        let mut idx = 0usize;
        let _ = walk(&mut m.layers, calib, &mut idx, total, w_bits);
        let obs = ActObserver::observe(&m, calib, Symmetry::Asymmetric, Clip::Laplace, a_bits);
        insert_act_quant(&mut m, &obs.ranges, a_bits, total);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_correction_zeroes_mean_output_shift() {
        let (m, calib) = super::super::tests::trained_small();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&calib);
        let q = BiasCorr.quantize(&m, 3, 8, &calib);
        let yq = q.forward(&calib);
        // mean shift per class must be tiny compared to the RTN version
        let q_rtn = super::super::Rtn.quantize(&m, 3, 8, &calib);
        let yr = q_rtn.forward(&calib);
        let mean_shift = |y: &Tensor| {
            let d = yf.sub(y).sum_axis0().scale(1.0 / yf.dims()[0] as f32);
            d.max_abs()
        };
        assert!(
            mean_shift(&yq) <= mean_shift(&yr) * 1.1,
            "biascorr shift {} rtn shift {}",
            mean_shift(&yq),
            mean_shift(&yr)
        );
    }
}
