//! PTQ baseline comparators (Table 1/2/3/4 rows): each implements the
//! core idea of its paper at the granularity this harness needs.
//!
//! All baselines share the same protocol as the paper's §5.1 setup: BN is
//! folded first, weights are fake-quantized (per-channel unless noted),
//! activations are fake-quantized by [`graph::Layer::ActQuant`] nodes
//! inserted after every conv/linear with ranges calibrated on a small
//! unlabeled batch, and the first/last layer runs at 8 bits.

pub mod aciq;
pub mod adaquant;
pub mod biascorr;
pub mod ensemble;
pub mod lapq;
pub mod mseclip;
pub mod rtn;

pub use aciq::Aciq;
pub use adaquant::AdaQuant;
pub use biascorr::BiasCorr;
pub use ensemble::IntEnsemble;
pub use lapq::Lapq;
pub use mseclip::MseClip;
pub use rtn::Rtn;

use crate::models::graph::{Layer, Model};
use crate::tensor::Tensor;
use crate::xint::quantizer::{channel_range, fake_quant, Clip, Range, Symmetry};
use crate::xint::BitSpec;

/// A PTQ method: FP model + calibration batch → fake-quantized FP model.
pub trait PtqMethod {
    fn name(&self) -> &'static str;
    /// Quantize (weights at `w_bits`, activations at `a_bits`).
    fn quantize(&self, fp: &Model, w_bits: u32, a_bits: u32, calib: &Tensor) -> Model;
}

/// First/last-layer index bookkeeping shared by all methods.
pub(crate) fn is_first_or_last(idx: usize, total: usize) -> bool {
    idx == 0 || idx + 1 == total
}

pub(crate) fn count_quantizable(layers: &[Layer]) -> usize {
    layers
        .iter()
        .map(|l| match l {
            Layer::Conv(_) | Layer::Linear(_) => 1,
            Layer::Residual(m, s) => count_quantizable(m) + count_quantizable(s),
            Layer::Branches(bs) => bs.iter().map(|b| count_quantizable(b)).sum(),
            _ => 0,
        })
        .sum()
}

/// Fake-quantize a weight tensor per output channel.
pub(crate) fn quant_weight_per_channel(w: &Tensor, bits: u32, clip: Clip) -> Tensor {
    let out_ch = w.dims()[0];
    let chlen = w.numel() / out_ch;
    let spec = BitSpec::int(bits);
    let mut data = Vec::with_capacity(w.numel());
    for c in 0..out_ch {
        let xs = &w.data()[c * chlen..(c + 1) * chlen];
        let r = channel_range(xs, Symmetry::Symmetric, clip, bits);
        data.extend(fake_quant(xs, r, spec));
    }
    Tensor::from_vec(w.dims(), data)
}

/// Fake-quantize a weight tensor per tensor (RTN-style).
pub(crate) fn quant_weight_per_tensor(w: &Tensor, bits: u32, clip: Clip) -> Tensor {
    let spec = BitSpec::int(bits);
    let r = channel_range(w.data(), Symmetry::Symmetric, clip, bits);
    Tensor::from_vec(w.dims(), fake_quant(w.data(), r, spec))
}

/// Walk the graph, applying `f(weight, layer_idx, total)` to each
/// conv/linear weight in execution order.
pub(crate) fn transform_weights(
    model: &mut Model,
    total: usize,
    f: &mut dyn FnMut(&Tensor, usize) -> Tensor,
) {
    fn walk(layers: &mut [Layer], idx: &mut usize, f: &mut dyn FnMut(&Tensor, usize) -> Tensor) {
        for l in layers {
            match l {
                Layer::Conv(c) => {
                    c.w = f(&c.w, *idx);
                    *idx += 1;
                }
                Layer::Linear(lin) => {
                    lin.w = f(&lin.w, *idx);
                    *idx += 1;
                }
                Layer::Residual(m, s) => {
                    walk(m, idx, f);
                    walk(s, idx, f);
                }
                Layer::Branches(bs) => {
                    for b in bs {
                        walk(b, idx, f);
                    }
                }
                _ => {}
            }
        }
    }
    let mut idx = 0usize;
    walk(&mut model.layers, &mut idx, f);
    debug_assert_eq!(idx, total);
}

/// Insert `ActQuant(range, bits)` after every conv/linear, using
/// calibrated per-layer ranges (execution order). First/last layers get
/// 8-bit ranges per the shared protocol.
pub(crate) fn insert_act_quant(
    model: &mut Model,
    ranges: &[Range],
    a_bits: u32,
    total: usize,
) {
    fn walk(
        layers: &mut Vec<Layer>,
        idx: &mut usize,
        ranges: &[Range],
        a_bits: u32,
        total: usize,
    ) {
        let mut i = 0;
        while i < layers.len() {
            match &mut layers[i] {
                Layer::Residual(m, s) => {
                    walk(m, idx, ranges, a_bits, total);
                    walk(s, idx, ranges, a_bits, total);
                }
                Layer::Branches(bs) => {
                    for b in bs.iter_mut() {
                        walk(b, idx, ranges, a_bits, total);
                    }
                }
                Layer::Conv(_) | Layer::Linear(_) => {
                    let bits = if is_first_or_last(*idx, total) { 8 } else { a_bits };
                    let r = ranges[*idx];
                    *idx += 1;
                    layers.insert(i + 1, Layer::ActQuant(r, BitSpec::int(bits)));
                    i += 1; // skip the inserted node
                }
                _ => {}
            }
            i += 1;
        }
    }
    let mut idx = 0usize;
    walk(&mut model.layers, &mut idx, ranges, a_bits, total);
    debug_assert_eq!(idx, total);
}

/// The standard baseline pipeline: fold BN → quantize weights with
/// `wq(w, is_first_last)` → calibrate activation ranges with `clip` →
/// insert ActQuant nodes.
pub(crate) fn baseline_pipeline(
    fp: &Model,
    calib: &Tensor,
    a_bits: u32,
    act_clip: Clip,
    wq: &mut dyn FnMut(&Tensor, bool) -> Tensor,
) -> Model {
    let mut m = fp.clone();
    m.fold_bn();
    let total = count_quantizable(&m.layers);
    transform_weights(&mut m, total, &mut |w, idx| {
        wq(w, is_first_or_last(idx, total))
    });
    // calibrate activation ranges on the weight-quantized model (post-quant
    // distributions are what the runtime sees)
    let obs = crate::models::quantized::ActObserver::observe(
        &m,
        calib,
        Symmetry::Asymmetric,
        act_clip,
        a_bits,
    );
    insert_act_quant(&mut m, &obs.ranges, a_bits, total);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SynthImg;
    use crate::models::zoo;
    use crate::tensor::Rng;

    /// Shared trained fixture — training once for the whole test binary
    /// keeps the baseline test suite fast.
    pub(crate) fn trained_small() -> (Model, Tensor) {
        static FIXTURE: std::sync::OnceLock<(Model, Tensor)> = std::sync::OnceLock::new();
        FIXTURE
            .get_or_init(|| {
                let data = SynthImg::new(4, 1, 12, 0.15, 21);
                let mut m = zoo::mini_resnet_a(4, 22);
                let cfg =
                    crate::train::TrainConfig { steps: 80, batch: 24, lr: 0.05, log_every: 1000 };
                crate::train::train_classifier(&mut m, &data, &cfg);
                let calib = data.batch(32, 3).x;
                (m, calib)
            })
            .clone()
    }

    #[test]
    fn all_methods_preserve_topology_and_run() {
        let (m, calib) = trained_small();
        let methods: Vec<Box<dyn PtqMethod>> = vec![
            Box::new(Rtn),
            Box::new(Aciq),
            Box::new(MseClip),
            Box::new(AdaQuant::default()),
            Box::new(Lapq::default()),
            Box::new(BiasCorr),
        ];
        let mut rng = Rng::seed(23);
        let x = Tensor::randn(&[2, 1, 12, 12], 1.0, &mut rng);
        for meth in methods {
            let q = meth.quantize(&m, 4, 4, &calib);
            let y = q.forward(&x);
            assert_eq!(y.dims(), &[2, 4], "{}", meth.name());
            assert!(y.data().iter().all(|v| v.is_finite()), "{}", meth.name());
        }
    }

    #[test]
    fn eight_bit_baselines_match_fp_closely() {
        let (m, calib) = trained_small();
        let mut fp = m.clone();
        fp.fold_bn();
        let x = calib.clone();
        let yf = fp.forward(&x);
        for meth in [&Rtn as &dyn PtqMethod, &Aciq] {
            let q = meth.quantize(&m, 8, 8, &calib);
            let yq = q.forward(&x);
            let rel = yf.sub(&yq).norm() / yf.norm();
            assert!(rel < 0.1, "{} W8A8 rel err {rel}", meth.name());
        }
    }

    #[test]
    fn act_quant_nodes_inserted_once_per_layer() {
        let (m, calib) = trained_small();
        let q = Rtn.quantize(&m, 4, 4, &calib);
        fn counts(layers: &[Layer]) -> (usize, usize) {
            let mut ql = 0;
            let mut aq = 0;
            for l in layers {
                match l {
                    Layer::Conv(_) | Layer::Linear(_) => ql += 1,
                    Layer::ActQuant(..) => aq += 1,
                    Layer::Residual(m, s) => {
                        let (a, b) = counts(m);
                        let (c, d) = counts(s);
                        ql += a + c;
                        aq += b + d;
                    }
                    Layer::Branches(bs) => {
                        for b in bs {
                            let (a, bb) = counts(b);
                            ql += a;
                            aq += bb;
                        }
                    }
                    _ => {}
                }
            }
            (ql, aq)
        }
        let (ql, aq) = counts(&q.layers);
        assert_eq!(ql, aq, "one ActQuant per quantizable layer");
        assert!(ql > 3);
    }
}
