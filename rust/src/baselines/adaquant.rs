//! AdaQuant (Hubara et al., 2020): layer-by-layer calibration — for each
//! layer, search the weight quantization scale that minimizes the layer's
//! *output* error on a calibration batch (the layerwise-optimization core
//! of the method, without the integer-programming bit allocation).

use super::{count_quantizable, insert_act_quant, is_first_or_last, PtqMethod};
use crate::models::graph::{Layer, Model};
use crate::models::quantized::ActObserver;
use crate::tensor::Tensor;
use crate::xint::quantizer::{fake_quant, Clip, Range, Symmetry};
use crate::xint::BitSpec;

pub struct AdaQuant {
    /// scale-multiplier grid around the min/max scale
    pub grid: Vec<f32>,
}

impl Default for AdaQuant {
    fn default() -> Self {
        AdaQuant { grid: vec![0.5, 0.65, 0.8, 0.9, 1.0, 1.1] }
    }
}

/// Quantize `w` per-channel with a global scale multiplier `mult`.
fn quant_with_mult(w: &Tensor, bits: u32, mult: f32) -> Tensor {
    let out_ch = w.dims()[0];
    let chlen = w.numel() / out_ch;
    let spec = BitSpec::int(bits);
    let mut data = Vec::with_capacity(w.numel());
    for c in 0..out_ch {
        let xs = &w.data()[c * chlen..(c + 1) * chlen];
        let maxabs = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let r = Range { bias: 0.0, half_width: maxabs * mult };
        data.extend(fake_quant(xs, r, spec));
    }
    Tensor::from_vec(w.dims(), data)
}

impl PtqMethod for AdaQuant {
    fn name(&self) -> &'static str {
        "AdaQuant"
    }

    fn quantize(&self, fp: &Model, w_bits: u32, a_bits: u32, calib: &Tensor) -> Model {
        let mut m = fp.clone();
        m.fold_bn();
        let total = count_quantizable(&m.layers);
        // walk the graph carrying the calibration activation; optimize each
        // layer's scale against its own FP output
        let grid = self.grid.clone();
        fn walk(
            layers: &mut [Layer],
            h: &Tensor,
            idx: &mut usize,
            total: usize,
            w_bits: u32,
            grid: &[f32],
        ) -> Tensor {
            let mut h = h.clone();
            for l in layers {
                match l {
                    Layer::Residual(main, short) => {
                        let hm = walk(main, &h, idx, total, w_bits, grid);
                        let hs = walk(short, &h, idx, total, w_bits, grid);
                        h = hm.add(&hs);
                    }
                    Layer::Branches(bs) => {
                        let outs: Vec<Tensor> = bs
                            .iter_mut()
                            .map(|b| walk(b, &h, idx, total, w_bits, grid))
                            .collect();
                        h = crate::models::graph::concat_channels_pub(&outs);
                    }
                    Layer::Conv(c) => {
                        let bits = if is_first_or_last(*idx, total) { 8 } else { w_bits };
                        *idx += 1;
                        let fp_out = c.forward(&h);
                        let w0 = c.w.clone();
                        let mut best = (f32::INFINITY, 1.0f32);
                        for &mult in grid {
                            c.w = quant_with_mult(&w0, bits, mult);
                            let out = c.forward(&h);
                            let err = fp_out.sub(&out).norm();
                            if err < best.0 {
                                best = (err, mult);
                            }
                        }
                        c.w = quant_with_mult(&w0, bits, best.1);
                        h = fp_out; // calibrate downstream layers on FP activations
                    }
                    Layer::Linear(lin) => {
                        let bits = if is_first_or_last(*idx, total) { 8 } else { w_bits };
                        *idx += 1;
                        let fp_out = lin.forward(&h);
                        let w0 = lin.w.clone();
                        let mut best = (f32::INFINITY, 1.0f32);
                        for &mult in grid {
                            lin.w = quant_with_mult(&w0, bits, mult);
                            let out = lin.forward(&h);
                            let err = fp_out.sub(&out).norm();
                            if err < best.0 {
                                best = (err, mult);
                            }
                        }
                        lin.w = quant_with_mult(&w0, bits, best.1);
                        h = fp_out;
                    }
                    other => {
                        h = other.forward(&h);
                    }
                }
            }
            h
        }
        let mut idx = 0usize;
        let _ = walk(&mut m.layers, calib, &mut idx, total, w_bits, &grid);
        debug_assert_eq!(idx, total);
        // activation calibration as usual
        let obs = ActObserver::observe(&m, calib, Symmetry::Asymmetric, Clip::Laplace, a_bits);
        insert_act_quant(&mut m, &obs.ranges, a_bits, total);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn scale_search_improves_layer_output_error() {
        // heavy-tailed weights: mult < 1 should win over mult = 1
        let mut rng = Rng::seed(85);
        let w = Tensor::from_vec(&[4, 128], (0..512).map(|_| rng.laplace(0.2)).collect());
        let x = Tensor::randn(&[8, 128], 1.0, &mut rng);
        let fp = crate::tensor::matmul_a_bt(&x, &w);
        let err = |mult: f32| {
            let q = quant_with_mult(&w, 3, mult);
            fp.sub(&crate::tensor::matmul_a_bt(&x, &q)).norm()
        };
        let best_sub1 = [0.5f32, 0.65, 0.8].iter().cloned().map(err).fold(f32::INFINITY, f32::min);
        assert!(best_sub1 < err(1.0), "clipped scale should win on laplace weights");
    }

    #[test]
    fn adaquant_not_worse_than_rtn_on_model_output() {
        let (m, calib) = super::super::tests::trained_small();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&calib);
        let q_ada = AdaQuant::default().quantize(&m, 3, 8, &calib);
        let q_rtn = super::super::Rtn.quantize(&m, 3, 8, &calib);
        let e_ada = yf.sub(&q_ada.forward(&calib)).norm();
        let e_rtn = yf.sub(&q_rtn.forward(&calib)).norm();
        assert!(e_ada <= e_rtn * 1.05, "ada {e_ada} rtn {e_rtn}");
    }
}
