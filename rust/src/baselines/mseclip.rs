//! MSE-grid clipping: per-channel grid search over clip fractions
//! minimizing weight reconstruction MSE (the OMSE-style calibration used
//! as a strong range-only baseline).

use super::{baseline_pipeline, PtqMethod};
use crate::models::Model;
use crate::tensor::Tensor;
use crate::xint::quantizer::{fake_quant, Clip, Range, Symmetry};
use crate::xint::BitSpec;

pub struct MseClip;

/// Per-channel MSE-optimal clip fraction (grid over [0.3, 1.0]·max).
pub fn mse_quant_per_channel(w: &Tensor, bits: u32) -> Tensor {
    let out_ch = w.dims()[0];
    let chlen = w.numel() / out_ch;
    let spec = BitSpec::int(bits);
    let mut data = Vec::with_capacity(w.numel());
    for c in 0..out_ch {
        let xs = &w.data()[c * chlen..(c + 1) * chlen];
        let maxabs = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mut best = (f32::INFINITY, maxabs);
        for i in 0..24 {
            let frac = 0.3 + 0.7 * (i as f32 / 23.0);
            let r = Range { bias: 0.0, half_width: maxabs * frac };
            let q = fake_quant(xs, r, spec);
            let mse: f32 = xs.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
            if mse < best.0 {
                best = (mse, maxabs * frac);
            }
        }
        let r = Range { bias: 0.0, half_width: best.1 };
        data.extend(fake_quant(xs, r, spec));
    }
    Tensor::from_vec(w.dims(), data)
}

impl PtqMethod for MseClip {
    fn name(&self) -> &'static str {
        "MSE-Clip"
    }

    fn quantize(&self, fp: &Model, w_bits: u32, a_bits: u32, calib: &Tensor) -> Model {
        baseline_pipeline(fp, calib, a_bits, Clip::Laplace, &mut |w, first_last| {
            let bits = if first_last { 8 } else { w_bits };
            mse_quant_per_channel(w, bits)
        })
    }
}

/// Percentile activation variant used by LAPQ's starting point; exposed
/// for reuse.
pub fn percentile_range(xs: &[f32], p: f32, bits: u32) -> Range {
    crate::xint::quantizer::channel_range(xs, Symmetry::Asymmetric, Clip::Percentile(p), bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::xint::quantizer::Clip;

    #[test]
    fn mse_clip_never_worse_than_full_range() {
        let mut rng = Rng::seed(84);
        // mix of gaussian + outliers
        let mut data: Vec<f32> = (0..512).map(|_| rng.normal() * 0.2).collect();
        data[0] = 4.0;
        data[511] = -4.0;
        let w = Tensor::from_vec(&[2, 256], data);
        let q_full = super::super::quant_weight_per_channel(&w, 4, Clip::None);
        let q_mse = mse_quant_per_channel(&w, 4);
        assert!(w.sub(&q_mse).norm() <= w.sub(&q_full).norm() * 1.001);
    }

    #[test]
    fn percentile_range_trims_outliers() {
        let mut xs: Vec<f32> = (0..99).map(|i| i as f32 / 99.0).collect();
        xs.push(100.0);
        let r = percentile_range(&xs, 95.0, 4);
        assert!(r.half_width < 50.0, "outlier not trimmed: {}", r.half_width);
    }
}
