//! ACIQ (Banner et al., 2018): analytical clipping for integer
//! quantization — per-channel weights and activations clipped at the
//! Laplace-MSE-optimal threshold (the closed-form clip our
//! `xint::quantizer::optimal_laplace_clip` implements).

use super::{baseline_pipeline, PtqMethod};
use crate::models::Model;
use crate::tensor::Tensor;
use crate::xint::quantizer::Clip;

pub struct Aciq;

impl PtqMethod for Aciq {
    fn name(&self) -> &'static str {
        "ACIQ"
    }

    fn quantize(&self, fp: &Model, w_bits: u32, a_bits: u32, calib: &Tensor) -> Model {
        baseline_pipeline(fp, calib, a_bits, Clip::Laplace, &mut |w, first_last| {
            let bits = if first_last { 8 } else { w_bits };
            super::quant_weight_per_channel(w, bits, Clip::Laplace)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::xint::quantizer::Clip;

    #[test]
    fn aciq_beats_rtn_on_heavy_tailed_weights() {
        // Laplace-distributed weights: clipping wins at low bits
        let mut rng = Rng::seed(83);
        let w = Tensor::from_vec(&[4, 256], (0..1024).map(|_| rng.laplace(0.3)).collect());
        let q_rtn = super::super::quant_weight_per_tensor(&w, 3, Clip::None);
        let q_aciq = super::super::quant_weight_per_channel(&w, 3, Clip::Laplace);
        assert!(
            w.sub(&q_aciq).norm() < w.sub(&q_rtn).norm(),
            "aciq {} rtn {}",
            w.sub(&q_aciq).norm(),
            w.sub(&q_rtn).norm()
        );
    }
}
