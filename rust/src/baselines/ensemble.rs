//! INT-model ensembles — the §5.4 "Series Expansion ≠ Ensemble" control.
//!
//! Combines `k` independently quantized INT models (each sees a different
//! stochastic rounding realization) by output averaging. The paper's
//! point: this does NOT converge to the FP model as k grows, while the
//! series expansion does — the benches quantify exactly that gap.

use crate::models::graph::Model;
use crate::tensor::{Rng, Tensor};
use crate::xint::quantizer::Range;
use crate::xint::BitSpec;

pub struct IntEnsemble {
    pub members: usize,
    pub seed: u64,
}

impl IntEnsemble {
    pub fn new(members: usize, seed: u64) -> Self {
        IntEnsemble { members, seed }
    }

    /// Stochastic-rounding fake quant: round up with probability equal to
    /// the fractional part (unbiased; different seeds → different members).
    fn stochastic_quant(w: &Tensor, bits: u32, rng: &mut Rng) -> Tensor {
        let spec = BitSpec::int(bits);
        let half = spec.half() as f32;
        let out_ch = w.dims()[0];
        let chlen = w.numel() / out_ch;
        let mut data = Vec::with_capacity(w.numel());
        for c in 0..out_ch {
            let xs = &w.data()[c * chlen..(c + 1) * chlen];
            let maxabs = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if maxabs == 0.0 {
                data.extend_from_slice(xs);
                continue;
            }
            let scale = maxabs / half;
            for &v in xs {
                let t = v / scale;
                let fl = t.floor();
                let frac = t - fl;
                let q = if (rng.f32()) < frac { fl + 1.0 } else { fl };
                data.push(q.clamp(-half, half) * scale);
            }
        }
        Tensor::from_vec(w.dims(), data)
    }

    /// Build the ensemble members from an FP model.
    pub fn build(&self, fp: &Model, w_bits: u32) -> Vec<Model> {
        let mut base = fp.clone();
        base.fold_bn();
        let total = super::count_quantizable(&base.layers);
        let mut rng = Rng::seed(self.seed);
        (0..self.members)
            .map(|_| {
                let mut m = base.clone();
                let mut member_rng = rng.fork(1);
                super::transform_weights(&mut m, total, &mut |w, _| {
                    Self::stochastic_quant(w, w_bits, &mut member_rng)
                });
                m
            })
            .collect()
    }

    /// Ensemble prediction: average of member logits.
    pub fn forward(members: &[Model], x: &Tensor) -> Tensor {
        let mut acc: Option<Tensor> = None;
        for m in members {
            let y = m.forward(x);
            acc = Some(match acc {
                Some(a) => a.add(&y),
                None => y,
            });
        }
        acc.expect("no members").scale(1.0 / members.len() as f32)
    }

    /// A matched-budget series expansion uses `members` INT terms; the
    /// ensemble uses `members` INT models. Returns (ensemble_err,
    /// series_err) against the FP output — the §5.4 comparison.
    pub fn versus_series(
        &self,
        fp: &Model,
        w_bits: u32,
        x: &Tensor,
    ) -> (f64, f64) {
        let mut folded = fp.clone();
        folded.fold_bn();
        let y_fp = folded.forward(x);
        let members = self.build(fp, w_bits);
        let y_ens = Self::forward(&members, x);
        let ens_err = (y_fp.sub(&y_ens).norm() / y_fp.norm()) as f64;
        // series: same #INT terms in the weight expansion
        let policy = crate::xint::layer::LayerPolicy::new(w_bits, 8)
            .with_terms(self.members, 2);
        let q = crate::models::quantized::quantize_model(fp, policy);
        let y_series = q.forward(x);
        let ser_err = (y_fp.sub(&y_series).norm() / y_fp.norm()) as f64;
        (ens_err, ser_err)
    }

    /// Average fake-quant range helper exposed for tests.
    pub fn nominal_range(w: &Tensor, bits: u32) -> Range {
        crate::xint::quantizer::channel_range(
            w.data(),
            crate::xint::quantizer::Symmetry::Symmetric,
            crate::xint::quantizer::Clip::None,
            bits,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stochastic_rounding_is_unbiased() {
        let mut rng = Rng::seed(91);
        let w = Tensor::full(&[1, 1000], 0.3711);
        let mut acc = vec![0.0f64; 1000];
        let reps = 64;
        for _ in 0..reps {
            let q = IntEnsemble::stochastic_quant(&w, 4, &mut rng);
            for (a, &v) in acc.iter_mut().zip(q.data()) {
                *a += v as f64;
            }
        }
        let grand_mean = acc.iter().sum::<f64>() / (1000.0 * reps as f64);
        assert!((grand_mean - 0.3711).abs() < 0.005, "biased: {grand_mean}");
    }

    #[test]
    fn ensemble_error_plateaus_while_series_converges() {
        let (m, calib) = super::super::tests::trained_small();
        let e2 = IntEnsemble::new(2, 7).versus_series(&m, 3, &calib);
        let e4 = IntEnsemble::new(4, 7).versus_series(&m, 3, &calib);
        // series error shrinks fast with terms; ensemble error stays
        // roughly flat (it averages noise but keeps the quantization bias)
        assert!(e4.1 < e2.1 * 0.5, "series must converge: {} -> {}", e2.1, e4.1);
        assert!(e4.0 > e4.1 * 3.0, "ensemble {} should be far above series {}", e4.0, e4.1);
    }

    #[test]
    fn members_differ_but_agree_on_average() {
        let (m, calib) = super::super::tests::trained_small();
        let members = IntEnsemble::new(3, 11).build(&m, 4);
        assert_eq!(members.len(), 3);
        let y0 = members[0].forward(&calib);
        let y1 = members[1].forward(&calib);
        assert!(y0.sub(&y1).max_abs() > 0.0, "members must differ");
    }
}
