//! Pass 3 — wire-protocol conformance.
//!
//! Protocol v3's byte layout is duplicated by design: the incremental
//! `FrameDecoder` and the encoders live in `serve/protocol.rs`, the
//! blocking clients re-read the same offsets, and loadgen's open-loop
//! `RespDecoder` duplicates the response layout a third time. Nothing
//! ties those copies together except review care — this pass pins them
//! to each other and to an append-only code registry:
//!
//! * `registry-pin` / `registry-append` / `registry-collision` — the
//!   `CODE_*` / `CTRL_*` / `STREAM_*` wire constants form an
//!   append-only registry: a pinned constant must parse to exactly its
//!   registered value, a new family member must be registered in
//!   [`WIRE_PINNED`], and no two codes in a family may share a value.
//! * `frame-offset` — decoder byte offsets (`u64_at(12)` is the
//!   request trace id, `u64_at(8)` the cancel trace id, header sizes
//!   8/16/20/28) in both the codec and loadgen's duplicate.
//! * `encoder-layout` — every encoder emits its fields in the
//!   documented frame order (extracted from the `to_le_bytes` call
//!   sequence in its body).
//! * `client-layout` — the blocking clients read words in frame order.
//! * `spankind-append` — `SpanKind`'s packed wire numbering (delegated
//!   from `scripts/check_invariants.py`): pinned variants never
//!   renumber, appended ones take the next discriminant.
//! * `layout-local` — no `to_le_bytes`/`from_le_bytes` anywhere else
//!   in the serving plane: frame layouts live in the codec (loadgen's
//!   decoder being the one sanctioned copy).

use super::lexer::{collect_consts, seq_count, LexFile, Tok, TokKind};
use super::{missing_file, Finding, Level, SourceSet};

const PASS: &str = "protocol";

pub const PROTOCOL_FILE: &str = "serve/protocol.rs";
pub const LOADGEN_FILE: &str = "serve/loadgen.rs";
pub const RECORDER_FILE: &str = "obs/recorder.rs";

/// Registry prefixes that form wire-code families (collision scope).
const FAMILIES: [&str; 3] = ["CODE_", "CTRL_", "STREAM_"];

/// The append-only wire-constant registry. Renumbering any entry is a
/// protocol break; appending a code means appending here too — that is
/// the review gate, mirroring the python lint's SpanKind flow.
const WIRE_PINNED: [(&str, i128); 12] = [
    ("CODE_SHED", 0),
    ("CODE_BATCH_FAILED", 1),
    ("CODE_MALFORMED", 2),
    ("CONTROL_SENTINEL", 4_294_967_295),
    ("CTRL_METRICS", 1),
    ("CTRL_TRACE", 2),
    ("STREAM_SENTINEL", 4_294_967_294),
    ("STREAM_FLAG", 0x8000_0000),
    ("STREAM_PREFIX", 0),
    ("STREAM_DELTA", 1),
    ("STREAM_END", 2),
    ("MAX_ELEMS", 16_777_216),
];

/// `SpanKind`'s packed wire numbering (delegated from
/// `check_invariants.py`, which now keeps only the text-level
/// ratchets). Discriminants are packed into ring slots and exported —
/// append, never reorder.
const SPANKIND_PINNED: [(&str, i128); 13] = [
    ("Request", 0),
    ("Decode", 1),
    ("Admission", 2),
    ("QueueWait", 3),
    ("BatchForm", 4),
    ("Schedule", 5),
    ("WorkerTerm", 6),
    ("Reduce", 7),
    ("Reply", 8),
    ("LayerGrid", 9),
    ("Accept", 10),
    ("Write", 11),
    ("Refine", 12),
];

fn err(out: &mut Vec<Finding>, file: &str, line: u32, rule: &'static str, message: String) {
    let file = file.to_string();
    out.push(Finding { file, line, pass: PASS, rule, level: Level::Error, message });
}

/// A named fn body with its location, for offset pinning.
struct Scope<'a> {
    f: &'a LexFile,
    body: &'a [Tok],
    line: u32,
    name: &'a str,
}

impl<'a> Scope<'a> {
    fn new(f: &'a LexFile, name: &'a str) -> Option<Scope<'a>> {
        let (lo, hi) = f.fn_body(name, 0)?;
        Some(Scope { f, body: &f.toks[lo..hi], line: f.toks[lo].line, name })
    }

    /// Require `want` occurrences of the token pattern in the body
    /// (`exact` pins the count, otherwise it is a floor).
    fn pin(&self, out: &mut Vec<Finding>, pat: &[&str], want: usize, exact: bool, what: &str) {
        let got = seq_count(self.body, pat);
        let ok = if exact { got == want } else { got >= want };
        if !ok {
            let mode = if exact { "exactly" } else { "at least" };
            err(
                out,
                &self.f.rel,
                self.line,
                "frame-offset",
                format!(
                    "fn {}: wanted {mode} {want} of `{}` ({what}), found {got} — the frame \
                     byte layout drifted from the documented offsets",
                    self.name,
                    pat.join(" ")
                ),
            );
        }
    }
}

fn check_registry(out: &mut Vec<Finding>, proto: &LexFile) {
    let consts = collect_consts(proto);
    for &(name, want) in &WIRE_PINNED {
        match consts.get(name) {
            None => err(
                out,
                &proto.rel,
                0,
                "registry-pin",
                format!("wire constant `{name}` is missing or unparsable — it is pinned at {want}"),
            ),
            Some(&(got, line)) if got != want => err(
                out,
                &proto.rel,
                line,
                "registry-pin",
                format!(
                    "wire constant `{name}` is pinned at {want}, found {got} — codes are \
                     append-only and never renumbered"
                ),
            ),
            Some(_) => {}
        }
    }
    for (name, &(v, line)) in &consts {
        if WIRE_PINNED.iter().any(|&(p, _)| p == name) {
            continue;
        }
        if let Some(fam) = FAMILIES.iter().find(|p| name.starts_with(*p)) {
            err(
                out,
                &proto.rel,
                line,
                "registry-append",
                format!(
                    "new `{fam}` wire constant `{name}` = {v} is not registered — append it to \
                     WIRE_PINNED in analyze/protocol.rs after checking its family for collisions"
                ),
            );
        }
    }
    for fam in FAMILIES {
        let mut seen: Vec<(&str, i128)> = Vec::new();
        for (name, &(v, line)) in &consts {
            if !name.starts_with(fam) {
                continue;
            }
            if let Some(&(other, _)) = seen.iter().find(|&&(_, ov)| ov == v) {
                err(
                    out,
                    &proto.rel,
                    line,
                    "registry-collision",
                    format!("`{name}` = {v} collides with `{other}` in the {fam} family"),
                );
            }
            seen.push((name, v));
        }
    }
}

fn check_next_frame(out: &mut Vec<Finding>, proto: &LexFile) {
    let Some(s) = Scope::new(proto, "next_frame") else {
        let msg = "fn next_frame not found — the decoder moved; update the analyzer".to_string();
        err(out, &proto.rel, 0, "frame-offset", msg);
        return;
    };
    s.pin(out, &["u32_at", "(", "0", ")"], 1, false, "first header word");
    s.pin(out, &["u32_at", "(", "4", ")"], 2, false, "control code / request d at byte 4");
    s.pin(out, &["u32_at", "(", "8", ")"], 1, false, "request tier word at byte 8");
    s.pin(out, &["u64_at", "(", "12", ")"], 1, true, "request trace_id at bytes 12..20");
    s.pin(out, &["u64_at", "(", "8", ")"], 1, true, "cancel trace_id at bytes 8..16");
    s.pin(out, &["pending", "(", ")", "<", "8"], 1, false, "control header is 8 bytes");
    s.pin(out, &["pending", "(", ")", "<", "16"], 1, false, "cancel header is 16 bytes");
    s.pin(out, &["pending", "(", ")", "<", "20"], 2, false, "request header is 20 bytes");
    s.pin(out, &["consume", "(", "8", ")"], 1, false, "control frame consume");
    s.pin(out, &["consume", "(", "16", ")"], 1, false, "cancel frame consume");
    s.pin(out, &["consume", "(", "20"], 3, false, "request header consume");
    s.pin(out, &["STREAM_FLAG"], 1, false, "stream bit masked out of the tier word");
}

fn check_loadgen(out: &mut Vec<Finding>, lg: &LexFile) {
    let Some(s) = Scope::new(lg, "next_event") else {
        let msg = "fn next_event not found — loadgen's decoder moved; update the analyzer";
        err(out, &lg.rel, 0, "frame-offset", msg.to_string());
        return;
    };
    s.pin(out, &["u32_at", "(", "0", ")"], 1, false, "first header word");
    s.pin(out, &["u32_at", "(", "4", ")"], 3, false, "kind / code / cols word at byte 4");
    s.pin(out, &["u64_at", "(", "8", ")"], 2, true, "trace_id at bytes 8..16 (stream + reply)");
    s.pin(out, &["u32_at", "(", "16", ")"], 2, false, "stream rows / failure len at byte 16");
    s.pin(out, &["u32_at", "(", "20", ")"], 1, false, "stream cols at byte 20");
    s.pin(out, &["have", "(", "16", ")"], 1, false, "classic header is 16 bytes");
    s.pin(out, &["have", "(", "28", ")"], 1, false, "stream data header is 28 bytes");
    s.pin(out, &["consume", "(", "20", ")"], 2, false, "shed / stream-end consume");
    if lg.count_seq(&["start", "+", "12", "..", "start", "+", "20"]) != 1 {
        err(
            out,
            &lg.rel,
            0,
            "frame-offset",
            "open-loop sender no longer stamps trace_id at request bytes 12..20".to_string(),
        );
    }
}

fn int_text(t: &Tok) -> String {
    t.val.map(|v| v.to_string()).unwrap_or_else(|| t.text.clone())
}

/// Index of the `(` matching the `)` at `close`, scanning backwards.
fn matching_open(body: &[Tok], close: usize) -> Option<usize> {
    let mut depth = 0usize;
    let mut j = close;
    loop {
        if body[j].is(")") {
            depth += 1;
        } else if body[j].is("(") {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
        j = j.checked_sub(1)?;
    }
}

/// The field a `.to_le_bytes()` call serializes, walking back from the
/// token before the dot: through method-call chains (`tier.as_u32()` →
/// `tier`), into one parenthesized cast group (`(rows as u32)` →
/// `rows`), or the bare identifier / integer literal itself.
fn le_source(body: &[Tok], start: usize) -> Option<String> {
    let mut j = start;
    loop {
        let t = &body[j];
        if t.is(")") {
            let open = matching_open(body, j)?;
            if open > 0 && body[open - 1].kind == TokKind::Ident {
                // a call `name(..)`: keep walking its receiver chain
                j = open - 1;
                if j >= 2 && body[j - 1].is(".") {
                    j -= 2;
                    continue;
                }
                return Some(body[j].text.clone());
            }
            // a parenthesized expression: first ident/int inside
            return body[open + 1..j].iter().find_map(|t| match t.kind {
                TokKind::Ident => Some(t.text.clone()),
                TokKind::Int => Some(int_text(t)),
                _ => None,
            });
        }
        return match t.kind {
            TokKind::Ident => Some(t.text.clone()),
            TokKind::Int => Some(int_text(t)),
            _ => None,
        };
    }
}

/// Source-order list of fields serialized by `.to_le_bytes()` calls.
fn le_fields(body: &[Tok]) -> Vec<String> {
    let mut out = Vec::new();
    for k in 0..body.len() {
        if body[k].is_ident("to_le_bytes") && k >= 2 && body[k - 1].is(".") {
            if let Some(src) = le_source(body, k - 2) {
                out.push(src);
            }
        }
    }
    out
}

fn check_encoder(out: &mut Vec<Finding>, proto: &LexFile, name: &str, want: &[&str]) {
    let Some(s) = Scope::new(proto, name) else {
        err(out, &proto.rel, 0, "encoder-layout", format!("fn {name} not found"));
        return;
    };
    let got = le_fields(s.body);
    let got_refs: Vec<&str> = got.iter().map(String::as_str).collect();
    if got_refs != want {
        err(
            out,
            &proto.rel,
            s.line,
            "encoder-layout",
            format!(
                "fn {name}: `to_le_bytes` field order is [{}] but the documented frame order \
                 is [{}] — encoder and frame doc drifted apart",
                got.join(", "),
                want.join(", ")
            ),
        );
    }
}

fn check_encoders(out: &mut Vec<Finding>, proto: &LexFile) {
    check_encoder(out, proto, "encode_request", &["n", "d", "tw", "trace_id", "v"]);
    check_encoder(out, proto, "encode_response_rows", &["rows", "cols", "trace_id", "v"]);
    check_encoder(out, proto, "encode_error", &["0", "code", "trace_id"]);
    check_encoder(out, proto, "encode_failure", &["bytes"]);
    check_encoder(out, proto, "encode_control", &["CONTROL_SENTINEL", "code"]);
    check_encoder(out, proto, "encode_control_reply", &["bytes"]);
    check_encoder(out, proto, "encode_cancel", &["STREAM_SENTINEL", "0", "trace_id"]);
    check_encoder(
        out,
        proto,
        "encode_stream_data",
        &["STREAM_SENTINEL", "kind", "trace_id", "rows", "cols", "terms", "v"],
    );
    check_encoder(
        out,
        proto,
        "encode_stream_end",
        &["STREAM_SENTINEL", "STREAM_END", "trace_id", "terms"],
    );
    // the error-frame wrappers must delegate with their pinned code
    for (name, code) in [("encode_shed", "CODE_SHED"), ("encode_failure", "CODE_BATCH_FAILED")] {
        let Some(s) = Scope::new(proto, name) else {
            err(out, &proto.rel, 0, "encoder-layout", format!("fn {name} not found"));
            continue;
        };
        if seq_count(s.body, &["encode_error", "(", code]) == 0 {
            err(
                out,
                &proto.rel,
                s.line,
                "encoder-layout",
                format!("fn {name}: expected delegation to `encode_error({code}, ..)`"),
            );
        }
    }
}

/// Blocking-read signature of a fn body: the `read_u32` / `read_u64` /
/// `read_f32s` calls in source order, shortened to their word kinds.
fn read_signature(body: &[Tok]) -> String {
    let mut parts: Vec<&str> = Vec::new();
    for t in body {
        match t.text.as_str() {
            "read_u32" => parts.push("u32"),
            "read_u64" => parts.push("u64"),
            "read_f32s" => parts.push("f32s"),
            _ => {}
        }
    }
    parts.join(" ")
}

fn check_client(out: &mut Vec<Finding>, proto: &LexFile, name: &str, want: &str) {
    let Some(s) = Scope::new(proto, name) else {
        err(out, &proto.rel, 0, "client-layout", format!("fn {name} not found"));
        return;
    };
    let got = read_signature(s.body);
    if got != want {
        err(
            out,
            &proto.rel,
            s.line,
            "client-layout",
            format!(
                "fn {name}: blocking read sequence `{got}` does not match the frame layout \
                 `{want}` — client and decoder drifted apart"
            ),
        );
    }
}

fn check_clients(out: &mut Vec<Finding>, proto: &LexFile) {
    check_client(out, proto, "read_reply", "u32 u32 u64 f32s");
    check_client(out, proto, "recv", "u32 u32 u64 u32 u32 u32 u32 f32s u32 u64 f32s");
}

/// Parse `Name = <int>` variants of the enum whose `enum` keyword sits
/// at token index `at`.
fn enum_discriminants(f: &LexFile, at: usize) -> Option<Vec<(String, i128, u32)>> {
    let open = (at + 2..f.toks.len()).find(|&k| f.toks[k].is("{"))?;
    let close = f.matching_brace(open)?;
    let mut vars = Vec::new();
    let mut i = open + 1;
    while i + 2 < close {
        if f.toks[i].kind == TokKind::Ident
            && f.toks[i + 1].is("=")
            && f.toks[i + 2].kind == TokKind::Int
        {
            vars.push((f.toks[i].text.clone(), f.toks[i + 2].val.unwrap_or(-1), f.toks[i].line));
            i += 3;
        } else {
            i += 1;
        }
    }
    Some(vars)
}

fn check_spankind(out: &mut Vec<Finding>, rec: &LexFile) {
    let Some(at) = rec.find_seq(0, &["enum", "SpanKind"]) else {
        let msg = "enum SpanKind not found — the packed wire numbering is unchecked".to_string();
        err(out, &rec.rel, 0, "spankind-append", msg);
        return;
    };
    let Some(vars) = enum_discriminants(rec, at) else {
        err(out, &rec.rel, 0, "spankind-append", "cannot parse SpanKind variants".to_string());
        return;
    };
    for (idx, &(name, disc)) in SPANKIND_PINNED.iter().enumerate() {
        match vars.get(idx) {
            Some((got, gd, _)) if got == name && *gd == disc => {}
            Some((got, gd, line)) => err(
                out,
                &rec.rel,
                *line,
                "spankind-append",
                format!(
                    "SpanKind[{idx}] is pinned as `{name} = {disc}`, found `{got} = {gd}` — \
                     the packed wire numbering is append-only; never renumber or reorder"
                ),
            ),
            None => err(
                out,
                &rec.rel,
                0,
                "spankind-append",
                format!("pinned SpanKind variant `{name} = {disc}` is missing"),
            ),
        }
    }
    for (idx, (name, disc, line)) in vars.iter().enumerate().skip(SPANKIND_PINNED.len()) {
        if *disc != idx as i128 {
            err(
                out,
                &rec.rel,
                *line,
                "spankind-append",
                format!(
                    "appended SpanKind variant `{name}` must take the next discriminant \
                     ({idx}), found {disc} — then pin it in analyze/protocol.rs"
                ),
            );
        }
    }
}

fn check_layout_local(out: &mut Vec<Finding>, set: &SourceSet) {
    for f in &set.files {
        if !f.rel.starts_with("serve/") || f.rel == PROTOCOL_FILE || f.rel == LOADGEN_FILE {
            continue;
        }
        for t in &f.toks {
            if t.is_ident("to_le_bytes") || t.is_ident("from_le_bytes") {
                err(
                    out,
                    &f.rel,
                    t.line,
                    "layout-local",
                    "byte-layout call in the serving plane outside serve/protocol.rs — frame \
                     layouts live in the codec (loadgen's decoder is the one sanctioned copy)"
                        .to_string(),
                );
            }
        }
    }
}

/// Run pass 3 over the set.
pub fn run(set: &SourceSet) -> Vec<Finding> {
    let mut out = Vec::new();
    match set.get(PROTOCOL_FILE) {
        Some(proto) => {
            check_registry(&mut out, proto);
            check_next_frame(&mut out, proto);
            check_encoders(&mut out, proto);
            check_clients(&mut out, proto);
        }
        None => out.push(missing_file(PASS, PROTOCOL_FILE)),
    }
    match set.get(LOADGEN_FILE) {
        Some(lg) => check_loadgen(&mut out, lg),
        None => out.push(missing_file(PASS, LOADGEN_FILE)),
    }
    match set.get(RECORDER_FILE) {
        Some(rec) => check_spankind(&mut out, rec),
        None => out.push(missing_file(PASS, RECORDER_FILE)),
    }
    check_layout_local(&mut out, set);
    out
}
