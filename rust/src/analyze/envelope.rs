//! Pass 1 — the integer-overflow envelope proof, machine-checked.
//!
//! The kernel plane's exactness rests on an arithmetic chain that
//! lives in comments: `INT_DOT_MAX_ABS` bounds scalar plane values so
//! a 256-element i32 partial cannot overflow; `PACK_MAX_ABS` bounds
//! i8 plane values so `maddubs` pair sums cannot saturate i16 and
//! `FOLD_CHUNKS` 32-element chunks cannot overflow an i32 lane before
//! the i64 fold. This pass re-parses those constants and accumulator
//! shapes from source and re-derives every inequality with i128
//! arithmetic — widening a constant (or shrinking a fold cadence)
//! without re-establishing the proof is a finding, not a comment
//! drift.

use super::lexer::{collect_consts, seq_count, seq_find, LexFile, Tok};
use super::{missing_file, Finding, Level, SourceSet};

const PASS: &str = "envelope";

pub const GEMM_FILE: &str = "xint/gemm.rs";
pub const PACK_FILE: &str = "xint/kernel/pack.rs";
pub const MICRO_FILE: &str = "xint/kernel/micro.rs";

/// What `maddubs` pairs: two adjacent i8 products per i16 lane.
const MADDUBS_PAIR: i128 = 2;
/// What `madd_epi16` folds: two i16 pair sums per i32 lane.
const MADD_LANE_PAIRS: i128 = 2;

struct Ctx {
    findings: Vec<Finding>,
}

impl Ctx {
    fn err(&mut self, file: &str, line: u32, rule: &'static str, message: String) {
        self.findings.push(Finding {
            file: file.to_string(),
            line,
            pass: PASS,
            rule,
            level: Level::Error,
            message,
        });
    }
}

/// Look up a const parsed from `file`, or emit a finding.
fn want_const(
    ctx: &mut Ctx,
    file: &LexFile,
    consts: &std::collections::BTreeMap<String, (i128, u32)>,
    name: &str,
) -> Option<(i128, u32)> {
    match consts.get(name) {
        Some(&v) => Some(v),
        None => {
            ctx.err(
                &file.rel,
                0,
                "const-parse",
                format!("could not parse `const {name}` — the envelope proof needs its value"),
            );
            None
        }
    }
}

/// The `const CHUNK` inside a named fn body.
fn fn_chunk(ctx: &mut Ctx, file: &LexFile, fn_name: &str) -> Option<(i128, u32)> {
    let Some((lo, hi)) = file.fn_body(fn_name, 0) else {
        ctx.err(
            &file.rel,
            0,
            "fn-shape",
            format!("fn {fn_name} not found — the envelope proof checks its accumulator shape"),
        );
        return None;
    };
    let body = &file.toks[lo..hi];
    let parsed = seq_find(body, 0, &["const", "CHUNK"]).and_then(|at| {
        let eq = seq_find(body, at, &["="])?;
        let semi = seq_find(body, eq, &[";"])?;
        super::lexer::eval_const(&body[eq + 1..semi], &|_| None).map(|v| (v, body[at].line))
    });
    if parsed.is_none() {
        ctx.err(
            &file.rel,
            file.toks[lo].line,
            "const-parse",
            format!("fn {fn_name}: could not parse `const CHUNK` — the chunk bound needs it"),
        );
    }
    parsed
}

/// Structural check: the fn folds an `i32` partial into an `i64`
/// accumulator (the shape the chunk bound licenses).
fn check_fold_shape(ctx: &mut Ctx, file: &LexFile, fn_name: &str) {
    let Some((lo, hi)) = file.fn_body(fn_name, 0) else {
        ctx.err(&file.rel, 0, "fn-shape", format!("fn {fn_name} not found"));
        return;
    };
    let body = &file.toks[lo..hi];
    let line = file.toks[lo].line;
    if seq_find(body, 0, &["partial", ":", "i32"]).is_none() {
        ctx.err(
            &file.rel,
            line,
            "fn-shape",
            format!(
                "fn {fn_name}: expected an `i32` chunk partial (`partial: i32`) — the chunk \
                 bound is proved against a 32-bit accumulator"
            ),
        );
    }
    if seq_find(body, 0, &["acc", ":", "i64"]).is_none() {
        ctx.err(
            &file.rel,
            line,
            "fn-shape",
            format!(
                "fn {fn_name}: expected the i64 fold accumulator (`acc: i64`) — without it the \
                 per-chunk bound does not compose across chunks"
            ),
        );
    }
}

/// Structural check: the fn gates both operands through the shared
/// envelope helper (satellite of the same proof: one assertion site).
fn check_envelope_gate(ctx: &mut Ctx, file: &LexFile, fn_name: &str, bound: &str) {
    let Some((lo, hi)) = file.fn_body(fn_name, 0) else {
        return; // fn-shape already reported
    };
    let body = &file.toks[lo..hi];
    if seq_find(body, 0, &["debug_assert_envelope"]).is_none()
        || seq_find(body, 0, &[bound]).is_none()
    {
        ctx.err(
            &file.rel,
            file.toks[lo].line,
            "envelope-gate",
            format!(
                "fn {fn_name}: expected a `debug_assert_envelope(.., {bound}, ..)` gate — the \
                 overflow proof assumes inputs were checked against this bound"
            ),
        );
    }
}

/// AVX2 micro-kernel structure: the fold trigger and the i64 horizontal
/// sum must both be present, or `FOLD_CHUNKS` bounds nothing.
fn check_avx2_fold(ctx: &mut Ctx, file: &LexFile, fn_name: &str) {
    let Some((lo, hi)) = file.fn_body(fn_name, 0) else {
        ctx.err(&file.rel, 0, "fn-shape", format!("fn {fn_name} not found"));
        return;
    };
    let body = &file.toks[lo..hi];
    let line = file.toks[lo].line;
    if seq_find(body, 0, &["folds", "==", "FOLD_CHUNKS"]).is_none() {
        ctx.err(
            &file.rel,
            line,
            "fold-cadence",
            format!(
                "fn {fn_name}: the `folds == FOLD_CHUNKS` i64 fold trigger is missing — i32 \
                 lanes would grow unbounded"
            ),
        );
    }
    if seq_find(body, 0, &["hsum_i32x8"]).is_none() {
        ctx.err(
            &file.rel,
            line,
            "fold-cadence",
            format!("fn {fn_name}: no `hsum_i32x8` fold into the i64 total"),
        );
    }
}

fn prove(ctx: &mut Ctx, ok: bool, file: &str, line: u32, rule: &'static str, claim: String) {
    if !ok {
        ctx.err(file, line, rule, claim);
    }
}

/// Run pass 1 over the set.
pub fn run(set: &SourceSet) -> Vec<Finding> {
    let mut ctx = Ctx { findings: Vec::new() };

    let (Some(gemm), Some(pack), Some(micro)) =
        (set.get(GEMM_FILE), set.get(PACK_FILE), set.get(MICRO_FILE))
    else {
        for rel in [GEMM_FILE, PACK_FILE, MICRO_FILE] {
            if set.get(rel).is_none() {
                ctx.findings.push(missing_file(PASS, rel));
            }
        }
        return ctx.findings;
    };

    let gemm_consts = collect_consts(gemm);
    let pack_consts = collect_consts(pack);
    let micro_consts = collect_consts(micro);

    let int_dot = want_const(&mut ctx, gemm, &gemm_consts, "INT_DOT_MAX_ABS");
    let pack_max = want_const(&mut ctx, pack, &pack_consts, "PACK_MAX_ABS");
    let fold_chunks = want_const(&mut ctx, micro, &micro_consts, "FOLD_CHUNKS");
    let gemm_chunk = fn_chunk(&mut ctx, gemm, "int_dot");
    let micro_chunk = fn_chunk(&mut ctx, micro, "dot_i8_portable");

    // --- the arithmetic chain, re-derived in i128 ---------------------
    if let (Some((d, _)), Some((p, pl))) = (int_dot, pack_max) {
        // maddubs computes a·b as |a| · sign_a(b); sign_epi8(-128)
        // wraps, so both operands must stay within ±127
        prove(
            &mut ctx,
            p <= 127,
            PACK_FILE,
            pl,
            "pack-sign-wrap",
            format!("PACK_MAX_ABS = {p} > 127: sign_epi8(±128) wraps, the maddubs identity breaks"),
        );
        // each maddubs i16 lane sums MADDUBS_PAIR products of |v| ≤ p
        prove(
            &mut ctx,
            MADDUBS_PAIR * p * p < (1 << 15),
            PACK_FILE,
            pl,
            "pack-i16-saturate",
            format!(
                "maddubs pair sum bound {MADDUBS_PAIR}·{p}² = {} ≥ 2^15: i16 lanes saturate and \
                 the dot is no longer exact",
                MADDUBS_PAIR * p * p
            ),
        );
        // the i8 fast-path envelope must be strictly inside the scalar
        // envelope (planes that fail packing fall back to the scalar
        // kernel, which is only exact up to INT_DOT_MAX_ABS)
        prove(
            &mut ctx,
            p < d,
            PACK_FILE,
            pl,
            "pack-inside-scalar",
            format!("PACK_MAX_ABS = {p} must be strictly tighter than INT_DOT_MAX_ABS = {d}"),
        );
    }
    if let (Some((d, dl)), Some((c, _))) = (int_dot, gemm_chunk) {
        // a CHUNK-element partial of |x·y| ≤ d² products in an i32
        prove(
            &mut ctx,
            d * d * c <= i32::MAX as i128,
            GEMM_FILE,
            dl,
            "scalar-chunk-overflow",
            format!(
                "int_dot partial bound INT_DOT_MAX_ABS²·CHUNK = {d}²·{c} = {} exceeds i32::MAX \
                 ({}) — the chunked i32 accumulation can overflow",
                d * d * c,
                i32::MAX
            ),
        );
    }
    if let (Some((p, pl)), Some((c, _))) = (pack_max, micro_chunk) {
        prove(
            &mut ctx,
            p * p * c <= i32::MAX as i128,
            MICRO_FILE,
            pl,
            "portable-chunk-overflow",
            format!(
                "dot_i8_portable partial bound PACK_MAX_ABS²·CHUNK = {p}²·{c} = {} exceeds \
                 i32::MAX — the portable fold cadence is too slow",
                p * p * c
            ),
        );
    }
    if let (Some((p, _)), Some((f, fl))) = (pack_max, fold_chunks) {
        // per 32-element chunk each i32 lane gains MADD_LANE_PAIRS pair
        // sums, each ≤ MADDUBS_PAIR·p²; FOLD_CHUNKS chunks accumulate
        // before the i64 fold
        let per_chunk = MADD_LANE_PAIRS * MADDUBS_PAIR * p * p;
        prove(
            &mut ctx,
            per_chunk * f <= i32::MAX as i128,
            MICRO_FILE,
            fl,
            "avx2-fold-overflow",
            format!(
                "AVX2 lane bound {MADD_LANE_PAIRS}·{MADDUBS_PAIR}·PACK_MAX_ABS²·FOLD_CHUNKS = \
                 {per_chunk}·{f} = {} exceeds i32::MAX ({}) — i32 lanes overflow before the i64 \
                 fold",
                per_chunk * f,
                i32::MAX
            ),
        );
    }

    // --- structural shape of the proofs' subjects ---------------------
    check_fold_shape(&mut ctx, gemm, "int_dot");
    check_fold_shape(&mut ctx, micro, "dot_i8_portable");
    check_envelope_gate(&mut ctx, gemm, "int_dot", "INT_DOT_MAX_ABS");
    check_envelope_gate(&mut ctx, pack, "pack", "INT_DOT_MAX_ABS");
    check_avx2_fold(&mut ctx, micro, "dot_avx2");
    check_avx2_fold(&mut ctx, micro, "dot4_avx2");

    // pack() must still reject values above PACK_MAX_ABS (the scalar
    // fallback gate) — the return-None comparison has to survive
    if let Some((lo, hi)) = pack.fn_body("pack", 0) {
        let body: &[Tok] = &pack.toks[lo..hi];
        if seq_count(body, &["PACK_MAX_ABS"]) == 0 {
            ctx.err(
                PACK_FILE,
                pack.toks[lo].line,
                "pack-reject-gate",
                "PackedPlane::pack no longer compares against PACK_MAX_ABS — out-of-envelope \
                 planes would be packed instead of falling back to the scalar kernel"
                    .to_string(),
            );
        }
    }

    ctx.findings
}
