//! Pass 2 — acquire/release pairing over the `util::sync` shim.
//!
//! PR 7's `// ordering:` proximity lint could see that an `Ordering`
//! had a rationale comment, but not whether a Release store actually
//! has a paired Acquire load — the exact seqlock tearing bug that PR
//! fixed by hand. This pass collects every atomic operation site,
//! keyed by the receiver's field name within a file, classifies
//! publish (Release-or-stronger store/RMW) vs consume
//! (Acquire-or-stronger load/RMW) orderings, and checks the pairing:
//!
//! * `unpaired-release` — a field is published with Release but never
//!   read with Acquire (the barrier orders nothing).
//! * `unpaired-acquire` — a field is read with Acquire but never
//!   published (the read synchronizes with no store).
//! * `relaxed-load-of-published` — a published field is also read
//!   Relaxed somewhere: that load can observe torn/stale protocol
//!   state (the PR 7 bug class).
//! * `relaxed-store-to-published` — a published field is also written
//!   Relaxed: readers pairing with the Release store may still miss
//!   this write.
//!
//! Grouping is per `(file, field)` — every pairing in this crate is
//! file-local (the recorder seqlock, the reactor wake latch, the
//! server stop flag), and a cross-file pair would rightly demand a
//! refactor or an explicit rule update here.
//!
//! The pass also owns the `// ordering:` rationale rule delegated
//! from `scripts/check_invariants.py`: every `Ordering::{Relaxed,
//! Acquire,Release,AcqRel,SeqCst}` token needs a `// ordering:`
//! comment on its line or within the 8 lines above. Token-level
//! matching means string literals and `cmp::Ordering` values never
//! trip it.

use super::lexer::{LexFile, TokKind};
use super::{Finding, Level, SourceSet};
use std::collections::BTreeMap;

const PASS: &str = "atomics";

/// Same window as the python rule this pass replaces.
const ORDERING_WINDOW: u32 = 8;
const ORDERING_COMMENT: &str = "// ordering:";

const MEM_ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Atomic receiver methods and whether they store, load, or both.
const METHODS: [(&str, bool, bool); 14] = [
    ("load", false, true),
    ("store", true, false),
    ("swap", true, true),
    ("fetch_add", true, true),
    ("fetch_sub", true, true),
    ("fetch_and", true, true),
    ("fetch_or", true, true),
    ("fetch_xor", true, true),
    ("fetch_nand", true, true),
    ("fetch_min", true, true),
    ("fetch_max", true, true),
    ("fetch_update", true, true),
    ("compare_exchange", true, true),
    ("compare_exchange_weak", true, true),
];

#[derive(Clone, Debug)]
struct Site {
    line: u32,
    /// ordering applied to the store side (RMW: success/set ordering)
    store_ord: Option<String>,
    /// strongest ordering visible to the load side
    load_ord: Option<String>,
}

fn is_publish(ord: &str) -> bool {
    matches!(ord, "Release" | "AcqRel" | "SeqCst")
}

fn is_consume(ord: &str) -> bool {
    matches!(ord, "Acquire" | "AcqRel" | "SeqCst")
}

/// The receiver field name of a `.method(...)` call: walking backwards
/// from the `.`, skip one balanced `[...]`/`(...)` group, then take
/// the identifier (or tuple index) — `self.pressure[tier.idx()].load`
/// keys as `pressure`, `width_cap().store` as `width_cap`,
/// `self.0.swap` as `0`.
fn receiver_key(f: &LexFile, dot: usize) -> Option<String> {
    let mut i = dot.checked_sub(1)?;
    loop {
        let t = &f.toks[i];
        if t.is("]") || t.is(")") {
            // skip the balanced group backwards
            let (open, close) = if t.is("]") { ("[", "]") } else { ("(", ")") };
            let mut depth = 0usize;
            loop {
                if f.toks[i].is(close) {
                    depth += 1;
                } else if f.toks[i].is(open) {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                i = i.checked_sub(1)?;
            }
            i = i.checked_sub(1)?;
            continue;
        }
        if t.kind == TokKind::Ident || t.kind == TokKind::Int {
            return Some(t.text.clone());
        }
        return None;
    }
}

/// Memory orderings named inside the token range (literal
/// `Ordering::X` mentions, in order).
fn orderings_in(f: &LexFile, lo: usize, hi: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = lo;
    while i + 2 < hi {
        if f.toks[i].is_ident("Ordering")
            && f.toks[i + 1].is("::")
            && MEM_ORDERINGS.contains(&f.toks[i + 2].text.as_str())
        {
            out.push(f.toks[i + 2].text.clone());
            i += 3;
        } else {
            i += 1;
        }
    }
    out
}

fn collect_sites(f: &LexFile) -> BTreeMap<String, Vec<Site>> {
    let mut groups: BTreeMap<String, Vec<Site>> = BTreeMap::new();
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let Some(&(_, stores, loads)) = METHODS.iter().find(|(m, _, _)| t.is_ident(m)) else {
            continue;
        };
        // shape: `. method (` — a free fn like mem::swap(a, b) is not
        // an atomic receiver call
        if i == 0 || !f.toks[i - 1].is(".") || !f.toks.get(i + 1).is_some_and(|n| n.is("(")) {
            continue;
        }
        let Some(close) = f.matching_group(i + 1) else { continue };
        let ords = orderings_in(f, i + 2, close);
        if ords.is_empty() {
            continue; // not an atomic call (Vec::swap, HashMap::get ...)
        }
        let Some(key) = receiver_key(f, i - 1) else { continue };
        let strongest_load = ords.iter().find(|o| is_consume(o)).or_else(|| ords.first()).cloned();
        groups.entry(key).or_default().push(Site {
            line: t.line,
            store_ord: stores.then(|| ords[0].clone()),
            load_ord: loads.then_some(strongest_load).flatten(),
        });
    }
    groups
}

fn err(out: &mut Vec<Finding>, f: &LexFile, line: u32, rule: &'static str, message: String) {
    out.push(Finding { file: f.rel.clone(), line, pass: PASS, rule, level: Level::Error, message });
}

fn check_pairing(out: &mut Vec<Finding>, f: &LexFile) {
    for (key, sites) in collect_sites(f) {
        let publishes: Vec<&Site> =
            sites.iter().filter(|s| s.store_ord.as_deref().is_some_and(is_publish)).collect();
        let consumes: Vec<&Site> =
            sites.iter().filter(|s| s.load_ord.as_deref().is_some_and(is_consume)).collect();
        let relaxed_loads: Vec<&Site> =
            sites.iter().filter(|s| s.load_ord.as_deref() == Some("Relaxed")).collect();
        let relaxed_stores: Vec<&Site> =
            sites.iter().filter(|s| s.store_ord.as_deref() == Some("Relaxed")).collect();

        if !publishes.is_empty() && consumes.is_empty() {
            err(
                out,
                f,
                publishes[0].line,
                "unpaired-release",
                format!(
                    "field `{key}` is published with Release-or-stronger but has no \
                     Acquire-side reader in this file — the release barrier pairs with nothing"
                ),
            );
        }
        if !consumes.is_empty() && publishes.is_empty() {
            err(
                out,
                f,
                consumes[0].line,
                "unpaired-acquire",
                format!(
                    "field `{key}` is read with Acquire-or-stronger but never published with \
                     Release-or-stronger in this file — the acquire synchronizes with no store"
                ),
            );
        }
        if !publishes.is_empty() {
            for s in relaxed_loads {
                err(
                    out,
                    f,
                    s.line,
                    "relaxed-load-of-published",
                    format!(
                        "Relaxed load of `{key}`, a field published with Release — this read \
                         can observe torn protocol state (the PR 7 seqlock bug class)"
                    ),
                );
            }
            for s in relaxed_stores {
                err(
                    out,
                    f,
                    s.line,
                    "relaxed-store-to-published",
                    format!(
                        "Relaxed store to `{key}`, a field also published with Release — \
                         readers pairing with the Release store may miss this write"
                    ),
                );
            }
        }
    }
}

/// The delegated `// ordering:` rationale rule (was
/// `check_invariants.py` rule `ordering-comment`).
fn check_ordering_rationale(out: &mut Vec<Finding>, f: &LexFile) {
    let mut i = 0usize;
    while i + 2 < f.toks.len() {
        if f.toks[i].is_ident("Ordering")
            && f.toks[i + 1].is("::")
            && MEM_ORDERINGS.contains(&f.toks[i + 2].text.as_str())
        {
            let line = f.toks[i + 2].line;
            if !f.comment_near(line, ORDERING_WINDOW, ORDERING_COMMENT) {
                err(
                    out,
                    f,
                    line,
                    "ordering-comment",
                    format!(
                        "memory-ordering choice Ordering::{} without a '{ORDERING_COMMENT}' \
                         rationale within {ORDERING_WINDOW} lines",
                        f.toks[i + 2].text
                    ),
                );
            }
            i += 3;
        } else {
            i += 1;
        }
    }
}

/// Run pass 2 over every file in the set.
pub fn run(set: &SourceSet) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &set.files {
        check_pairing(&mut out, f);
        check_ordering_rationale(&mut out, f);
    }
    out
}
