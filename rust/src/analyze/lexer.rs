//! Hand-rolled Rust-source lexer for the [`crate::analyze`] passes.
//!
//! Deliberately tiny (no external deps, matching the vendored-loom
//! pattern): it produces a flat token stream plus a separate comment
//! list, both carrying 1-based line numbers. Matching over *tokens*
//! rather than raw lines is what lets the passes ignore string
//! literals and comments — the analyzer's own embedded test corpus
//! would otherwise trip every rule it checks.
//!
//! The stream is cut at the file's trailing test region (everything
//! from the first `#[cfg(test)]` / `#[cfg(all(test, ...))]` line to
//! EOF), the same convention `scripts/check_invariants.py` uses.

/// Token class. Punctuation is mostly single-char; the only fused
/// operators are the ones passes match on (`::`, `<<`, `==`, `..`,
/// `->`, `=>`) so `1 << 11` and `Ordering::Relaxed` stay recognizable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Int,
    Float,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One lexed token.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    /// Source text (for `Str` the raw literal including quotes).
    pub text: String,
    /// Parsed value for `Int` tokens (suffix and `_` stripped).
    pub val: Option<i128>,
    /// 1-based source line.
    pub line: u32,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }
}

/// One `//` or `/* */` comment with its starting line.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    /// Comment text including the delimiter (`// ...`).
    pub text: String,
}

/// A lexed source file, already cut at the trailing test region.
pub struct LexFile {
    /// Path relative to the source root, `/`-separated.
    pub rel: String,
    /// Non-test tokens in source order.
    pub toks: Vec<Tok>,
    /// Non-test comments in source order.
    pub comments: Vec<Comment>,
    /// 1-based line where the test region starts (`u32::MAX` if none).
    pub cut_line: u32,
}

impl LexFile {
    pub fn new(rel: &str, text: &str) -> LexFile {
        let cut_line = test_cut_line(text);
        let (mut toks, mut comments) = lex(text);
        toks.retain(|t| t.line < cut_line);
        comments.retain(|c| c.line < cut_line);
        LexFile { rel: rel.to_string(), toks, comments, cut_line }
    }

    /// Find the next occurrence of a token subsequence (each pattern
    /// element matched against `Tok::text`) at or after `from`;
    /// returns the index of the first matched token.
    pub fn find_seq(&self, from: usize, pat: &[&str]) -> Option<usize> {
        seq_find(&self.toks, from, pat)
    }

    /// Count non-overlapping occurrences of a token subsequence.
    pub fn count_seq(&self, pat: &[&str]) -> usize {
        seq_count(&self.toks, pat)
    }

    /// Token index range of the body (`{ ... }`, exclusive of the
    /// braces) of the first `fn name` at or after `from`.
    pub fn fn_body(&self, name: &str, from: usize) -> Option<(usize, usize)> {
        let mut i = from;
        loop {
            let at = self.find_seq(i, &["fn", name])?;
            // guard against a longer identifier prefix match is not
            // needed (token equality is exact); find the opening brace
            let open = (at + 2..self.toks.len()).find(|&k| {
                self.toks[k].is("{") || self.toks[k].is(";")
            })?;
            if self.toks[open].is(";") {
                // trait method declaration without a body — keep looking
                i = at + 2;
                continue;
            }
            return self.matching_brace(open).map(|close| (open + 1, close));
        }
    }

    /// Index of the `}` matching the `{` at `open`.
    pub fn matching_brace(&self, open: usize) -> Option<usize> {
        let mut depth = 0usize;
        for (k, t) in self.toks.iter().enumerate().skip(open) {
            if t.is("{") {
                depth += 1;
            } else if t.is("}") {
                depth -= 1;
                if depth == 0 {
                    return Some(k);
                }
            }
        }
        None
    }

    /// Index of the `)`/`]` matching the opener at `open`.
    pub fn matching_group(&self, open: usize) -> Option<usize> {
        let (o, c) = match self.toks[open].text.as_str() {
            "(" => ("(", ")"),
            "[" => ("[", "]"),
            "{" => ("{", "}"),
            _ => return None,
        };
        let mut depth = 0usize;
        for (k, t) in self.toks.iter().enumerate().skip(open) {
            if t.is(o) {
                depth += 1;
            } else if t.is(c) {
                depth -= 1;
                if depth == 0 {
                    return Some(k);
                }
            }
        }
        None
    }

    /// True when a comment containing `needle` sits on `line` or within
    /// the `window` lines before it.
    pub fn comment_near(&self, line: u32, window: u32, needle: &str) -> bool {
        let lo = line.saturating_sub(window);
        self.comments
            .iter()
            .any(|c| c.line >= lo && c.line <= line && c.text.contains(needle))
    }
}

/// Find a token subsequence in a slice (free-standing variant of
/// [`LexFile::find_seq`] for fn-body slices).
pub fn seq_find(toks: &[Tok], from: usize, pat: &[&str]) -> Option<usize> {
    if pat.is_empty() || toks.len() < pat.len() {
        return None;
    }
    (from..=toks.len() - pat.len())
        .find(|&i| pat.iter().enumerate().all(|(j, p)| toks[i + j].is(p)))
}

/// Count non-overlapping subsequence occurrences in a slice.
pub fn seq_count(toks: &[Tok], pat: &[&str]) -> usize {
    let mut n = 0;
    let mut i = 0;
    while let Some(j) = seq_find(toks, i, pat) {
        n += 1;
        i = j + pat.len();
    }
    n
}

/// 1-based line where the trailing test region starts.
fn test_cut_line(text: &str) -> u32 {
    for (i, line) in text.lines().enumerate() {
        if line.starts_with("#[cfg(test)]") || line.starts_with("#[cfg(all(test") {
            return (i + 1) as u32;
        }
    }
    u32::MAX
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex the full text into (tokens, comments).
fn lex(text: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b: Vec<char> = text.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let n = b.len();
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (also covers doc comments)
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            comments.push(Comment { line, text: b[start..i].iter().collect() });
            continue;
        }
        // block comment
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let (start, start_line) = (i, line);
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 1;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 1;
                }
                i += 1;
            }
            comments.push(Comment { line: start_line, text: b[start..i].iter().collect() });
            continue;
        }
        // string literal (plain, byte, raw)
        if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"') {
            let start = i;
            if c == 'b' {
                i += 1;
            }
            i += 1; // opening quote
            while i < n {
                if b[i] == '\\' {
                    i += 2;
                    continue;
                }
                if b[i] == '\n' {
                    line += 1;
                }
                if b[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Str,
                text: b[start..i.min(n)].iter().collect(),
                val: None,
                line,
            });
            continue;
        }
        if c == 'r' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '#') {
            // raw string r"..." / r#"..."# (or an ident starting with r)
            let mut j = i + 1;
            let mut hashes = 0;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                let start = i;
                let closer = format!("\"{}", "#".repeat(hashes));
                let rest: String = b[j + 1..].iter().collect();
                let end = rest.find(&closer).map(|p| j + 1 + p + closer.len()).unwrap_or(n);
                line += b[i..end.min(n)].iter().filter(|&&ch| ch == '\n').count() as u32;
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: b[start..end.min(n)].iter().collect(),
                    val: None,
                    line,
                });
                i = end;
                continue;
            }
        }
        // char literal vs lifetime
        if c == '\'' {
            let is_char = i + 1 < n
                && (b[i + 1] == '\\' || (i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\''));
            if is_char {
                let start = i;
                i += 1;
                while i < n {
                    if b[i] == '\\' {
                        i += 2;
                        continue;
                    }
                    if b[i] == '\'' {
                        i += 1;
                        break;
                    }
                    i += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Char,
                    text: b[start..i.min(n)].iter().collect(),
                    val: None,
                    line,
                });
            } else {
                let start = i;
                i += 1;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[start..i].iter().collect(),
                    val: None,
                    line,
                });
            }
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: b[start..i].iter().collect(),
                val: None,
                line,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let mut is_float = false;
            if c == '0' && i + 1 < n && (b[i + 1] == 'x' || b[i + 1] == 'b' || b[i + 1] == 'o') {
                i += 2;
                while i < n && (b[i].is_ascii_hexdigit() || b[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                    i += 1;
                }
                if i + 1 < n && b[i] == '.' && b[i + 1].is_ascii_digit() {
                    is_float = true;
                    i += 1;
                    while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                        i += 1;
                    }
                }
            }
            // type suffix (i8/u32/usize/f64/e-notation exponent)
            let digits_end = i;
            while i < n && is_ident_cont(b[i]) {
                if b[i] == 'e' || b[i] == 'E' || b[i] == 'f' {
                    is_float = is_float || b[i] != 'f';
                }
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            let digits: String = b[start..digits_end].iter().filter(|&&ch| ch != '_').collect();
            let val = if is_float {
                None
            } else if let Some(hex) = digits.strip_prefix("0x") {
                i128::from_str_radix(hex, 16).ok()
            } else if let Some(bin) = digits.strip_prefix("0b") {
                i128::from_str_radix(bin, 2).ok()
            } else if let Some(oct) = digits.strip_prefix("0o") {
                i128::from_str_radix(oct, 8).ok()
            } else {
                digits.parse::<i128>().ok()
            };
            let kind = if is_float { TokKind::Float } else { TokKind::Int };
            toks.push(Tok { kind, text, val, line });
            continue;
        }
        // punctuation: fuse only the operators the passes match on
        let two: Option<&str> = if i + 1 < n {
            match (c, b[i + 1]) {
                (':', ':') => Some("::"),
                ('<', '<') => Some("<<"),
                ('=', '=') => Some("=="),
                ('.', '.') => Some(".."),
                ('-', '>') => Some("->"),
                ('=', '>') => Some("=>"),
                _ => None,
            }
        } else {
            None
        };
        if let Some(op) = two {
            toks.push(Tok { kind: TokKind::Punct, text: op.to_string(), val: None, line });
            i += 2;
        } else {
            toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), val: None, line });
            i += 1;
        }
    }
    (toks, comments)
}

// ---------------------------------------------------------------------
// Const-expression evaluation (enough for the envelope/protocol consts:
// integer literals, `<<`, `* + -`, parens, and `u32::MAX`-style paths).

/// Evaluate the token slice as an integer constant expression.
/// `consts` resolves bare identifiers (earlier consts in the file).
pub fn eval_const(toks: &[Tok], consts: &dyn Fn(&str) -> Option<i128>) -> Option<i128> {
    let mut p = ExprParser { toks, i: 0, consts };
    let v = p.shift()?;
    if p.i == toks.len() {
        Some(v)
    } else {
        None
    }
}

struct ExprParser<'a> {
    toks: &'a [Tok],
    i: usize,
    consts: &'a dyn Fn(&str) -> Option<i128>,
}

impl ExprParser<'_> {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i)
    }

    fn eat(&mut self, text: &str) -> bool {
        if self.peek().is_some_and(|t| t.is(text)) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    // precedence (loosest first): << | + - | * | unary
    fn shift(&mut self) -> Option<i128> {
        let mut v = self.add()?;
        while self.eat("<<") {
            let r = self.add()?;
            v = v.checked_shl(u32::try_from(r).ok()?)?;
        }
        Some(v)
    }

    fn add(&mut self) -> Option<i128> {
        let mut v = self.mul()?;
        loop {
            if self.eat("+") {
                v = v.checked_add(self.mul()?)?;
            } else if self.eat("-") {
                v = v.checked_sub(self.mul()?)?;
            } else {
                return Some(v);
            }
        }
    }

    fn mul(&mut self) -> Option<i128> {
        let mut v = self.unary()?;
        while self.eat("*") {
            v = v.checked_mul(self.unary()?)?;
        }
        Some(v)
    }

    fn unary(&mut self) -> Option<i128> {
        if self.eat("-") {
            return self.unary().map(|v| -v);
        }
        self.atom()
    }

    fn atom(&mut self) -> Option<i128> {
        let t = self.peek()?.clone();
        if t.is("(") {
            self.i += 1;
            let v = self.shift()?;
            if self.eat(")") {
                return Some(v);
            }
            return None;
        }
        if t.kind == TokKind::Int {
            self.i += 1;
            return t.val;
        }
        if t.kind == TokKind::Ident {
            // path constant: `u32::MAX` etc., or a bare local const
            self.i += 1;
            if self.eat("::") {
                let field = self.peek()?.text.clone();
                self.i += 1;
                return match (t.text.as_str(), field.as_str()) {
                    ("u8", "MAX") => Some(u8::MAX as i128),
                    ("u16", "MAX") => Some(u16::MAX as i128),
                    ("u32", "MAX") => Some(u32::MAX as i128),
                    ("u64", "MAX") => Some(u64::MAX as i128),
                    ("i8", "MAX") => Some(i8::MAX as i128),
                    ("i16", "MAX") => Some(i16::MAX as i128),
                    ("i32", "MAX") => Some(i32::MAX as i128),
                    ("i64", "MAX") => Some(i64::MAX as i128),
                    _ => None,
                };
            }
            return (self.consts)(&t.text);
        }
        None
    }
}

/// Collect every `const NAME: TY = EXPR;` in the file (top-level and
/// inside fn bodies alike) into a name → (value, line) map, resolving
/// earlier consts while evaluating later ones.
pub fn collect_consts(f: &LexFile) -> std::collections::BTreeMap<String, (i128, u32)> {
    let mut out = std::collections::BTreeMap::new();
    let mut i = 0usize;
    while let Some(at) = f.find_seq(i, &["const"]) {
        i = at + 1;
        let Some(name_tok) = f.toks.get(at + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            continue;
        }
        // skip the `: TYPE` annotation up to `=`
        let Some(eq) =
            (at + 2..f.toks.len().min(at + 12)).find(|&k| f.toks[k].is("=") && !f.toks[k].is("=="))
        else {
            continue;
        };
        let Some(semi) = (eq + 1..f.toks.len()).find(|&k| f.toks[k].is(";")) else {
            continue;
        };
        let snapshot = out.clone();
        let lookup = move |n: &str| snapshot.get(n).map(|&(v, _)| v);
        if let Some(v) = eval_const(&f.toks[eq + 1..semi], &lookup) {
            out.insert(name_tok.text.clone(), (v, name_tok.line));
        }
        i = semi;
    }
    out
}
