//! Pass 4 — the unsafe audit.
//!
//! `lib.rs` carries `#![deny(unsafe_code)]` with exactly two sanctioned
//! `#[allow(unsafe_code)]` islands: the reactor's epoll FFI shim and
//! the AVX2 micro-kernel. This pass machine-checks that story:
//!
//! * `deny-missing` — `lib.rs` must keep the crate-wide deny.
//! * `unsanctioned-island` — an `#[allow(unsafe_code)]` (or any
//!   `unsafe` token at all) outside [`SANCTIONED`] means a third
//!   island appeared; add it here only after review.
//! * `missing-safety-comment` — every `unsafe {` block needs a
//!   `// SAFETY:` comment on its line or just above it.
//! * `missing-safety-doc` — every `unsafe fn` needs a `# Safety`
//!   section in its doc comment.

use super::{missing_file, Finding, Level, SourceSet};

const PASS: &str = "unsafe";

/// The two sanctioned `#[allow(unsafe_code)]` modules. Growing this
/// list is a deliberate review decision, same as the python lint's
/// island registry before it.
pub const SANCTIONED: [&str; 2] = ["serve/reactor.rs", "xint/kernel/micro.rs"];

const LIB_FILE: &str = "lib.rs";
const SAFETY_COMMENT: &str = "// SAFETY:";
const SAFETY_DOC: &str = "# Safety";
/// `// SAFETY:` must sit on the unsafe block's line or this close above.
const COMMENT_WINDOW: u32 = 3;
/// `# Safety` doc lines sit above the attribute stack, so wider reach.
const DOC_WINDOW: u32 = 8;

fn err(out: &mut Vec<Finding>, file: &str, line: u32, rule: &'static str, message: String) {
    let file = file.to_string();
    out.push(Finding { file, line, pass: PASS, rule, level: Level::Error, message });
}

/// Run pass 4 over the set.
pub fn run(set: &SourceSet) -> Vec<Finding> {
    let mut out = Vec::new();
    match set.get(LIB_FILE) {
        Some(lib) => {
            if lib.find_seq(0, &["deny", "(", "unsafe_code"]).is_none() {
                err(
                    &mut out,
                    &lib.rel,
                    0,
                    "deny-missing",
                    "lib.rs no longer carries #![deny(unsafe_code)] — the two-island policy \
                     rests on the crate-wide deny"
                        .to_string(),
                );
            }
        }
        None => out.push(missing_file(PASS, LIB_FILE)),
    }
    for f in &set.files {
        let sanctioned = SANCTIONED.contains(&f.rel.as_str());
        // allow(unsafe_code) outside a sanctioned island
        if !sanctioned && f.rel != LIB_FILE {
            let mut from = 0usize;
            while let Some(at) = f.find_seq(from, &["allow", "(", "unsafe_code"]) {
                err(
                    &mut out,
                    &f.rel,
                    f.toks[at].line,
                    "unsanctioned-island",
                    format!(
                        "#[allow(unsafe_code)] outside the sanctioned islands ({}) — a new \
                         island is a review decision; register it in analyze/unsafe_audit.rs",
                        SANCTIONED.join(", ")
                    ),
                );
                from = at + 3;
            }
        }
        for (i, t) in f.toks.iter().enumerate() {
            if !t.is_ident("unsafe") {
                continue;
            }
            if !sanctioned {
                err(
                    &mut out,
                    &f.rel,
                    t.line,
                    "unsanctioned-island",
                    format!(
                        "`unsafe` outside the sanctioned islands ({})",
                        SANCTIONED.join(", ")
                    ),
                );
                continue;
            }
            match f.toks.get(i + 1) {
                Some(n) if n.is("{") => {
                    if !f.comment_near(t.line, COMMENT_WINDOW, SAFETY_COMMENT) {
                        err(
                            &mut out,
                            &f.rel,
                            t.line,
                            "missing-safety-comment",
                            format!(
                                "unsafe block without a `{SAFETY_COMMENT}` comment on the \
                                 line or within {COMMENT_WINDOW} lines above"
                            ),
                        );
                    }
                }
                Some(n) if n.is_ident("fn") => {
                    if !f.comment_near(t.line, DOC_WINDOW, SAFETY_DOC) {
                        err(
                            &mut out,
                            &f.rel,
                            t.line,
                            "missing-safety-doc",
                            format!(
                                "unsafe fn without a `{SAFETY_DOC}` doc section within \
                                 {DOC_WINDOW} lines above"
                            ),
                        );
                    }
                }
                _ => {
                    err(
                        &mut out,
                        &f.rel,
                        t.line,
                        "unsafe-shape",
                        "`unsafe` not followed by `{` or `fn` — unsafe trait/impl is not \
                         used in this crate; extend the audit if that changes"
                            .to_string(),
                    );
                }
            }
        }
    }
    out
}
