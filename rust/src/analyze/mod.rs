//! Domain-aware static analysis over the crate's own sources
//! (`fp-xint analyze`).
//!
//! The correctness story of the kernel/concurrency/serving planes
//! rests on arguments a generic linter cannot check: the SIMD fold
//! cadence is an arithmetic claim about `FOLD_CHUNKS`, the seqlock is
//! a pairing claim about `Ordering`s, and the wire format is a byte
//! layout duplicated across encoder, decoder, and clients. This module
//! regenerates those proofs from source on every run (see ANALYSIS.md
//! for the full rule catalogue):
//!
//! * [`envelope`] — **pass 1**: re-derives the integer-overflow
//!   envelope chain (`INT_DOT_MAX_ABS` / `PACK_MAX_ABS` / chunk and
//!   fold cadences vs accumulator widths) from the parsed constants,
//!   so changing any of them without re-establishing the proof fails.
//! * [`atomics`] — **pass 2**: groups atomic store/load sites by field
//!   and checks publish/consume pairing (a Release store needs an
//!   Acquire-side reader; a published field must not be read or
//!   written Relaxed), plus the `// ordering:` rationale rule
//!   delegated from `scripts/check_invariants.py`.
//! * [`protocol`] — **pass 3**: pins the wire-protocol constants and
//!   SpanKind numbering to an append-only registry and cross-checks
//!   the frame byte offsets at every encode/decode site (codec,
//!   blocking clients, loadgen's open-loop decoder).
//! * [`unsafe_audit`] — **pass 4**: exactly the two sanctioned
//!   `#[allow(unsafe_code)]` islands, every `unsafe` block within
//!   reach of a `// SAFETY:` comment, every `unsafe fn` documented.
//!
//! All passes lex with [`lexer`] (tokens, not lines, so string
//! literals and comments can't trip rules) and skip trailing
//! `#[cfg(test)]` regions, the same convention the python lint uses.
//! [`selftest::run`] feeds every pass an adversarial mutated corpus
//! and asserts each seeded bug is caught.

pub mod atomics;
pub mod envelope;
pub mod lexer;
pub mod protocol;
pub mod selftest;
pub mod unsafe_audit;

use crate::util::json::Json;
use lexer::LexFile;
use std::path::{Path, PathBuf};

/// Finding severity. Errors always fail the run; warnings fail it
/// under `--deny warnings` (the CI mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    Warning,
    Error,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Warning => "warning",
            Level::Error => "error",
        }
    }
}

/// One analyzer finding, keyed to a file/line and a stable rule name.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the source root (e.g. `xint/kernel/micro.rs`).
    pub file: String,
    /// 1-based line (0 for whole-file findings).
    pub line: u32,
    /// Pass that produced it (`envelope`, `atomics`, `protocol`,
    /// `unsafe`).
    pub pass: &'static str,
    /// Stable rule identifier within the pass.
    pub rule: &'static str,
    pub level: Level,
    pub message: String,
}

impl Finding {
    pub fn render_line(&self) -> String {
        format!(
            "{}:{}: {}: [{}/{}] {}",
            self.file,
            self.line,
            self.level.name(),
            self.pass,
            self.rule,
            self.message
        )
    }
}

/// The lexed source tree a run analyzes. Loadable from disk (the real
/// crate) or from in-memory strings (the adversarial self-test corpus).
pub struct SourceSet {
    /// Human-readable origin for the report header.
    pub root: String,
    pub files: Vec<LexFile>,
}

impl SourceSet {
    /// Lex every `*.rs` under `root` (recursively, sorted for stable
    /// output). `root` is the crate's `src/` directory.
    pub fn load(root: &Path) -> std::io::Result<SourceSet> {
        let mut paths = Vec::new();
        collect_rs(root, &mut paths)?;
        paths.sort();
        let mut files = Vec::with_capacity(paths.len());
        for p in &paths {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let text = std::fs::read_to_string(p)?;
            files.push(LexFile::new(&rel, &text));
        }
        Ok(SourceSet { root: root.display().to_string(), files })
    }

    /// Build a set from `(rel_path, source_text)` pairs (self-test).
    pub fn from_strings(files: &[(&str, &str)]) -> SourceSet {
        SourceSet {
            root: "<in-memory corpus>".to_string(),
            files: files.iter().map(|(rel, text)| LexFile::new(rel, text)).collect(),
        }
    }

    pub fn get(&self, rel: &str) -> Option<&LexFile> {
        self.files.iter().find(|f| f.rel == rel)
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The crate's `src/` directory, from wherever the binary was invoked
/// (repo root or `rust/`).
pub fn default_src_root() -> Option<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() {
            return Some(p);
        }
    }
    None
}

/// A finding for a file a pass requires but the set does not contain —
/// moving or deleting a checked file must not silently disarm its pass.
pub(crate) fn missing_file(pass: &'static str, rel: &str) -> Finding {
    Finding {
        file: rel.to_string(),
        line: 0,
        pass,
        rule: "missing-file",
        level: Level::Error,
        message: format!(
            "expected source file {rel} not found — if it moved, update the analyzer pass"
        ),
    }
}

/// Run all four passes and return the findings sorted by location.
pub fn run_all(set: &SourceSet) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(envelope::run(set));
    out.extend(atomics::run(set));
    out.extend(protocol::run(set));
    out.extend(unsafe_audit::run(set));
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

/// Render the machine-readable report (schema documented in
/// ANALYSIS.md).
pub fn render_report(set: &SourceSet, findings: &[Finding]) -> String {
    let errors = findings.iter().filter(|f| f.level == Level::Error).count();
    let warnings = findings.len() - errors;
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            Json::obj([
                ("file", Json::str(&f.file)),
                ("line", Json::num(f.line as f64)),
                ("pass", Json::str(f.pass)),
                ("rule", Json::str(f.rule)),
                ("level", Json::str(f.level.name())),
                ("message", Json::str(&f.message)),
            ])
        })
        .collect();
    Json::obj([
        ("version", Json::num(1.0)),
        ("root", Json::str(&set.root)),
        ("findings", Json::Arr(items)),
        (
            "summary",
            Json::obj([
                ("errors", Json::num(errors as f64)),
                ("warnings", Json::num(warnings as f64)),
                ("files_scanned", Json::num(set.files.len() as f64)),
            ]),
        ),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn real_tree() -> SourceSet {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
        SourceSet::load(&root).expect("load crate sources")
    }

    /// The acceptance gate: the unmodified tree produces zero findings.
    #[test]
    fn analyzer_clean_on_own_tree() {
        let set = real_tree();
        let findings = run_all(&set);
        let lines: Vec<String> = findings.iter().map(|f| f.render_line()).collect();
        assert!(findings.is_empty(), "analyzer found issues in the clean tree:\n{lines:?}");
    }

    /// Every seeded corpus mutation is caught (mirrors
    /// `check_invariants.py --self-test`).
    #[test]
    fn adversarial_self_test_passes() {
        let report = selftest::run();
        assert!(report.failed.is_empty(), "self-test failures: {:?}", report.failed);
    }

    #[test]
    fn report_json_roundtrips() {
        let set = SourceSet::from_strings(&[("a.rs", "fn main() {}\n")]);
        let findings = vec![Finding {
            file: "a.rs".to_string(),
            line: 3,
            pass: "envelope",
            rule: "demo",
            level: Level::Warning,
            message: "demo finding".to_string(),
        }];
        let text = render_report(&set, &findings);
        let j = Json::parse(&text).expect("valid JSON");
        let warnings = j.get("summary").and_then(|s| s.get("warnings")).and_then(Json::as_usize);
        assert_eq!(warnings, Some(1));
        let arr = j.get("findings").and_then(Json::as_arr).expect("findings array");
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("rule").and_then(Json::as_str), Some("demo"));
    }
}
