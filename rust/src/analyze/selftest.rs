//! Adversarial self-test: seed each bug class the analyzer exists to
//! catch into a copy of the real tree and assert the right rule fires.
//!
//! This is the analyzer's analogue of `check_invariants.py
//! --self-test`: a checker whose rules silently stopped matching is
//! worse than no checker, so every pass gets a corpus of mutations —
//! a shrunk fold cadence, a weakened `Ordering`, a drifted wire
//! offset, a new unsafe island, a renumbered frame code — built by
//! string surgery on the actual sources (so corpus rot shows up as an
//! anchor failure, not a vacuous pass). Case 0 is the clean tree
//! itself: zero findings, the acceptance gate.
//!
//! Run via `fp-xint analyze --self-test` (CI does) or the
//! `adversarial_self_test_passes` unit test.

use super::{run_all, SourceSet};
use std::path::{Path, PathBuf};

/// Self-test outcome: how many checks ran and which failed.
pub struct Report {
    pub total: usize,
    pub failed: Vec<String>,
}

enum Mutation {
    /// Replace the first occurrence of `find` in `file`.
    Replace { file: &'static str, find: &'static str, replace: &'static str },
    /// Add a file that does not exist in the real tree.
    AddFile { rel: &'static str, text: &'static str },
}

struct Case {
    name: &'static str,
    mutation: Mutation,
    expect_file: &'static str,
    expect_rule: &'static str,
}

const MICRO: &str = "xint/kernel/micro.rs";
const GEMM: &str = "xint/gemm.rs";
const PACK: &str = "xint/kernel/pack.rs";
const RECORDER: &str = "obs/recorder.rs";
const SERVER: &str = "serve/server.rs";
const CONN: &str = "serve/conn.rs";
const PROTOCOL: &str = "serve/protocol.rs";
const LOADGEN: &str = "serve/loadgen.rs";

static CASES: &[Case] = &[
    // --- pass 1: envelope -------------------------------------------
    Case {
        name: "fold-cadence-shrunk",
        mutation: Mutation::Replace {
            file: MICRO,
            find: "const FOLD_CHUNKS: usize = 4096;",
            replace: "const FOLD_CHUNKS: usize = 65536;",
        },
        expect_file: MICRO,
        expect_rule: "avx2-fold-overflow",
    },
    Case {
        name: "scalar-envelope-widened",
        mutation: Mutation::Replace {
            file: GEMM,
            find: "pub const INT_DOT_MAX_ABS: i32 = 1 << 11;",
            replace: "pub const INT_DOT_MAX_ABS: i32 = 1 << 14;",
        },
        expect_file: GEMM,
        expect_rule: "scalar-chunk-overflow",
    },
    Case {
        name: "pack-envelope-widened",
        mutation: Mutation::Replace {
            file: PACK,
            find: "pub const PACK_MAX_ABS: i32 = 127;",
            replace: "pub const PACK_MAX_ABS: i32 = 181;",
        },
        expect_file: PACK,
        expect_rule: "pack-i16-saturate",
    },
    Case {
        name: "scalar-chunk-widened",
        mutation: Mutation::Replace {
            file: GEMM,
            find: "const CHUNK: usize = 256;",
            replace: "const CHUNK: usize = 1 << 20;",
        },
        expect_file: GEMM,
        expect_rule: "scalar-chunk-overflow",
    },
    Case {
        name: "fold-trigger-weakened",
        mutation: Mutation::Replace {
            file: MICRO,
            find: "if folds == FOLD_CHUNKS {",
            replace: "if folds >= FOLD_CHUNKS {",
        },
        expect_file: MICRO,
        expect_rule: "fold-cadence",
    },
    Case {
        name: "envelope-gate-dropped",
        mutation: Mutation::Replace {
            file: PACK,
            find: "debug_assert_envelope(plane",
            replace: "skip_envelope_gate(plane",
        },
        expect_file: PACK,
        expect_rule: "envelope-gate",
    },
    // --- pass 2: atomics --------------------------------------------
    Case {
        name: "seqlock-publish-relaxed",
        mutation: Mutation::Replace {
            file: RECORDER,
            find: "slot.seq.store(2 * n + 2, Ordering::Release);",
            replace: "slot.seq.store(2 * n + 2, Ordering::Relaxed);",
        },
        expect_file: RECORDER,
        expect_rule: "relaxed-store-to-published",
    },
    Case {
        name: "seqlock-read-relaxed",
        mutation: Mutation::Replace {
            file: RECORDER,
            find: "let s1 = slot.seq.load(Ordering::Acquire);",
            replace: "let s1 = slot.seq.load(Ordering::Relaxed);",
        },
        expect_file: RECORDER,
        expect_rule: "relaxed-load-of-published",
    },
    Case {
        name: "stop-flag-reader-removed",
        mutation: Mutation::Replace {
            file: SERVER,
            find: "if self.stop.load(Ordering::SeqCst) {",
            replace: "if self.stop_requested() {",
        },
        expect_file: SERVER,
        expect_rule: "unpaired-release",
    },
    Case {
        name: "ordering-rationale-dropped",
        mutation: Mutation::Replace {
            file: CONN,
            find: "// ordering: Relaxed — lone advisory stop flag polled by",
            replace: "// note: Relaxed — lone advisory stop flag polled by",
        },
        expect_file: CONN,
        expect_rule: "ordering-comment",
    },
    // --- pass 3: protocol -------------------------------------------
    Case {
        name: "request-trace-offset-drift",
        mutation: Mutation::Replace {
            file: PROTOCOL,
            find: "let trace_id = self.u64_at(12);",
            replace: "let trace_id = self.u64_at(13);",
        },
        expect_file: PROTOCOL,
        expect_rule: "frame-offset",
    },
    Case {
        name: "loadgen-trace-offset-drift",
        mutation: Mutation::Replace {
            file: LOADGEN,
            find: "let trace_id = self.u64_at(8);",
            replace: "let trace_id = self.u64_at(9);",
        },
        expect_file: LOADGEN,
        expect_rule: "frame-offset",
    },
    Case {
        name: "frame-code-renumbered",
        mutation: Mutation::Replace {
            file: PROTOCOL,
            find: "pub const CODE_MALFORMED: u32 = 2;",
            replace: "pub const CODE_MALFORMED: u32 = 3;",
        },
        expect_file: PROTOCOL,
        expect_rule: "registry-pin",
    },
    Case {
        name: "frame-code-unregistered",
        mutation: Mutation::Replace {
            file: PROTOCOL,
            find: "pub const CODE_MALFORMED: u32 = 2;",
            replace: "pub const CODE_MALFORMED: u32 = 2;\npub const CODE_RETRY: u32 = 7;",
        },
        expect_file: PROTOCOL,
        expect_rule: "registry-append",
    },
    Case {
        name: "encoder-fields-swapped",
        mutation: Mutation::Replace {
            file: PROTOCOL,
            find: "    out.extend_from_slice(&tw.to_le_bytes());\n    \
                   out.extend_from_slice(&trace_id.to_le_bytes());",
            replace: "    out.extend_from_slice(&trace_id.to_le_bytes());\n    \
                      out.extend_from_slice(&tw.to_le_bytes());",
        },
        expect_file: PROTOCOL,
        expect_rule: "encoder-layout",
    },
    Case {
        name: "client-skips-trace-word",
        mutation: Mutation::Replace {
            file: PROTOCOL,
            find: "let echoed = read_u64(s)?;",
            replace: "let echoed = 0u64;",
        },
        expect_file: PROTOCOL,
        expect_rule: "client-layout",
    },
    Case {
        name: "spankind-renumbered",
        mutation: Mutation::Replace {
            file: RECORDER,
            find: "Reduce = 7,",
            replace: "Reduce = 11,",
        },
        expect_file: RECORDER,
        expect_rule: "spankind-append",
    },
    Case {
        name: "layout-call-outside-codec",
        mutation: Mutation::AddFile {
            rel: "serve/raw.rs",
            text: "pub fn stamp(out: &mut Vec<u8>, id: u64) {\n    \
                   out.extend_from_slice(&id.to_le_bytes());\n}\n",
        },
        expect_file: "serve/raw.rs",
        expect_rule: "layout-local",
    },
    // --- pass 4: unsafe ---------------------------------------------
    Case {
        name: "third-unsafe-island",
        mutation: Mutation::AddFile {
            rel: "util/fastmem.rs",
            text: "#[allow(unsafe_code)]\npub mod fast {}\n",
        },
        expect_file: "util/fastmem.rs",
        expect_rule: "unsanctioned-island",
    },
    Case {
        name: "safety-comment-dropped",
        mutation: Mutation::Replace {
            file: MICRO,
            find: "// SAFETY: AVX2 presence just verified; slices are equal",
            replace: "// NB: AVX2 presence just verified; slices are equal",
        },
        expect_file: MICRO,
        expect_rule: "missing-safety-comment",
    },
    Case {
        name: "safety-doc-dropped",
        mutation: Mutation::Replace {
            file: MICRO,
            find: "    /// # Safety\n    /// Caller must have verified AVX2 support.\n",
            replace: "",
        },
        expect_file: MICRO,
        expect_rule: "missing-safety-doc",
    },
    Case {
        name: "crate-deny-dropped",
        mutation: Mutation::Replace {
            file: "lib.rs",
            find: "#![deny(unsafe_code)]",
            replace: "#![allow(unsafe_code)]",
        },
        expect_file: "lib.rs",
        expect_rule: "deny-missing",
    },
];

/// Pass-2 corpus that needs no mutation of the real tree: a Release
/// publisher whose only reader is Relaxed (the PR 7 seqlock bug, in
/// miniature) — both `unpaired-release` and `relaxed-load-of-published`
/// must fire.
const UNPAIRED_RELEASE_CORPUS: &str = r#"
use crate::util::sync::atomic::{AtomicU32, Ordering};
pub struct W {
    seq: AtomicU32,
}
impl W {
    pub fn publish(&self) {
        // ordering: Release — publishes the slot to readers.
        self.seq.store(1, Ordering::Release);
    }
    pub fn peek(&self) -> u32 {
        // ordering: Relaxed — (seeded bug) reads the published slot.
        self.seq.load(Ordering::Relaxed)
    }
}
"#;

/// Pass-2 corpus: an Acquire reader with no publisher anywhere.
const UNPAIRED_ACQUIRE_CORPUS: &str = r#"
use crate::util::sync::atomic::{AtomicU32, Ordering};
pub struct W {
    flag: AtomicU32,
}
impl W {
    pub fn wait(&self) -> u32 {
        // ordering: Acquire — pairs with a publisher that is gone.
        self.flag.load(Ordering::Acquire)
    }
}
"#;

fn load_texts(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut paths = Vec::new();
    super::collect_rs(root, &mut paths)?;
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        out.push((rel, std::fs::read_to_string(p)?));
    }
    Ok(out)
}

fn set_from(texts: &[(String, String)]) -> SourceSet {
    let refs: Vec<(&str, &str)> = texts.iter().map(|(r, t)| (r.as_str(), t.as_str())).collect();
    SourceSet::from_strings(&refs)
}

fn run_case(texts: &[(String, String)], case: &Case, failed: &mut Vec<String>) {
    let mut mutated = texts.to_vec();
    match &case.mutation {
        Mutation::Replace { file, find, replace } => {
            let Some(entry) = mutated.iter_mut().find(|(r, _)| r.as_str() == *file) else {
                failed.push(format!("{}: corpus file {file} missing from the tree", case.name));
                return;
            };
            if !entry.1.contains(find) {
                failed.push(format!(
                    "{}: mutation anchor not found in {file}: {find:?} — the corpus rotted; \
                     update the self-test",
                    case.name
                ));
                return;
            }
            entry.1 = entry.1.replacen(find, replace, 1);
        }
        Mutation::AddFile { rel, text } => mutated.push((rel.to_string(), text.to_string())),
    }
    let findings = run_all(&set_from(&mutated));
    if !findings.iter().any(|f| f.file == case.expect_file && f.rule == case.expect_rule) {
        let got: Vec<String> = findings.iter().map(|f| f.render_line()).collect();
        failed.push(format!(
            "{}: seeded bug not caught — expected a `{}` finding in {}, got {got:?}",
            case.name, case.expect_rule, case.expect_file
        ));
    }
}

fn run_synthetic(failed: &mut Vec<String>, total: &mut usize) {
    let set = SourceSet::from_strings(&[("sync/demo_release.rs", UNPAIRED_RELEASE_CORPUS)]);
    let findings = super::atomics::run(&set);
    for rule in ["unpaired-release", "relaxed-load-of-published"] {
        *total += 1;
        if !findings.iter().any(|f| f.rule == rule) {
            failed.push(format!("synthetic release corpus: expected a `{rule}` finding"));
        }
    }
    let set = SourceSet::from_strings(&[("sync/demo_acquire.rs", UNPAIRED_ACQUIRE_CORPUS)]);
    let findings = super::atomics::run(&set);
    *total += 1;
    if !findings.iter().any(|f| f.rule == "unpaired-acquire") {
        failed.push("synthetic acquire corpus: expected an `unpaired-acquire` finding".to_string());
    }
}

/// Run the whole corpus against the real tree. Errors loading the tree
/// are reported as failures, not panics, so `--self-test` exits 1 with
/// a message instead of aborting.
pub fn run() -> Report {
    let mut failed = Vec::new();
    let mut total = 0usize;

    let root = super::default_src_root()
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));
    let texts = match load_texts(&root) {
        Ok(t) => t,
        Err(e) => {
            failed.push(format!("cannot load crate sources under {}: {e}", root.display()));
            return Report { total: 1, failed };
        }
    };

    // case 0: the unmutated tree is clean (the acceptance gate)
    total += 1;
    let findings = run_all(&set_from(&texts));
    if !findings.is_empty() {
        let got: Vec<String> = findings.iter().map(|f| f.render_line()).collect();
        failed.push(format!("clean tree: expected zero findings, got {got:?}"));
    }

    for case in CASES {
        total += 1;
        run_case(&texts, case, &mut failed);
    }
    run_synthetic(&mut failed, &mut total);

    Report { total, failed }
}
