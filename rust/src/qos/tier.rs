//! Service tiers — the request-facing half of the QoS contract.
//!
//! A tier names an accuracy/latency trade-off, not a term count: the
//! [`TermController`](super::TermController) translates each tier's
//! tolerance into a basis-term budget using §5.3 convergence data, and
//! may lower the budget further under load. Because the expansion is a
//! *series*, every prefix of the basis pool is itself a valid model —
//! tiers select how far along the series a request rides.
//!
//! Each tier also carries its own latency contract
//! ([`Tier::slo_target`]): the controller runs one pressure loop *per
//! tier*, stepping a tier down only when **its own** windowed p99
//! breaks **its own** SLO target or its own queue runs hot — a flood
//! in one tier can never degrade another tier's precision.

/// Number of tiers (array sizing for per-tier metrics/budgets).
pub const NUM_TIERS: usize = 4;

/// Per-request service tier, ordered strictest → loosest.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    /// Full series: every basis term, never degraded by the controller.
    #[default]
    Exact = 0,
    /// Reconstruction within the paper's 1e-4 auto-stop tolerance (§5.3).
    Balanced = 1,
    /// Coarse reconstruction (1e-2 tolerance) tuned for tail latency.
    Throughput = 2,
    /// Whatever precision the current load affords; degraded first.
    BestEffort = 3,
}

impl Tier {
    /// All tiers in wire order.
    pub const ALL: [Tier; NUM_TIERS] =
        [Tier::Exact, Tier::Balanced, Tier::Throughput, Tier::BestEffort];

    /// Wire encoding (the TCP protocol's tier field).
    pub fn as_u32(self) -> u32 {
        self as u32
    }

    /// Decode the wire value; `None` for unknown tiers (protocol error).
    pub fn from_u32(v: u32) -> Option<Tier> {
        Tier::ALL.get(v as usize).copied()
    }

    /// Index into per-tier arrays (budgets, metrics).
    pub fn idx(self) -> usize {
        self as usize
    }

    /// Max-residual tolerance defining the tier's base budget; `None`
    /// means "all terms" (Exact is a tolerance-free contract).
    pub fn tolerance(self) -> Option<f32> {
        match self {
            Tier::Exact => None,
            Tier::Balanced => Some(1e-4),
            Tier::Throughput => Some(1e-2),
            Tier::BestEffort => Some(1e-1),
        }
    }

    /// Minimum term count the controller may degrade this tier to.
    /// Exact is immune (floor = total); looser tiers bottom out earlier.
    pub fn floor_terms(self, total: usize) -> usize {
        match self {
            Tier::Exact => total,
            Tier::Balanced => (total / 4).max(1),
            Tier::Throughput => 1,
            Tier::BestEffort => 1,
        }
    }

    /// Weighted-service share for the per-tier batcher queues: rows of
    /// deficit-round-robin credit accrued per scheduling rotation.
    /// Strict tiers are served more often under contention, but every
    /// weight is ≥ 1 so no tier can be starved outright.
    pub fn service_weight(self) -> u32 {
        match self {
            Tier::Exact => 8,
            Tier::Balanced => 4,
            Tier::Throughput => 2,
            Tier::BestEffort => 1,
        }
    }

    /// All service weights, indexed by [`Tier::idx`] (batcher config).
    pub fn service_weights() -> [u32; NUM_TIERS] {
        std::array::from_fn(|i| Tier::ALL[i].service_weight())
    }

    /// Uncalibrated default *layer-granularity* term cap: the per-axis
    /// bound a tier puts on every expanded layer's Eq. 3 grid in
    /// replication mode (the pool-prefix budget's counterpart one level
    /// down). `usize::MAX` means untruncated.
    pub fn default_layer_terms(self) -> usize {
        match self {
            Tier::Exact => usize::MAX,
            Tier::Balanced => 3,
            Tier::Throughput => 2,
            Tier::BestEffort => 1,
        }
    }

    /// Minimum activation-term cap pressure may degrade a tier's layer
    /// budget to. Exact is immune (never truncated at all).
    pub fn layer_floor_terms(self) -> usize {
        match self {
            Tier::Exact => usize::MAX,
            Tier::Balanced => 2,
            Tier::Throughput => 1,
            Tier::BestEffort => 1,
        }
    }

    /// Default p99 request-latency SLO target (seconds) driving this
    /// tier's pressure loop; `None` = no latency SLO. The ladder runs
    /// *opposite* to the precision ladder: precision-strict tiers buy
    /// accuracy with latency (`Exact` promises none at all), while
    /// `Throughput` — the tail-latency product — carries the tightest
    /// target. `BestEffort` promises only "eventually". Overridable per
    /// deployment via
    /// [`QosConfig::with_slo_target`](super::QosConfig::with_slo_target).
    pub fn slo_target(self) -> Option<f64> {
        match self {
            Tier::Exact => None,
            Tier::Balanced => Some(0.100),
            Tier::Throughput => Some(0.025),
            Tier::BestEffort => Some(0.500),
        }
    }

    /// All SLO targets in seconds, `0.0` where a tier has none — the
    /// array form [`QosConfig`](super::QosConfig) carries, indexed by
    /// [`Tier::idx`].
    pub fn slo_targets() -> [f64; NUM_TIERS] {
        std::array::from_fn(|i| Tier::ALL[i].slo_target().unwrap_or(0.0))
    }

    /// §5.3 *relative* scale-product threshold for the in-grid anytime
    /// stop: a planned layer budget carries this floor so the sorted
    /// `(i, j)` execution stops once `s_wi · s_aj` drops below
    /// `floor ×` the layer's leading product (see
    /// [`TermBudget::scale_floor`](crate::xint::TermBudget); the
    /// leading pair always runs). The tier tolerance doubles as the
    /// relative threshold: a pair whose product is below `tol ×` the
    /// leading product contributes at most `tol ×` the leading pair's
    /// magnitude — the same scale-invariant relative rule the
    /// pool-prefix anytime reduction uses on reduced terms (the paper
    /// gives no in-grid formula; recorded as a substitution). 0.0 for
    /// Exact: never stop.
    pub fn grid_scale_floor(self) -> f32 {
        self.tolerance().unwrap_or(0.0)
    }

    /// Uncalibrated default budget (used before a monitor calibration).
    pub fn default_budget(self, total: usize) -> usize {
        match self {
            Tier::Exact => total,
            Tier::Balanced => total.div_ceil(2).max(1),
            Tier::Throughput => total.div_ceil(4).max(1),
            Tier::BestEffort => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Tier::Exact => "exact",
            Tier::Balanced => "balanced",
            Tier::Throughput => "throughput",
            Tier::BestEffort => "best-effort",
        }
    }

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Tier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "exact" => Some(Tier::Exact),
            "balanced" => Some(Tier::Balanced),
            "throughput" => Some(Tier::Throughput),
            "best-effort" | "besteffort" | "best_effort" => Some(Tier::BestEffort),
            _ => None,
        }
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrip() {
        for t in Tier::ALL {
            assert_eq!(Tier::from_u32(t.as_u32()), Some(t));
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::from_u32(17), None);
        assert_eq!(Tier::parse("platinum"), None);
    }

    #[test]
    fn ordering_strictest_first() {
        assert!(Tier::Exact < Tier::Balanced);
        assert!(Tier::Balanced < Tier::Throughput);
        assert!(Tier::Throughput < Tier::BestEffort);
    }

    #[test]
    fn tolerances_loosen_down_the_ladder() {
        let tols: Vec<f32> = Tier::ALL.iter().filter_map(|t| t.tolerance()).collect();
        assert!(tols.windows(2).all(|w| w[0] < w[1]), "{tols:?}");
        assert_eq!(Tier::Exact.tolerance(), None);
    }

    #[test]
    fn service_weights_strict_tiers_first_and_never_zero() {
        let w = Tier::service_weights();
        assert!(w.windows(2).all(|p| p[1] <= p[0]), "{w:?}");
        assert!(w.iter().all(|&x| x >= 1), "zero weight would starve a tier: {w:?}");
        assert_eq!(w[Tier::Exact.idx()], Tier::Exact.service_weight());
    }

    #[test]
    fn layer_terms_monotone_and_floored() {
        let caps: Vec<usize> = Tier::ALL.iter().map(|t| t.default_layer_terms()).collect();
        assert!(caps.windows(2).all(|w| w[1] <= w[0]), "{caps:?}");
        assert_eq!(caps[0], usize::MAX, "exact is never truncated");
        assert_eq!(caps[3], 1, "best-effort bottoms out at one term per axis");
        for t in Tier::ALL {
            assert!(t.layer_floor_terms() >= 1);
            assert!(t.layer_floor_terms() <= t.default_layer_terms());
        }
    }

    #[test]
    fn grid_scale_floor_follows_the_tolerance_ladder() {
        assert_eq!(Tier::Exact.grid_scale_floor(), 0.0, "exact never stops the grid");
        let floors: Vec<f32> = Tier::ALL.iter().map(|t| t.grid_scale_floor()).collect();
        assert!(floors.windows(2).all(|w| w[0] <= w[1]), "{floors:?}");
        for t in [Tier::Balanced, Tier::Throughput, Tier::BestEffort] {
            assert_eq!(t.grid_scale_floor(), t.tolerance().unwrap());
        }
    }

    #[test]
    fn slo_ladder_exact_free_throughput_tightest() {
        assert_eq!(Tier::Exact.slo_target(), None, "exact promises precision, not latency");
        let targets: Vec<f64> = Tier::ALL.iter().filter_map(|t| t.slo_target()).collect();
        assert!(targets.iter().all(|&t| t > 0.0), "{targets:?}");
        let tightest = targets.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(
            Tier::Throughput.slo_target(),
            Some(tightest),
            "the tail-latency tier must carry the tightest SLO"
        );
        // array form: 0.0 encodes "no SLO", everything else matches
        let arr = Tier::slo_targets();
        assert_eq!(arr[Tier::Exact.idx()], 0.0);
        for t in [Tier::Balanced, Tier::Throughput, Tier::BestEffort] {
            assert_eq!(arr[t.idx()], t.slo_target().unwrap());
        }
    }

    #[test]
    fn budgets_and_floors_monotone_in_tier() {
        for total in [1usize, 2, 4, 8, 16] {
            let budgets: Vec<usize> =
                Tier::ALL.iter().map(|t| t.default_budget(total)).collect();
            assert!(budgets.windows(2).all(|w| w[1] <= w[0]), "{budgets:?}");
            for t in Tier::ALL {
                assert!((1..=total).contains(&t.floor_terms(total)));
            }
            assert_eq!(Tier::Exact.floor_terms(total), total);
        }
    }
}
