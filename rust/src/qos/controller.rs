//! Term controller — translates tier tolerances into basis-term budgets
//! and degrades those budgets under load instead of shedding requests.
//!
//! Calibration uses [`ExpansionMonitor`] convergence data (§5.3): a
//! tier's base budget is the smallest term count whose observed
//! max-residual is below the tier tolerance. At serve time the
//! controller runs **one pressure loop per tier**: each formed batch
//! feeds exactly one [`TermController::observe_batch`] decision for
//! *its own* tier — that tier's queue occupancy (its depth over its
//! own cap, from the per-tier batcher queues), that tier's batch
//! service-time EWMA, and that tier's windowed request-latency p99
//! checked against the tier's SLO target
//! ([`Tier::slo_target`], overridable via
//! [`QosConfig::with_slo_target`]). A tier steps pressure up only when
//! **its own** p99 breaks **its own** SLO or its own queue runs hot,
//! and each step removes precision from that tier alone, bounded below
//! by the tier's floor. When the tier's queue drains and its latency
//! cools, its pressure falls and full precision is restored —
//! precision degrades per tier, availability does not, and a
//! Throughput flood can never move Balanced's served precision (the
//! pre-PR-5 loop fed one global scalar from the *hottest* queue across
//! all tiers, so it could).
//!
//! The p99 signal comes from a small lock-free ring digest per tier
//! inside the controller ([`TermController::record_latency`]), seeded
//! by the scheduler with exactly the latencies
//! [`Metrics::record_completed_tier`](crate::coordinator::Metrics::record_completed_tier)
//! sees (elided for tiers whose SLO is disabled — they never read the
//! window); each decision consumes its tier's window
//! ([`TermController::take_tier_p99`]), so a window spans the
//! latencies completed since the tier's previous decision. Failed
//! batches feed occupancy relief only — their service time and
//! latencies stay out of the EWMA and digest, so errors cannot
//! masquerade as load.
//!
//! With per-layer calibration attached
//! ([`TermController::calibrate_layers`]), each tier maps to a
//! sensitivity-planned [`BudgetPlan`] instead of one scalar layer
//! budget: the tier's **total** grid-term ceiling (the uniform
//! allocation's cost at the tier's calibrated cap) is spread across
//! layers by marginal max-diff gain, pressure shrinks the *ceiling*
//! (one uniform activation-term-equivalent per step) and replans, and
//! Exact is immune by construction ([`BudgetPlan::full`] always).
//! Plans stay memoized per (tier, that tier's effective ceiling).

use super::tier::{Tier, NUM_TIERS};
use crate::util::stats::percentile;
use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::Mutex;
use crate::xint::budget::{BudgetPlan, TermBudget};
use crate::xint::monitor::ExpansionMonitor;
use crate::xint::planner::{BudgetPlanner, LayerGridProfile};

/// Controller tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct QosConfig {
    /// total basis terms available (the worker-pool size)
    pub total_terms: usize,
    /// per-tier queue occupancy above which that tier's pressure rises
    /// (the observed batch's own depth/cap; one decision per batch)
    pub high_watermark: f64,
    /// per-tier queue occupancy below which that tier's pressure falls
    pub low_watermark: f64,
    /// batch service time (seconds) above which pressure also rises;
    /// 0.0 disables the service-time signal
    pub service_target_s: f64,
    /// per-tier p99 request-latency SLO target in seconds (0.0 = that
    /// tier has no latency SLO), indexed by [`Tier::idx`]; defaults to
    /// the [`Tier::slo_target`] ladder
    pub slo_targets: [f64; NUM_TIERS],
    /// enable anytime reduction: stop the prefix sum early when the
    /// marginal term's contribution falls below the batch tolerance,
    /// and carry each tier's §5.3 scale floor
    /// ([`Tier::grid_scale_floor`]) into planned layer budgets so the
    /// sorted (i, j) grid stops early too
    pub anytime: bool,
}

impl QosConfig {
    pub fn new(total_terms: usize) -> QosConfig {
        QosConfig {
            total_terms,
            high_watermark: 0.75,
            low_watermark: 0.25,
            service_target_s: 0.0,
            slo_targets: Tier::slo_targets(),
            anytime: false,
        }
    }

    pub fn with_anytime(mut self, on: bool) -> QosConfig {
        self.anytime = on;
        self
    }

    pub fn with_service_target(mut self, target_s: f64) -> QosConfig {
        self.service_target_s = target_s;
        self
    }

    /// Override one tier's p99 SLO target (seconds; 0.0 disables the
    /// latency SLO for that tier).
    pub fn with_slo_target(mut self, tier: Tier, p99_s: f64) -> QosConfig {
        self.slo_targets[tier.idx()] = p99_s;
        self
    }
}

/// Ring capacity of each tier's latency digest: bounds both memory and
/// the cost of one p99 read. A decision window rarely exceeds one
/// batch's worth of replies, so 256 slots lose nothing in practice.
const DIGEST_CAP: usize = 256;

/// Lock-free ring of recent request latencies for one tier (f64 bits
/// in atomics). Writers `fetch_add` a cursor and store into the slot;
/// the reader snapshots the filled prefix. The DECISION path is
/// single-writer single-consumer (the batcher's forming thread records
/// and consumes), so its windows are exact; a concurrent observability
/// read ([`TermController::tier_p99`] from a snapshot) may transiently
/// see up to one claimed-but-unwritten slot (reading the previous
/// window's value or the 0.0 init), and a reset racing a writer can
/// strand one sample — bounded staleness, harmless for a load signal.
#[derive(Debug)]
struct LatencyDigest {
    slots: [AtomicU64; DIGEST_CAP],
    /// samples pushed since the last window reset (ring-wraps over
    /// `slots`; reads clamp to the capacity)
    pushed: AtomicUsize,
}

impl LatencyDigest {
    fn new() -> LatencyDigest {
        LatencyDigest {
            slots: std::array::from_fn(|_| AtomicU64::new(0)),
            pushed: AtomicUsize::new(0),
        }
    }

    fn record(&self, latency_s: f64) {
        // ordering: Relaxed — the cursor RMW only claims a slot; no
        // reader dereferences anything on the strength of the counter,
        // and each slot holds a self-contained f64 (a stale or
        // concurrently-updated sample shifts a load estimate by one
        // data point, documented above as bounded staleness).
        let i = self.pushed.fetch_add(1, Ordering::Relaxed) % DIGEST_CAP;
        self.slots[i].store(latency_s.to_bits(), Ordering::Relaxed);
    }

    fn p99(&self) -> Option<f64> {
        // ordering: Relaxed — the decision path is single-threaded
        // (record and consume happen on the batcher's forming thread,
        // which sequences its own accesses); concurrent observability
        // readers tolerate one stale slot by contract.
        let n = self.pushed.load(Ordering::Relaxed).min(DIGEST_CAP);
        if n == 0 {
            return None;
        }
        // ordering: Relaxed — slot loads, same contract as above.
        let xs: Vec<f64> =
            (0..n).map(|i| f64::from_bits(self.slots[i].load(Ordering::Relaxed))).collect();
        Some(percentile(&xs, 99.0))
    }

    fn reset(&self) {
        // ordering: Relaxed — rolls the window for the same
        // single-consumer decision path; a racing writer strands at
        // most one sample (bounded staleness, per the type docs).
        self.pushed.store(0, Ordering::Relaxed);
    }
}

/// The EWMA blend step. NaN bits are the "no sample yet" sentinel —
/// a genuine ~0 s service sample is a real initialization, not "unset"
/// (the previous `prev == 0.0` sentinel let one fast batch reset the
/// whole filter).
fn blend_ewma(prev: f64, sample: f64) -> f64 {
    if prev.is_nan() {
        sample
    } else {
        0.8 * prev + 0.2 * sample
    }
}

/// Per-layer calibration state behind [`TermController::plan_for`].
#[derive(Clone, Debug)]
struct PlanCalibration {
    /// per-tier profiles with the tier's weight-axis cap already
    /// applied (mirroring the scalar path, which truncates the `i`
    /// axis at the tier cap); empty for tiers that plan a full budget
    capped: [Vec<LayerGridProfile>; NUM_TIERS],
    /// zero-pressure grid ceiling per tier (`usize::MAX` = untruncated,
    /// i.e. the tier plans a full budget)
    base_ceiling: [usize; NUM_TIERS],
    /// ceiling floor per tier: every non-exempt layer at the tier's
    /// layer floor — pressure never cuts below this
    floor_ceiling: [usize; NUM_TIERS],
    /// grid terms one pressure step removes at each tier: one
    /// activation term off every plannable layer at the tier's
    /// weight-axis cap (the uniform-equivalent of the scalar path's
    /// one-term step)
    pressure_step: [usize; NUM_TIERS],
    /// memoized plans keyed by (tier idx, effective ceiling): the
    /// greedy allocation is deterministic and each tier's pressure
    /// takes at most its capped range of discrete values, so this
    /// stays tiny and the per-batch hot path is a hash lookup
    plan_cache: std::collections::HashMap<(usize, usize), BudgetPlan>,
}

/// Point-in-time view of the controller (observability/reporting).
#[derive(Clone, Debug)]
pub struct QosSnapshot {
    /// per-tier pressure level, indexed by [`Tier::idx`]
    pub pressures: [usize; NUM_TIERS],
    /// effective budget per tier, indexed by [`Tier::idx`]
    pub budgets: [usize; NUM_TIERS],
    /// effective layer-granularity budget per tier (replication mode,
    /// uniform fallback path)
    pub layer_budgets: [TermBudget; NUM_TIERS],
    /// per-tier planned grid ceiling (`None` before per-layer
    /// calibration and for untruncated tiers)
    pub plan_ceilings: [Option<usize>; NUM_TIERS],
    /// per-tier windowed request-latency p99 (`None` = empty window)
    pub tier_p99: [Option<f64>; NUM_TIERS],
    /// per-tier degrade/restore step counts
    pub tier_degrade_events: [u64; NUM_TIERS],
    pub tier_restore_events: [u64; NUM_TIERS],
    /// totals across tiers
    pub degrade_events: u64,
    pub restore_events: u64,
}

/// Adaptive-precision control plane shared by batcher and scheduler.
///
/// All scalar state is atomic: `budget_for` runs on the scheduler hot
/// path while pressure observations arrive from batch formation. The
/// per-layer plan calibration sits behind a mutex (`plan_for` takes it
/// once per formed batch, not per request).
#[derive(Debug)]
pub struct TermController {
    cfg: QosConfig,
    /// calibrated base budget per tier (before pressure)
    base: [AtomicUsize; NUM_TIERS],
    /// calibrated base *layer* term cap per tier (replication mode's
    /// per-axis Eq. 3 grid bound; `usize::MAX` = untruncated)
    layer_base: [AtomicUsize; NUM_TIERS],
    /// current pressure per tier: degradation steps applied to that
    /// tier alone (Exact's entry is pinned at 0 by its cap)
    pressure: [AtomicUsize; NUM_TIERS],
    /// per-tier pressure ceiling: enough steps to take every degradable
    /// axis (pool prefix, uniform layer budget, plan ceiling) to its
    /// floor, and no more — deeper pressure would only delay recovery
    max_pressure: [AtomicUsize; NUM_TIERS],
    degrade_events: [AtomicU64; NUM_TIERS],
    restore_events: [AtomicU64; NUM_TIERS],
    /// observed max-residual per term count (monitor copy), for
    /// estimated-precision-loss reporting; empty before calibration
    convergence: Mutex<Vec<f32>>,
    /// per-layer sensitivity calibration; `None` until
    /// [`TermController::calibrate_layers`] runs
    plan_cal: Mutex<Option<PlanCalibration>>,
    /// per-tier EWMA of batch service time (seconds as f64 bits; NaN
    /// bits = no sample yet), updated by CAS so concurrent observers
    /// never drop each other's samples
    service_ewma: [AtomicU64; NUM_TIERS],
    /// per-tier windowed latency digests feeding the p99-vs-SLO signal
    digests: [LatencyDigest; NUM_TIERS],
}

impl TermController {
    pub fn new(cfg: QosConfig) -> TermController {
        assert!(cfg.total_terms >= 1, "controller needs at least one term");
        assert!(cfg.low_watermark < cfg.high_watermark, "watermarks inverted");
        let base = std::array::from_fn(|i| {
            AtomicUsize::new(Tier::ALL[i].default_budget(cfg.total_terms))
        });
        let layer_base =
            std::array::from_fn(|i| AtomicUsize::new(Tier::ALL[i].default_layer_terms()));
        let c = TermController {
            cfg,
            base,
            layer_base,
            pressure: std::array::from_fn(|_| AtomicUsize::new(0)),
            max_pressure: std::array::from_fn(|_| AtomicUsize::new(0)),
            degrade_events: std::array::from_fn(|_| AtomicU64::new(0)),
            restore_events: std::array::from_fn(|_| AtomicU64::new(0)),
            convergence: Mutex::new(Vec::new()),
            plan_cal: Mutex::new(None),
            service_ewma: std::array::from_fn(|_| AtomicU64::new(f64::NAN.to_bits())),
            digests: std::array::from_fn(|_| LatencyDigest::new()),
        };
        c.refresh_max_pressure();
        c
    }

    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    /// Set each tier's base budget from observed convergence: the
    /// smallest term count under the tier tolerance (§5.3 rule), all
    /// terms when the tolerance was never reached. The same rule
    /// calibrates the layer-granularity budget — the monitor measures
    /// how many series terms a tensor needs for a tolerance, which is
    /// exactly the per-axis cap a layer's Eq. 3 grid should honor.
    pub fn calibrate(&self, monitor: &ExpansionMonitor) {
        let total = self.cfg.total_terms;
        for tier in Tier::ALL {
            let (budget, layer) = match tier.tolerance() {
                None => (total, usize::MAX),
                Some(tol) => {
                    let n = monitor.optimal_terms(tol);
                    (n.unwrap_or(total).min(total), n.unwrap_or(usize::MAX))
                }
            };
            // ordering: Relaxed — each base is an independent scalar;
            // hot-path readers compose whatever mix of old/new bases
            // they observe with floors applied per read, so no
            // publication edge is needed.
            self.base[tier.idx()].store(budget.max(1), Ordering::Relaxed);
            self.layer_base[tier.idx()].store(layer.max(1), Ordering::Relaxed);
        }
        let mut conv = self.convergence.lock().unwrap();
        *conv = monitor.max_diff().to_vec();
        drop(conv);
        self.refresh_max_pressure();
    }

    /// Attach per-layer sensitivity calibration: each tier's plan
    /// ceiling is the *scalar* path's exact grid cost at the tier's
    /// calibrated cap — both axes clamped per layer, exactly what
    /// [`TermController::layer_budget_for`] would spend — so a planned
    /// allocation redistributes the same total, never more. The planner
    /// then spreads that total across layers by marginal max-diff gain.
    /// Call after [`TermController::calibrate`] so the per-tier caps
    /// reflect the monitor; calling it first uses the tier defaults.
    pub fn calibrate_layers(&self, profiles: Vec<LayerGridProfile>) {
        let mut base_ceiling = [usize::MAX; NUM_TIERS];
        let mut floor_ceiling = [0usize; NUM_TIERS];
        let mut pressure_step = [1usize; NUM_TIERS];
        let mut capped: [Vec<LayerGridProfile>; NUM_TIERS] = std::array::from_fn(|_| Vec::new());
        for tier in Tier::ALL {
            // ordering: Relaxed — reads a calibration scalar; see
            // `calibrate` for why no publication edge is needed.
            let cap = self.layer_base[tier.idx()].load(Ordering::Relaxed);
            if tier == Tier::Exact || cap == usize::MAX {
                continue;
            }
            let i = tier.idx();
            // mirror the scalar path's weight-axis cap so a planned
            // budget never spends GEMMs on weight terms the uniform
            // budget would have truncated
            capped[i] = profiles
                .iter()
                .map(|p| {
                    let mut p = p.clone();
                    if !p.exempt {
                        p.w_terms = p.w_terms.min(cap).max(1);
                    }
                    p
                })
                .collect();
            base_ceiling[i] = BudgetPlanner::grid_cost(&profiles, cap, cap);
            let floor = tier.layer_floor_terms();
            floor_ceiling[i] = if floor == usize::MAX {
                base_ceiling[i]
            } else {
                // pressure degrades only the activation axis (scalar
                // path semantics): the floor keeps the tier's w cap
                BudgetPlanner::grid_cost(&profiles, cap, floor)
            };
            // one activation term off every plannable layer at this
            // tier's weight cap
            pressure_step[i] = BudgetPlanner::grid_cost(&profiles, cap, 1).max(1);
        }
        let mut cal = self.plan_cal.lock().unwrap();
        *cal = Some(PlanCalibration {
            capped,
            base_ceiling,
            floor_ceiling,
            pressure_step,
            plan_cache: std::collections::HashMap::new(),
        });
        drop(cal);
        self.refresh_max_pressure();
    }

    /// Recompute each tier's pressure ceiling from the current
    /// calibration: exactly enough steps to take the pool-prefix
    /// budget, the uniform layer budget, and (when armed) the plan
    /// ceiling to their floors. Capping here keeps recovery prompt —
    /// every drain decision removes one step, so a flood can never
    /// bank more pressure than its tier's budgets can express. (The
    /// pre-PR-5 cap of `total_terms - 1` also pinned replication pools
    /// of one worker at zero pressure, so plan ceilings could never
    /// degrade end-to-end.)
    fn refresh_max_pressure(&self) {
        let cal = self.plan_cal.lock().unwrap();
        for tier in Tier::ALL {
            let i = tier.idx();
            // ordering: Relaxed (whole loop) — every atomic here is an
            // independent control scalar: caps and bases are read to
            // derive a new cap, and readers of `max_pressure` clamp per
            // read, so a momentarily stale mix only delays one pressure
            // step. The CAS below needs atomicity (no lost clamp), not
            // ordering.
            if tier == Tier::Exact {
                self.max_pressure[i].store(0, Ordering::Relaxed);
                continue;
            }
            // ordering: Relaxed — per the loop-head note.
            let base = self.base[i].load(Ordering::Relaxed);
            let floor = tier.floor_terms(self.cfg.total_terms).min(base);
            let mut cap = base.saturating_sub(floor);
            // ordering: Relaxed — per the loop-head note.
            let lb = self.layer_base[i].load(Ordering::Relaxed);
            if lb != usize::MAX {
                cap = cap.max(lb.saturating_sub(tier.layer_floor_terms().min(lb)));
            }
            if let Some(c) = cal.as_ref() {
                let (b, f) = (c.base_ceiling[i], c.floor_ceiling[i]);
                if b != usize::MAX {
                    cap = cap.max(b.saturating_sub(f).div_ceil(c.pressure_step[i].max(1)));
                }
            }
            // Cap and clamp are independent control scalars (see the
            // loop-head note); the fetch_update needs atomicity so no
            // concurrent step loses the clamp, not an ordering edge.
            // Recalibration can shrink a tier's span below its banked
            // pressure; clamp so recovery stays within the new span
            // (budgets already floor-clamp, this keeps the drain
            // short), and book the clamp as restores so the
            // degrade/restore accounting stays balanced.
            // ordering: Relaxed — store, fetch_update, and counter.
            self.max_pressure[i].store(cap, Ordering::Relaxed);
            let clamped = self.pressure[i]
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |p| (p > cap).then_some(cap));
            if let Ok(p) = clamped {
                self.restore_events[i].fetch_add((p - cap) as u64, Ordering::Relaxed);
            }
        }
    }

    /// Effective term budget for `tier` right now: base minus the
    /// tier's own pressure, clamped to the tier floor. Exact is immune
    /// by construction (`floor_terms(total) == total`).
    pub fn budget_for(&self, tier: Tier) -> usize {
        // ordering: Relaxed — base and pressure are independent control
        // scalars; any observed mix yields a valid budget because the
        // floor/cap clamp is applied per read. Scheduler hot path.
        let base = self.base[tier.idx()].load(Ordering::Relaxed);
        let floor = tier.floor_terms(self.cfg.total_terms).min(base);
        let p = self.pressure[tier.idx()].load(Ordering::Relaxed);
        base.saturating_sub(p).clamp(floor.max(1), self.cfg.total_terms)
    }

    /// Effective *layer-granularity* [`TermBudget`] for `tier` right
    /// now — the replication-mode twin of [`TermController::budget_for`]
    /// and the uniform fallback under [`TermController::plan_for`].
    /// The weight axis keeps the calibrated cap (weight planes are
    /// pre-expanded; truncating them saves GEMMs, not expansion work);
    /// the activation axis additionally degrades with the tier's own
    /// pressure, bounded by [`Tier::layer_floor_terms`]. Exact is
    /// immune by construction.
    pub fn layer_budget_for(&self, tier: Tier) -> TermBudget {
        // ordering: Relaxed — same contract as `budget_for`: per-read
        // clamping makes any mix of base/pressure values valid.
        let base = self.layer_base[tier.idx()].load(Ordering::Relaxed);
        if base == usize::MAX {
            return TermBudget::full();
        }
        let floor = tier.layer_floor_terms().min(base).max(1);
        let p = self.pressure[tier.idx()].load(Ordering::Relaxed);
        TermBudget::new(base, base.saturating_sub(p).max(floor))
    }

    /// The [`BudgetPlan`] `tier` is served under right now — the unit
    /// the scheduler hands to budget-aware workers.
    ///
    /// * Exact: always [`BudgetPlan::full`] (immune to calibration and
    ///   pressure alike).
    /// * With per-layer calibration: the tier's base grid ceiling,
    ///   shrunk by one uniform activation-term-equivalent per step of
    ///   the tier's own pressure (never below the tier's floor
    ///   ceiling), allocated across layers by the greedy sensitivity
    ///   planner — pressure degradation shrinks the *total*, the
    ///   planner decides *where*. Plans are memoized per (tier,
    ///   effective ceiling), so the per-batch cost is a hash lookup
    ///   once each of the tier's pressure levels has been seen.
    /// * Without per-layer calibration: the uniform plan over
    ///   [`TermController::layer_budget_for`] (PR 3 behavior).
    pub fn plan_for(&self, tier: Tier) -> BudgetPlan {
        if tier == Tier::Exact {
            return BudgetPlan::full();
        }
        let mut cal = self.plan_cal.lock().unwrap();
        let Some(c) = cal.as_mut() else {
            // uniform fallback keeps the §5.3 in-grid stop: without it,
            // anytime mode would never arm the scale floor unless
            // per-layer calibration also ran
            let mut budget = self.layer_budget_for(tier);
            let floor = self.grid_scale_floor(tier);
            if floor > 0.0 && budget != TermBudget::full() {
                budget = budget.with_scale_floor(floor);
            }
            return BudgetPlan::uniform(budget);
        };
        let i = tier.idx();
        let base = c.base_ceiling[i];
        if base == usize::MAX {
            return BudgetPlan::full();
        }
        // ordering: Relaxed — pressure is a lone control scalar; the
        // ceiling clamp below keeps any observed value valid.
        let p = self.pressure[i].load(Ordering::Relaxed);
        let floor = c.floor_ceiling[i].min(base);
        let total = base.saturating_sub(p.saturating_mul(c.pressure_step[i])).max(floor);
        if let Some(plan) = c.plan_cache.get(&(i, total)) {
            return plan.clone();
        }
        let plan = BudgetPlanner::new(total)
            .with_scale_floor(self.grid_scale_floor(tier))
            .plan(&c.capped[i]);
        c.plan_cache.insert((i, total), plan.clone());
        plan
    }

    /// §5.3 scale-product stop threshold carried into planned budgets
    /// when anytime mode is on (0.0 = disabled / Exact).
    fn grid_scale_floor(&self, tier: Tier) -> f32 {
        if self.cfg.anytime {
            tier.grid_scale_floor()
        } else {
            0.0
        }
    }

    /// Push one completed request's latency into `tier`'s window digest
    /// — call next to
    /// [`Metrics::record_completed_tier`](crate::coordinator::Metrics::record_completed_tier)
    /// so the SLO loop and the metrics see the same latencies. A tier
    /// with no latency SLO never reads its window, so its writes are
    /// elided entirely (no per-reply digest traffic for `Exact` or for
    /// occupancy-only deployments).
    pub fn record_latency(&self, tier: Tier, latency_s: f64) {
        if self.cfg.slo_targets[tier.idx()] > 0.0 {
            self.digests[tier.idx()].record(latency_s);
        }
    }

    /// Windowed p99 of `tier`'s request latencies since the tier's last
    /// consumed window (`None` when the window is empty). Peek only —
    /// decisions use [`TermController::take_tier_p99`].
    pub fn tier_p99(&self, tier: Tier) -> Option<f64> {
        self.digests[tier.idx()].p99()
    }

    /// [`TermController::tier_p99`] plus a window reset: the
    /// per-decision read. Consuming the window makes each
    /// [`TermController::observe_batch`] decision see only the
    /// latencies completed since the tier's previous decision, so a
    /// drained tier's next light batch immediately reads cold instead
    /// of dragging flood-era samples along.
    pub fn take_tier_p99(&self, tier: Tier) -> Option<f64> {
        let d = &self.digests[tier.idx()];
        // a tier with no latency SLO never reads its window: skip the
        // per-batch quantile sort on the hot path, just roll the
        // window forward (observe_batch abstains on None either way)
        let armed = self.cfg.slo_targets[tier.idx()] > 0.0;
        let p = if armed { d.p99() } else { None };
        d.reset();
        p
    }

    /// Per-tier batch service-time EWMA (seconds); `None` before the
    /// tier's first successful batch.
    pub fn tier_service_ewma(&self, tier: Tier) -> Option<f64> {
        // ordering: Relaxed — a self-contained f64 snapshot (the NaN
        // sentinel travels inside the same word as the value).
        let v = f64::from_bits(self.service_ewma[tier.idx()].load(Ordering::Relaxed));
        if v.is_nan() { None } else { Some(v) }
    }

    /// Feed one formed batch's signals and take at most ONE pressure
    /// step **for that batch's tier** — the one-step-per-batch contract
    /// per tier. `occupancy` is the batch's own tier queue occupancy at
    /// formation
    /// ([`FormedBatch::tier_occupancy`](crate::coordinator::batcher::FormedBatch::tier_occupancy)
    /// — NOT the hottest queue across tiers, which is exactly the
    /// cross-tier coupling this loop exists to prevent); `service_s` is
    /// the batch's service time, folded into the tier's EWMA by CAS
    /// (`None` for failed batches: they relieve the queue signal but
    /// must not pollute the service estimate); `tier_p99` is the tier's
    /// windowed request-latency p99 (from
    /// [`TermController::take_tier_p99`]; `None` abstains).
    ///
    /// A hot signal on any axis — own queue over the high watermark,
    /// service EWMA over the global target, own p99 over the tier's
    /// SLO — raises the tier's pressure; lowering requires the tier's
    /// queue cold AND its service EWMA cold (when a target is set) AND
    /// its p99 under half its SLO (when one is set and the window is
    /// non-empty).
    ///
    /// Own-tier saturation semantics are deliberate: a tier saturated
    /// at steady state means its offered load exceeds capacity —
    /// degraded precision (never below the tier's floor) is the
    /// intended trade for *that tier*, per-tier admission control caps
    /// the damage to that tier's queue, and its pressure falls as soon
    /// as its own queue empties and its own latency cools.
    pub fn observe_batch(
        &self,
        tier: Tier,
        occupancy: f64,
        service_s: Option<f64>,
        tier_p99: Option<f64>,
    ) {
        let i = tier.idx();
        let ewma = match service_s {
            Some(s) => {
                // CAS blend: the load→blend→store sequence this
                // replaces dropped concurrent updates.
                // ordering: Relaxed — the RMW's atomicity is the whole
                // contract (no lost sample); the word is self-contained
                // (value + NaN sentinel), so no edge is published.
                let prev_bits = self.service_ewma[i]
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                        Some(blend_ewma(f64::from_bits(bits), s).to_bits())
                    })
                    .unwrap_or_else(|bits| bits);
                blend_ewma(f64::from_bits(prev_bits), s)
            }
            // ordering: Relaxed — self-contained snapshot, as above.
            None => f64::from_bits(self.service_ewma[i].load(Ordering::Relaxed)),
        };
        let target = self.cfg.service_target_s;
        let svc_hot = target > 0.0 && ewma > target;
        // an uninitialized EWMA (NaN) is cold: no evidence of heat
        let svc_cold = target <= 0.0 || ewma.is_nan() || ewma < 0.5 * target;
        let slo = self.cfg.slo_targets[i];
        let (p99_hot, p99_cold) = match tier_p99 {
            Some(p) if slo > 0.0 => (p > slo, p < 0.5 * slo),
            // no SLO for this tier, or an empty window: the latency
            // axis abstains — neither raises nor blocks restoration
            _ => (false, true),
        };
        if occupancy > self.cfg.high_watermark || svc_hot || p99_hot {
            self.raise_pressure(tier);
        } else if occupancy < self.cfg.low_watermark && svc_cold && p99_cold {
            self.lower_pressure(tier);
        }
    }

    fn raise_pressure(&self, tier: Tier) {
        let i = tier.idx();
        // ordering: Relaxed — the CAS guarantees exactly-one-step per
        // observed level (a racing step makes this one a no-op, which
        // is the one-step-per-batch contract); event counters are
        // statistics. No payload is published under the pressure word.
        let max_p = self.max_pressure[i].load(Ordering::Relaxed);
        let p = self.pressure[i].load(Ordering::Relaxed);
        if p < max_p
            && self.pressure[i]
                .compare_exchange(p, p + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            // ordering: Relaxed — event counter, a statistic.
            self.degrade_events[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    fn lower_pressure(&self, tier: Tier) {
        let i = tier.idx();
        // ordering: Relaxed — mirror of `raise_pressure`: CAS for the
        // step contract, counters are statistics.
        let p = self.pressure[i].load(Ordering::Relaxed);
        if p > 0
            && self.pressure[i]
                .compare_exchange(p, p - 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            self.restore_events[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One tier's current pressure (degradation steps applied to that
    /// tier alone).
    pub fn tier_pressure(&self, tier: Tier) -> usize {
        // ordering: Relaxed — observability read of a lone scalar.
        self.pressure[tier.idx()].load(Ordering::Relaxed)
    }

    /// Hottest per-tier pressure — aggregate observability; control is
    /// per tier (see [`TermController::tier_pressure`]).
    pub fn pressure(&self) -> usize {
        // ordering: Relaxed — observability read of lone scalars.
        self.pressure.iter().map(|p| p.load(Ordering::Relaxed)).max().unwrap_or(0)
    }

    /// Estimated max-residual at `terms` from the calibration data;
    /// `None` before calibration or out of the observed range.
    pub fn estimated_loss(&self, terms: usize) -> Option<f32> {
        let conv = self.convergence.lock().unwrap();
        if terms == 0 {
            return None;
        }
        conv.get(terms - 1).copied()
    }

    /// Smallest tolerance across a batch's tiers, for anytime stopping;
    /// `None` when any tier is Exact (never stop early).
    pub fn batch_tolerance(&self, tiers: impl IntoIterator<Item = Tier>) -> Option<f32> {
        let mut min_tol: Option<f32> = None;
        for t in tiers {
            match t.tolerance() {
                None => return None,
                Some(tol) => {
                    min_tol = Some(match min_tol {
                        Some(m) => m.min(tol),
                        None => tol,
                    });
                }
            }
        }
        min_tol
    }

    pub fn snapshot(&self) -> QosSnapshot {
        // ordering: Relaxed — an observability snapshot; each counter
        // is independently meaningful and tear-free on its own.
        let tier_degrade_events: [u64; NUM_TIERS] =
            std::array::from_fn(|i| self.degrade_events[i].load(Ordering::Relaxed));
        let tier_restore_events: [u64; NUM_TIERS] =
            std::array::from_fn(|i| self.restore_events[i].load(Ordering::Relaxed));
        QosSnapshot {
            pressures: std::array::from_fn(|i| self.tier_pressure(Tier::ALL[i])),
            budgets: std::array::from_fn(|i| self.budget_for(Tier::ALL[i])),
            layer_budgets: std::array::from_fn(|i| self.layer_budget_for(Tier::ALL[i])),
            plan_ceilings: std::array::from_fn(|i| self.plan_for(Tier::ALL[i]).total_grid_terms()),
            tier_p99: std::array::from_fn(|i| self.tier_p99(Tier::ALL[i])),
            degrade_events: tier_degrade_events.iter().sum(),
            restore_events: tier_restore_events.iter().sum(),
            tier_degrade_events,
            tier_restore_events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, Tensor};
    use crate::xint::{BitSpec, ExpandConfig};
    use std::sync::Arc;

    #[test]
    fn uncalibrated_budgets_follow_tier_defaults() {
        let c = TermController::new(QosConfig::new(8));
        assert_eq!(c.budget_for(Tier::Exact), 8);
        assert!(c.budget_for(Tier::Balanced) <= 8);
        assert!(c.budget_for(Tier::BestEffort) >= 1);
    }

    #[test]
    fn calibration_orders_budgets_by_tolerance() {
        let mut mon = ExpansionMonitor::new();
        let mut rng = Rng::seed(71);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 8);
        for _ in 0..3 {
            mon.observe(&Tensor::randn(&[32, 32], 1.0, &mut rng), &cfg).unwrap();
        }
        let c = TermController::new(QosConfig::new(8));
        c.calibrate(&mon);
        let b: Vec<usize> = Tier::ALL.iter().map(|&t| c.budget_for(t)).collect();
        assert_eq!(b[0], 8, "exact runs the full series");
        // looser tolerance ⇒ no more terms
        assert!(b.windows(2).all(|w| w[1] <= w[0]), "{b:?}");
        assert!(b[3] >= 1);
        // estimated loss is monotone non-increasing in terms
        let l1 = c.estimated_loss(1).unwrap();
        let l8 = c.estimated_loss(8).unwrap();
        assert!(l8 <= l1);
    }

    #[test]
    fn layer_budgets_follow_tier_ladder_and_pressure() {
        let c = TermController::new(QosConfig::new(8));
        assert_eq!(c.layer_budget_for(Tier::Exact), TermBudget::full());
        let be = c.layer_budget_for(Tier::BestEffort);
        assert_eq!((be.w_terms, be.a_terms), (1, 1));
        let bal = c.layer_budget_for(Tier::Balanced);
        let thr = c.layer_budget_for(Tier::Throughput);
        assert!(bal.a_terms >= be.a_terms);
        // Balanced's own pressure degrades ITS activation axis down to
        // its layer floor — and no other tier's
        for _ in 0..10 {
            c.observe_batch(Tier::Balanced, 0.95, None, None);
        }
        assert_eq!(c.layer_budget_for(Tier::Exact), TermBudget::full(), "exact immune");
        let bal_hot = c.layer_budget_for(Tier::Balanced);
        assert_eq!(bal_hot.a_terms, Tier::Balanced.layer_floor_terms());
        assert_eq!(bal_hot.w_terms, bal.w_terms, "weight axis is pressure-free");
        assert_eq!(
            c.layer_budget_for(Tier::Throughput),
            thr,
            "a Balanced flood must not move Throughput's layer budget"
        );
        // drain restores
        for _ in 0..20 {
            c.observe_batch(Tier::Balanced, 0.0, None, None);
        }
        assert_eq!(c.layer_budget_for(Tier::Balanced), bal);
        // snapshot carries the layer ladder
        let s = c.snapshot();
        assert_eq!(s.layer_budgets[Tier::Exact.idx()], TermBudget::full());
        assert_eq!(s.layer_budgets[Tier::BestEffort.idx()].a_terms, 1);
    }

    #[test]
    fn calibration_sets_layer_budgets_from_monitor() {
        let mut mon = ExpansionMonitor::new();
        let mut rng = Rng::seed(72);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 8);
        for _ in 0..3 {
            mon.observe(&Tensor::randn(&[32, 32], 1.0, &mut rng), &cfg).unwrap();
        }
        let c = TermController::new(QosConfig::new(8));
        c.calibrate(&mon);
        assert_eq!(c.layer_budget_for(Tier::Exact), TermBudget::full());
        let a_caps: Vec<usize> = [Tier::Balanced, Tier::Throughput, Tier::BestEffort]
            .iter()
            .map(|&t| c.layer_budget_for(t).a_terms)
            .collect();
        // looser tolerance ⇒ no more layer terms
        assert!(a_caps.windows(2).all(|w| w[1] <= w[0]), "{a_caps:?}");
        // and each calibrated cap meets its tier tolerance per the monitor
        for &t in &[Tier::Balanced, Tier::Throughput, Tier::BestEffort] {
            let cap = c.layer_budget_for(t).a_terms;
            if let (Some(loss), Some(tol)) = (mon.max_diff_at(cap), t.tolerance()) {
                assert!(loss < tol, "{t}: loss {loss} at cap {cap} vs tol {tol}");
            }
        }
    }

    fn test_profiles() -> Vec<LayerGridProfile> {
        // first/last exempt, three interior layers with geometric
        // curves of very different magnitudes (INT4-ish ratio 16)
        let curve = |first: f32| -> Vec<f32> {
            (0..4).map(|t| first / 16f32.powi(t as i32)).collect()
        };
        let interior = |first: f32| LayerGridProfile {
            w_terms: 2,
            a_terms: 4,
            exempt: false,
            max_diff: curve(first),
        };
        vec![
            LayerGridProfile { w_terms: 1, a_terms: 1, exempt: true, max_diff: vec![0.01] },
            interior(4.0),
            interior(0.25),
            interior(0.02),
            LayerGridProfile { w_terms: 1, a_terms: 1, exempt: true, max_diff: vec![0.01] },
        ]
    }

    #[test]
    fn plan_for_without_layer_calibration_is_uniform_fallback() {
        let c = TermController::new(QosConfig::new(8));
        assert_eq!(c.plan_for(Tier::Exact), BudgetPlan::full());
        let p = c.plan_for(Tier::BestEffort);
        assert!(p.is_uniform());
        assert_eq!(p.budget_for(0), c.layer_budget_for(Tier::BestEffort));
        let s = c.snapshot();
        assert_eq!(s.plan_ceilings, [None; NUM_TIERS]);
    }

    #[test]
    fn plan_for_allocates_tier_ceiling_by_sensitivity() {
        let c = TermController::new(QosConfig::new(8));
        c.calibrate_layers(test_profiles());
        // Exact stays full regardless of calibration
        assert_eq!(c.plan_for(Tier::Exact), BudgetPlan::full());
        let plan = c.plan_for(Tier::Throughput);
        assert!(!plan.is_uniform());
        assert_eq!(plan.layer_count(), 5);
        // §5.1 exempt layers pinned full
        assert_eq!(plan.budget_for(0), TermBudget::full());
        assert_eq!(plan.budget_for(4), TermBudget::full());
        // the ceiling equals the uniform allocation's cost at the
        // tier's default cap (2 for Throughput) = 3 layers × 2w × 2a
        assert_eq!(plan.total_grid_terms(), Some(12));
        // the sensitive layer outranks the robust one
        assert!(plan.budget_for(1).a_terms >= plan.budget_for(3).a_terms);
        // ladder: a stricter tier plans at least as large a ceiling
        let bal = c.plan_for(Tier::Balanced).total_grid_terms().unwrap();
        let thr = c.plan_for(Tier::Throughput).total_grid_terms().unwrap();
        let be = c.plan_for(Tier::BestEffort).total_grid_terms().unwrap();
        assert!(bal >= thr && thr >= be, "{bal} {thr} {be}");
        // snapshot surfaces the ceilings
        let s = c.snapshot();
        assert_eq!(s.plan_ceilings[Tier::Exact.idx()], None);
        assert_eq!(s.plan_ceilings[Tier::Throughput.idx()], Some(thr));
    }

    #[test]
    fn pressure_shrinks_plan_ceiling_and_replans_exact_immune() {
        let c = TermController::new(QosConfig::new(8));
        c.calibrate_layers(test_profiles());
        let cold = c.plan_for(Tier::Balanced).total_grid_terms().unwrap();
        let thr_cold = c.plan_for(Tier::Throughput).total_grid_terms().unwrap();
        for _ in 0..3 {
            c.observe_batch(Tier::Balanced, 0.95, None, None);
        }
        let hot = c.plan_for(Tier::Balanced).total_grid_terms().unwrap();
        assert!(hot < cold, "pressure must shrink the ceiling: {hot} !< {cold}");
        assert_eq!(c.plan_for(Tier::Exact), BudgetPlan::full(), "exact immune");
        assert_eq!(
            c.plan_for(Tier::Throughput).total_grid_terms(),
            Some(thr_cold),
            "a Balanced flood must not shrink Throughput's ceiling"
        );
        // the floor holds under arbitrary pressure: every plannable
        // layer still gets at least the tier's layer floor
        for _ in 0..100 {
            c.observe_batch(Tier::Balanced, 1.0, None, None);
        }
        let floored = c.plan_for(Tier::Balanced);
        let floor_ceiling =
            BudgetPlanner::uniform_cost(&test_profiles(), Tier::Balanced.layer_floor_terms());
        assert_eq!(floored.total_grid_terms(), Some(floor_ceiling));
        for i in [1usize, 2, 3] {
            assert!(floored.budget_for(i).a_terms >= 1);
        }
        // drain restores the cold ceiling
        for _ in 0..200 {
            c.observe_batch(Tier::Balanced, 0.0, None, None);
        }
        assert_eq!(c.plan_for(Tier::Balanced).total_grid_terms(), Some(cold));
    }

    #[test]
    fn replication_pools_of_one_can_still_ramp_pressure() {
        // the pool-prefix cap of total_terms - 1 used to pin a
        // single-worker replication pool at zero pressure, so plan
        // ceilings could never degrade end-to-end; the per-tier cap
        // now covers every degradable axis
        let c = TermController::new(QosConfig::new(1));
        c.calibrate_layers(test_profiles());
        let cold = c.plan_for(Tier::Throughput).total_grid_terms().unwrap();
        c.observe_batch(Tier::Throughput, 0.95, None, None);
        assert_eq!(c.tier_pressure(Tier::Throughput), 1);
        let hot = c.plan_for(Tier::Throughput).total_grid_terms().unwrap();
        assert!(hot < cold, "{hot} !< {cold}");
        assert_eq!(c.plan_for(Tier::Exact), BudgetPlan::full());
        c.observe_batch(Tier::Throughput, 0.0, None, None);
        assert_eq!(c.tier_pressure(Tier::Throughput), 0);
    }

    #[test]
    fn planned_spend_matches_scalar_path_when_cap_truncates_weights() {
        // BestEffort's calibrated cap (1) is below the interior weight
        // axis (k=2): the plan must cap the weight axis exactly like
        // layer_budget_for does, so enabling per-layer calibration
        // never spends MORE than the scalar path it replaces
        let c = TermController::new(QosConfig::new(8));
        let scalar = c.layer_budget_for(Tier::BestEffort);
        assert_eq!((scalar.w_terms, scalar.a_terms), (1, 1));
        c.calibrate_layers(test_profiles());
        let plan = c.plan_for(Tier::BestEffort);
        for i in [1usize, 2, 3] {
            let b = plan.budget_for(i);
            assert_eq!(
                (b.w_terms, b.a_terms),
                (scalar.w_terms, scalar.a_terms),
                "layer {i}: planned {b} must not outspend scalar {scalar}"
            );
        }
        // total ceiling = the scalar path's exact grid cost (3 × 1×1)
        assert_eq!(plan.total_grid_terms(), Some(3));
    }

    #[test]
    fn anytime_carries_tier_scale_floor_into_plans() {
        let c = TermController::new(QosConfig::new(8).with_anytime(true));
        c.calibrate_layers(test_profiles());
        let plan = c.plan_for(Tier::Throughput);
        assert_eq!(plan.budget_for(1).scale_floor, Tier::Throughput.grid_scale_floor());
        assert_eq!(plan.budget_for(0).scale_floor, 0.0, "exempt layers carry no stop");
        // without anytime the floor stays off
        let c2 = TermController::new(QosConfig::new(8));
        c2.calibrate_layers(test_profiles());
        assert_eq!(c2.plan_for(Tier::Throughput).budget_for(1).scale_floor, 0.0);
        // the uniform fallback (no per-layer calibration) carries the
        // floor too — anytime must arm the in-grid stop on every
        // serving path, and Exact stays full
        let c3 = TermController::new(QosConfig::new(8).with_anytime(true));
        let fb = c3.plan_for(Tier::Throughput);
        assert!(fb.is_uniform());
        assert_eq!(fb.budget_for(0).scale_floor, Tier::Throughput.grid_scale_floor());
        assert_eq!(c3.plan_for(Tier::Exact), BudgetPlan::full());
    }

    #[test]
    fn pressure_degrades_and_restores_only_the_observed_tier() {
        let c = TermController::new(QosConfig::new(8));
        let before = c.budget_for(Tier::Balanced);
        let thr_before = c.budget_for(Tier::Throughput);
        // sustained Balanced overload: ITS pressure ramps one step per
        // batch, saturating at the tier's own degradation span
        for _ in 0..4 {
            c.observe_batch(Tier::Balanced, 0.9, None, None);
        }
        assert!(c.tier_pressure(Tier::Balanced) >= 1);
        assert_eq!(c.budget_for(Tier::Exact), 8, "exact is immune");
        let degraded = c.budget_for(Tier::Balanced);
        assert!(degraded < before, "{degraded} !< {before}");
        assert!(degraded >= Tier::Balanced.floor_terms(8));
        // the flood is confined: no other tier moved
        assert_eq!(c.budget_for(Tier::Throughput), thr_before);
        assert_eq!(c.tier_pressure(Tier::Throughput), 0);
        assert_eq!(c.tier_pressure(Tier::BestEffort), 0);
        // drain: pressure falls, budget restored
        for _ in 0..8 {
            c.observe_batch(Tier::Balanced, 0.0, None, None);
        }
        assert_eq!(c.tier_pressure(Tier::Balanced), 0);
        assert_eq!(c.pressure(), 0, "aggregate view agrees once every tier is cold");
        assert_eq!(c.budget_for(Tier::Balanced), before);
        let s = c.snapshot();
        let bi = Tier::Balanced.idx();
        assert!(s.tier_degrade_events[bi] >= 1 && s.tier_restore_events[bi] >= 1);
        assert_eq!(s.tier_degrade_events[Tier::Throughput.idx()], 0);
        assert_eq!(s.degrade_events, s.tier_degrade_events.iter().sum::<u64>());
    }

    #[test]
    fn pressure_never_breaks_tier_floors() {
        let c = TermController::new(QosConfig::new(4));
        for tier in Tier::ALL {
            for _ in 0..100 {
                c.observe_batch(tier, 1.0, None, None);
            }
        }
        assert_eq!(c.budget_for(Tier::Exact), 4);
        assert_eq!(c.budget_for(Tier::Balanced), Tier::Balanced.floor_terms(4));
        assert_eq!(c.budget_for(Tier::Throughput), 1);
        assert_eq!(c.budget_for(Tier::BestEffort), 1);
        assert_eq!(c.tier_pressure(Tier::Exact), 0, "exact never banks pressure");
    }

    #[test]
    fn service_time_signal_raises_pressure_per_tier() {
        let c = TermController::new(QosConfig::new(8).with_service_target(0.010));
        for _ in 0..3 {
            c.observe_batch(Tier::Balanced, 0.0, Some(0.050), None);
        }
        assert!(c.tier_pressure(Tier::Balanced) > 0);
        assert_eq!(c.tier_pressure(Tier::Throughput), 0, "EWMAs are per tier");
        for _ in 0..20 {
            c.observe_batch(Tier::Balanced, 0.0, Some(0.001), None);
        }
        assert_eq!(c.tier_pressure(Tier::Balanced), 0);
    }

    #[test]
    fn one_step_per_batch_even_with_all_signals_hot() {
        // queue hot AND service hot AND p99 hot in one observation must
        // move ONE step, not three (the PR 1 double-stepping bug's
        // per-tier descendant)
        let c = TermController::new(QosConfig::new(8).with_service_target(0.010));
        c.observe_batch(Tier::Balanced, 0.95, Some(0.100), Some(10.0));
        assert_eq!(c.tier_pressure(Tier::Balanced), 1, "all-hot batch steps exactly once");
        // cold queue but hot service EWMA: still one step up, not a
        // raise+lower wash
        c.observe_batch(Tier::Balanced, 0.0, Some(0.100), None);
        assert_eq!(c.tier_pressure(Tier::Balanced), 2);
    }

    #[test]
    fn lowering_requires_every_axis_cold_and_cap_bounds_the_ramp() {
        let c = TermController::new(QosConfig::new(8).with_service_target(0.010));
        for _ in 0..5 {
            c.observe_batch(Tier::Balanced, 0.9, Some(0.050), None);
        }
        // Balanced (uncalibrated, total 8) can only express 2 steps of
        // degradation (base 4 → floor 2): pressure saturates there so
        // recovery is never more than 2 cold decisions away
        let p = c.tier_pressure(Tier::Balanced);
        assert_eq!(p, 2, "pressure must cap at the tier's degradation span");
        let s = c.snapshot();
        assert_eq!(s.tier_degrade_events[Tier::Balanced.idx()], 2, "capped steps are not events");
        // queue drained but the service EWMA is still hot: hold, don't
        // restore precision into an overloaded pool
        c.observe_batch(Tier::Balanced, 0.0, Some(0.050), None);
        assert_eq!(c.tier_pressure(Tier::Balanced), 2);
        // a hot windowed p99 alone also blocks restoration
        c.observe_batch(Tier::Balanced, 0.0, Some(0.0001), Some(10.0));
        assert!(c.tier_pressure(Tier::Balanced) >= 2);
        for _ in 0..40 {
            c.observe_batch(Tier::Balanced, 0.0, Some(0.0001), Some(0.0001));
        }
        assert_eq!(c.tier_pressure(Tier::Balanced), 0);
    }

    #[test]
    fn slo_pressure_is_per_tier_and_hysteretic() {
        let c = TermController::new(QosConfig::new(8).with_slo_target(Tier::Throughput, 0.010));
        // own-tier p99 over its own target → one step up
        c.observe_batch(Tier::Throughput, 0.0, None, Some(0.050));
        assert_eq!(c.tier_pressure(Tier::Throughput), 1);
        assert_eq!(c.tier_pressure(Tier::Balanced), 0, "the SLO breach is confined");
        // inside the hysteresis band (half target .. target): hold
        c.observe_batch(Tier::Throughput, 0.0, None, Some(0.007));
        assert_eq!(c.tier_pressure(Tier::Throughput), 1);
        // an empty window abstains — a cold queue alone restores
        c.observe_batch(Tier::Throughput, 0.0, None, None);
        assert_eq!(c.tier_pressure(Tier::Throughput), 0);
        // below half target restores too
        c.observe_batch(Tier::Throughput, 0.0, None, Some(0.050));
        c.observe_batch(Tier::Throughput, 0.0, None, Some(0.004));
        assert_eq!(c.tier_pressure(Tier::Throughput), 0);
        // a tier with no SLO (Exact's default) never latency-steps
        c.observe_batch(Tier::Exact, 0.0, None, Some(10.0));
        assert_eq!(c.tier_pressure(Tier::Exact), 0);
    }

    #[test]
    fn latency_digest_windows_p99_per_tier() {
        let c = TermController::new(QosConfig::new(4));
        assert_eq!(c.tier_p99(Tier::Balanced), None);
        for i in 1..=100u32 {
            c.record_latency(Tier::Balanced, f64::from(i) * 1e-3);
        }
        let p = c.tier_p99(Tier::Balanced).unwrap();
        assert!((p - 0.09901).abs() < 1e-6, "{p}");
        // other tiers' windows are independent
        assert_eq!(c.tier_p99(Tier::Throughput), None);
        // the take-variant consumes the window (one window per decision)
        assert!(c.take_tier_p99(Tier::Balanced).is_some());
        assert_eq!(c.tier_p99(Tier::Balanced), None);
        // ring wrap: only the freshest DIGEST_CAP samples define the
        // quantile once the window overflows
        for _ in 0..500 {
            c.record_latency(Tier::Balanced, 1.0);
        }
        for _ in 0..256 {
            c.record_latency(Tier::Balanced, 0.001);
        }
        assert!(c.take_tier_p99(Tier::Balanced).unwrap() < 0.01);
    }

    #[test]
    fn service_ewma_blends_per_tier_with_nan_init_sentinel() {
        let c = TermController::new(QosConfig::new(8).with_service_target(0.010));
        assert_eq!(c.tier_service_ewma(Tier::Balanced), None);
        // a genuine ~0 s first sample INITIALIZES the filter (the old
        // `prev == 0.0` sentinel treated it as "unset", so the next
        // sample replaced the filter instead of blending in)
        c.observe_batch(Tier::Balanced, 0.5, Some(0.0), None);
        assert_eq!(c.tier_service_ewma(Tier::Balanced), Some(0.0));
        c.observe_batch(Tier::Balanced, 0.5, Some(0.012), None);
        let e = c.tier_service_ewma(Tier::Balanced).unwrap();
        assert!((e - 0.0024).abs() < 1e-12, "blend, not reset: {e}");
        // the blended EWMA sits under the target → no pressure; the
        // reset bug would have jumped to 0.012 > target and stepped
        assert_eq!(c.tier_pressure(Tier::Balanced), 0);
        // EWMAs are per tier
        assert_eq!(c.tier_service_ewma(Tier::Throughput), None);
        // contrast: an uninitialized filter adopts the first sample whole
        let c2 = TermController::new(QosConfig::new(8).with_service_target(0.010));
        c2.observe_batch(Tier::Balanced, 0.5, Some(0.012), None);
        assert_eq!(c2.tier_service_ewma(Tier::Balanced), Some(0.012));
        assert_eq!(c2.tier_pressure(Tier::Balanced), 1);
    }

    #[test]
    fn recalibration_clamps_banked_pressure_to_the_new_span() {
        let c = TermController::new(QosConfig::new(8));
        for _ in 0..5 {
            c.observe_batch(Tier::Balanced, 0.95, None, None);
        }
        assert_eq!(c.tier_pressure(Tier::Balanced), 2, "uncalibrated span is 2");
        // a stream that converges at one term collapses every tier's
        // degradation span to zero — banked pressure must not outlive
        // the span it was drawn against, or recovery takes longer than
        // the documented <= span cold decisions
        let mut mon = ExpansionMonitor::new();
        let mut rng = Rng::seed(77);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 8);
        mon.observe(&Tensor::randn(&[8, 8], 1e-7, &mut rng), &cfg).unwrap();
        c.calibrate(&mon);
        assert_eq!(c.tier_pressure(Tier::Balanced), 0, "pressure clamped to the new span");
        assert_eq!(c.budget_for(Tier::Balanced), 1, "calibrated base applies immediately");
        // the clamp is booked as restores: degrade - restore == pressure
        let s = c.snapshot();
        let bi = Tier::Balanced.idx();
        assert_eq!(s.tier_degrade_events[bi], s.tier_restore_events[bi]);
    }

    #[test]
    fn failed_batch_signals_relieve_but_never_heat() {
        // any real service sample would trip this hair-trigger target
        let c = TermController::new(QosConfig::new(8).with_service_target(1e-12));
        // a failed batch (service None) at a hot queue still raises —
        // occupancy is a real signal regardless of outcome
        c.observe_batch(Tier::Balanced, 0.95, None, None);
        assert_eq!(c.tier_pressure(Tier::Balanced), 1);
        assert_eq!(c.tier_service_ewma(Tier::Balanced), None, "failures stay out of the EWMA");
        // and a failed batch at a cold queue still relieves
        c.observe_batch(Tier::Balanced, 0.0, None, None);
        assert_eq!(c.tier_pressure(Tier::Balanced), 0);
    }

    #[test]
    fn concurrent_observations_keep_pressure_accounting_exact() {
        // the load→blend→store EWMA dropped concurrent updates; the CAS
        // rewrite folds every sample, and degrade/restore events are
        // counted only on successful pressure CASes, so the invariant
        // degrade - restore == pressure holds under any interleaving
        let c = Arc::new(TermController::new(QosConfig::new(8).with_service_target(0.5)));
        let hot: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        c.observe_batch(Tier::Balanced, 1.0, Some(1.0), None);
                    }
                })
            })
            .collect();
        for h in hot {
            h.join().unwrap();
        }
        let i = Tier::Balanced.idx();
        // identical samples: the blend's fixed point is the sample
        assert_eq!(c.tier_service_ewma(Tier::Balanced), Some(1.0));
        let s = c.snapshot();
        assert!(s.pressures[i] >= 1);
        assert_eq!(s.tier_degrade_events[i] - s.tier_restore_events[i], s.pressures[i] as u64);
        let cold: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        c.observe_batch(Tier::Balanced, 0.0, Some(0.0), None);
                    }
                })
            })
            .collect();
        for h in cold {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert_eq!(s.pressures[i], 0);
        assert_eq!(s.tier_degrade_events[i], s.tier_restore_events[i]);
    }

    #[test]
    fn batch_tolerance_is_strictest_present() {
        let c = TermController::new(QosConfig::new(4));
        assert_eq!(c.batch_tolerance([Tier::Exact, Tier::BestEffort]), None);
        let t = c.batch_tolerance([Tier::Throughput, Tier::Balanced]).unwrap();
        assert_eq!(t, Tier::Balanced.tolerance().unwrap());
        assert_eq!(c.batch_tolerance([]), None);
    }
}

/// Loom models for the controller's lock-free signal paths. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_model_`
/// (see CONCURRENCY.md).
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use crate::util::sync::{thread, Arc};

    /// Two concurrent `observe_batch` calls fold their service samples
    /// into one tier's EWMA. The CAS `fetch_update` must not lose
    /// either sample: the final filter state is exactly one of the two
    /// serialized blend orders, never a lone sample (the lost-update
    /// outcome of the load→blend→store sequence the CAS replaced) and
    /// never a torn mix. Occupancy 0.5 sits between the default
    /// watermarks and no SLO/service target is set, so the pressure
    /// loop abstains and the EWMA is the whole story.
    #[test]
    fn loom_model_ewma_cas_never_loses_a_sample() {
        loom::model(|| {
            let ctl = Arc::new(TermController::new(QosConfig::new(8)));
            let tier = Tier::Throughput;
            let handles: Vec<_> = [1.0f64, 3.0]
                .into_iter()
                .map(|s| {
                    let ctl = Arc::clone(&ctl);
                    thread::spawn(move || ctl.observe_batch(tier, 0.5, Some(s), None))
                })
                .collect();
            // Concurrent observability read: NaN sentinel (None) or a
            // legal intermediate — never a half-written word.
            if let Some(v) = ctl.tier_service_ewma(tier) {
                let legal = [
                    blend_ewma(f64::NAN, 1.0),
                    blend_ewma(f64::NAN, 3.0),
                    blend_ewma(blend_ewma(f64::NAN, 1.0), 3.0),
                    blend_ewma(blend_ewma(f64::NAN, 3.0), 1.0),
                ];
                assert!(legal.contains(&v), "mid-race EWMA is not a serialized state: {v}");
            }
            for h in handles {
                h.join().unwrap();
            }
            let got = ctl.tier_service_ewma(tier).expect("EWMA initialized after two samples");
            let a = blend_ewma(blend_ewma(f64::NAN, 1.0), 3.0);
            let b = blend_ewma(blend_ewma(f64::NAN, 3.0), 1.0);
            assert!(got == a || got == b, "lost EWMA update: got {got}, want {a} or {b}");
            // Neutral signals: the pressure loop must not have stepped.
            assert_eq!(ctl.tier_pressure(tier), 0);
            let s = ctl.snapshot();
            assert_eq!(s.tier_degrade_events[tier.idx()], 0);
            assert_eq!(s.tier_restore_events[tier.idx()], 0);
        });
    }

    /// `record_latency` vs `take_tier_p99`: the window consume is
    /// atomic. A racing reader may see the sample once, may strand it
    /// (reset overwriting a just-claimed slot — the documented bounded
    /// staleness), and may transiently read a claimed-but-unwritten
    /// slot as 0.0 — but the sample is never surfaced twice and no
    /// phantom value ever appears.
    #[test]
    fn loom_model_digest_window_consume_is_atomic() {
        loom::model(|| {
            let tier = Tier::Balanced;
            let cfg = QosConfig::new(8).with_slo_target(tier, 1.0);
            let ctl = Arc::new(TermController::new(cfg));
            let w = {
                let ctl = Arc::clone(&ctl);
                thread::spawn(move || ctl.record_latency(tier, 5.0))
            };
            let take1 = ctl.take_tier_p99(tier);
            if let Some(v) = take1 {
                assert!(v == 0.0 || v == 5.0, "phantom latency surfaced mid-race: {v}");
            }
            w.join().unwrap();
            let take2 = ctl.take_tier_p99(tier);
            if let Some(v) = take2 {
                assert_eq!(v, 5.0, "phantom latency after quiescence");
            }
            assert!(
                !(take1 == Some(5.0) && take2 == Some(5.0)),
                "one sample surfaced in two windows"
            );
            if take1.is_some() {
                assert!(take2.is_none(), "consumed window resurfaced: {take2:?}");
            }
        });
    }
}
