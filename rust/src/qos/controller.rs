//! Term controller — translates tier tolerances into basis-term budgets
//! and degrades those budgets under load instead of shedding requests.
//!
//! Calibration uses [`ExpansionMonitor`] convergence data (§5.3): a
//! tier's base budget is the smallest term count whose observed
//! max-residual is below the tier tolerance. At serve time the
//! controller takes **one decision per formed batch**
//! ([`TermController::observe_batch`]): the hottest per-tier queue
//! occupancy (each tier's depth over its own cap, from the per-tier
//! batcher queues) and the batch service-time EWMA feed a single
//! pressure step — up, down, or hold. Each pressure step removes one
//! term from every non-Exact tier, bounded below by the tier's floor.
//! When the queues drain, pressure falls and full precision is
//! restored — precision degrades, availability does not.
//!
//! With per-layer calibration attached
//! ([`TermController::calibrate_layers`]), each tier maps to a
//! sensitivity-planned [`BudgetPlan`] instead of one scalar layer
//! budget: the tier's **total** grid-term ceiling (the uniform
//! allocation's cost at the tier's calibrated cap) is spread across
//! layers by marginal max-diff gain, pressure shrinks the *ceiling*
//! (one uniform activation-term-equivalent per step) and replans, and
//! Exact is immune by construction ([`BudgetPlan::full`] always).

use super::tier::{Tier, NUM_TIERS};
use crate::xint::budget::{BudgetPlan, TermBudget};
use crate::xint::monitor::ExpansionMonitor;
use crate::xint::planner::{BudgetPlanner, LayerGridProfile};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Controller tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct QosConfig {
    /// total basis terms available (the worker-pool size)
    pub total_terms: usize,
    /// per-tier queue occupancy above which pressure rises (the hottest
    /// tier's depth/cap; one step per formed batch)
    pub high_watermark: f64,
    /// per-tier queue occupancy below which pressure falls
    pub low_watermark: f64,
    /// batch service time (seconds) above which pressure also rises;
    /// 0.0 disables the latency signal
    pub service_target_s: f64,
    /// enable anytime reduction: stop the prefix sum early when the
    /// marginal term's contribution falls below the batch tolerance,
    /// and carry each tier's §5.3 scale floor
    /// ([`Tier::grid_scale_floor`]) into planned layer budgets so the
    /// sorted (i, j) grid stops early too
    pub anytime: bool,
}

impl QosConfig {
    pub fn new(total_terms: usize) -> QosConfig {
        QosConfig {
            total_terms,
            high_watermark: 0.75,
            low_watermark: 0.25,
            service_target_s: 0.0,
            anytime: false,
        }
    }

    pub fn with_anytime(mut self, on: bool) -> QosConfig {
        self.anytime = on;
        self
    }

    pub fn with_service_target(mut self, target_s: f64) -> QosConfig {
        self.service_target_s = target_s;
        self
    }
}

/// Per-layer calibration state behind [`TermController::plan_for`].
#[derive(Clone, Debug)]
struct PlanCalibration {
    /// per-tier profiles with the tier's weight-axis cap already
    /// applied (mirroring the scalar path, which truncates the `i`
    /// axis at the tier cap); empty for tiers that plan a full budget
    capped: [Vec<LayerGridProfile>; NUM_TIERS],
    /// zero-pressure grid ceiling per tier (`usize::MAX` = untruncated,
    /// i.e. the tier plans a full budget)
    base_ceiling: [usize; NUM_TIERS],
    /// ceiling floor per tier: every non-exempt layer at the tier's
    /// layer floor — pressure never cuts below this
    floor_ceiling: [usize; NUM_TIERS],
    /// grid terms one pressure step removes at each tier: one
    /// activation term off every plannable layer at the tier's
    /// weight-axis cap (the uniform-equivalent of the scalar path's
    /// one-term step)
    pressure_step: [usize; NUM_TIERS],
    /// memoized plans keyed by (tier idx, effective ceiling): the
    /// greedy allocation is deterministic and pressure takes at most
    /// `total_terms` discrete values, so this stays tiny and the
    /// per-batch hot path is a hash lookup, not a replan
    plan_cache: std::collections::HashMap<(usize, usize), BudgetPlan>,
}

/// Point-in-time view of the controller (observability/reporting).
#[derive(Clone, Debug)]
pub struct QosSnapshot {
    pub pressure: usize,
    /// effective budget per tier, indexed by [`Tier::idx`]
    pub budgets: [usize; NUM_TIERS],
    /// effective layer-granularity budget per tier (replication mode,
    /// uniform fallback path)
    pub layer_budgets: [TermBudget; NUM_TIERS],
    /// per-tier planned grid ceiling (`None` before per-layer
    /// calibration and for untruncated tiers)
    pub plan_ceilings: [Option<usize>; NUM_TIERS],
    pub degrade_events: u64,
    pub restore_events: u64,
}

/// Adaptive-precision control plane shared by batcher and scheduler.
///
/// All scalar state is atomic: `budget_for` runs on the scheduler hot
/// path while pressure observations arrive from batch formation. The
/// per-layer plan calibration sits behind a mutex (`plan_for` takes it
/// once per formed batch, not per request).
#[derive(Debug)]
pub struct TermController {
    cfg: QosConfig,
    /// calibrated base budget per tier (before pressure)
    base: [AtomicUsize; NUM_TIERS],
    /// calibrated base *layer* term cap per tier (replication mode's
    /// per-axis Eq. 3 grid bound; `usize::MAX` = untruncated)
    layer_base: [AtomicUsize; NUM_TIERS],
    /// current pressure level: terms removed from non-Exact tiers
    pressure: AtomicUsize,
    degrade_events: AtomicU64,
    restore_events: AtomicU64,
    /// observed max-residual per term count (monitor copy), for
    /// estimated-precision-loss reporting; empty before calibration
    convergence: Mutex<Vec<f32>>,
    /// per-layer sensitivity calibration; `None` until
    /// [`TermController::calibrate_layers`] runs
    plan_cal: Mutex<Option<PlanCalibration>>,
    /// EWMA of batch service time (seconds, stored as f64 bits)
    service_ewma: AtomicU64,
}

impl TermController {
    pub fn new(cfg: QosConfig) -> TermController {
        assert!(cfg.total_terms >= 1, "controller needs at least one term");
        assert!(cfg.low_watermark < cfg.high_watermark, "watermarks inverted");
        let base = std::array::from_fn(|i| {
            AtomicUsize::new(Tier::ALL[i].default_budget(cfg.total_terms))
        });
        let layer_base =
            std::array::from_fn(|i| AtomicUsize::new(Tier::ALL[i].default_layer_terms()));
        TermController {
            cfg,
            base,
            layer_base,
            pressure: AtomicUsize::new(0),
            degrade_events: AtomicU64::new(0),
            restore_events: AtomicU64::new(0),
            convergence: Mutex::new(Vec::new()),
            plan_cal: Mutex::new(None),
            service_ewma: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    /// Set each tier's base budget from observed convergence: the
    /// smallest term count under the tier tolerance (§5.3 rule), all
    /// terms when the tolerance was never reached. The same rule
    /// calibrates the layer-granularity budget — the monitor measures
    /// how many series terms a tensor needs for a tolerance, which is
    /// exactly the per-axis cap a layer's Eq. 3 grid should honor.
    pub fn calibrate(&self, monitor: &ExpansionMonitor) {
        let total = self.cfg.total_terms;
        for tier in Tier::ALL {
            let (budget, layer) = match tier.tolerance() {
                None => (total, usize::MAX),
                Some(tol) => {
                    let n = monitor.optimal_terms(tol);
                    (n.unwrap_or(total).min(total), n.unwrap_or(usize::MAX))
                }
            };
            self.base[tier.idx()].store(budget.max(1), Ordering::Relaxed);
            self.layer_base[tier.idx()].store(layer.max(1), Ordering::Relaxed);
        }
        let mut conv = self.convergence.lock().unwrap();
        *conv = monitor.max_diff().to_vec();
    }

    /// Attach per-layer sensitivity calibration: each tier's plan
    /// ceiling is the *scalar* path's exact grid cost at the tier's
    /// calibrated cap — both axes clamped per layer, exactly what
    /// [`TermController::layer_budget_for`] would spend — so a planned
    /// allocation redistributes the same total, never more. The planner
    /// then spreads that total across layers by marginal max-diff gain.
    /// Call after [`TermController::calibrate`] so the per-tier caps
    /// reflect the monitor; calling it first uses the tier defaults.
    pub fn calibrate_layers(&self, profiles: Vec<LayerGridProfile>) {
        let mut base_ceiling = [usize::MAX; NUM_TIERS];
        let mut floor_ceiling = [0usize; NUM_TIERS];
        let mut pressure_step = [1usize; NUM_TIERS];
        let mut capped: [Vec<LayerGridProfile>; NUM_TIERS] = std::array::from_fn(|_| Vec::new());
        for tier in Tier::ALL {
            let cap = self.layer_base[tier.idx()].load(Ordering::Relaxed);
            if tier == Tier::Exact || cap == usize::MAX {
                continue;
            }
            let i = tier.idx();
            // mirror the scalar path's weight-axis cap so a planned
            // budget never spends GEMMs on weight terms the uniform
            // budget would have truncated
            capped[i] = profiles
                .iter()
                .map(|p| {
                    let mut p = p.clone();
                    if !p.exempt {
                        p.w_terms = p.w_terms.min(cap).max(1);
                    }
                    p
                })
                .collect();
            base_ceiling[i] = BudgetPlanner::grid_cost(&profiles, cap, cap);
            let floor = tier.layer_floor_terms();
            floor_ceiling[i] = if floor == usize::MAX {
                base_ceiling[i]
            } else {
                // pressure degrades only the activation axis (scalar
                // path semantics): the floor keeps the tier's w cap
                BudgetPlanner::grid_cost(&profiles, cap, floor)
            };
            // one activation term off every plannable layer at this
            // tier's weight cap
            pressure_step[i] = BudgetPlanner::grid_cost(&profiles, cap, 1).max(1);
        }
        let mut cal = self.plan_cal.lock().unwrap();
        *cal = Some(PlanCalibration {
            capped,
            base_ceiling,
            floor_ceiling,
            pressure_step,
            plan_cache: std::collections::HashMap::new(),
        });
    }

    /// Effective term budget for `tier` right now: base minus pressure,
    /// clamped to the tier floor. Exact is immune by construction
    /// (`floor_terms(total) == total`).
    pub fn budget_for(&self, tier: Tier) -> usize {
        let base = self.base[tier.idx()].load(Ordering::Relaxed);
        let floor = tier.floor_terms(self.cfg.total_terms).min(base);
        let p = self.pressure.load(Ordering::Relaxed);
        base.saturating_sub(p).clamp(floor.max(1), self.cfg.total_terms)
    }

    /// Effective *layer-granularity* [`TermBudget`] for `tier` right
    /// now — the replication-mode twin of [`TermController::budget_for`]
    /// and the uniform fallback under [`TermController::plan_for`].
    /// The weight axis keeps the calibrated cap (weight planes are
    /// pre-expanded; truncating them saves GEMMs, not expansion work);
    /// the activation axis additionally degrades with pressure, bounded
    /// by [`Tier::layer_floor_terms`]. Exact is immune by construction.
    pub fn layer_budget_for(&self, tier: Tier) -> TermBudget {
        let base = self.layer_base[tier.idx()].load(Ordering::Relaxed);
        if base == usize::MAX {
            return TermBudget::full();
        }
        let floor = tier.layer_floor_terms().min(base).max(1);
        let p = self.pressure.load(Ordering::Relaxed);
        TermBudget::new(base, base.saturating_sub(p).max(floor))
    }

    /// The [`BudgetPlan`] `tier` is served under right now — the unit
    /// the scheduler hands to budget-aware workers.
    ///
    /// * Exact: always [`BudgetPlan::full`] (immune to calibration and
    ///   pressure alike).
    /// * With per-layer calibration: the tier's base grid ceiling,
    ///   shrunk by one uniform activation-term-equivalent per pressure
    ///   step (never below the tier's floor ceiling), allocated across
    ///   layers by the greedy sensitivity planner — pressure
    ///   degradation shrinks the *total*, the planner decides *where*.
    ///   Plans are memoized per (tier, effective ceiling), so the
    ///   per-batch cost is a hash lookup once each pressure level has
    ///   been seen.
    /// * Without per-layer calibration: the uniform plan over
    ///   [`TermController::layer_budget_for`] (PR 3 behavior).
    pub fn plan_for(&self, tier: Tier) -> BudgetPlan {
        if tier == Tier::Exact {
            return BudgetPlan::full();
        }
        let mut cal = self.plan_cal.lock().unwrap();
        let Some(c) = cal.as_mut() else {
            // uniform fallback keeps the §5.3 in-grid stop: without it,
            // anytime mode would never arm the scale floor unless
            // per-layer calibration also ran
            let mut budget = self.layer_budget_for(tier);
            let floor = self.grid_scale_floor(tier);
            if floor > 0.0 && budget != TermBudget::full() {
                budget = budget.with_scale_floor(floor);
            }
            return BudgetPlan::uniform(budget);
        };
        let i = tier.idx();
        let base = c.base_ceiling[i];
        if base == usize::MAX {
            return BudgetPlan::full();
        }
        let p = self.pressure.load(Ordering::Relaxed);
        let floor = c.floor_ceiling[i].min(base);
        let total = base.saturating_sub(p.saturating_mul(c.pressure_step[i])).max(floor);
        if let Some(plan) = c.plan_cache.get(&(i, total)) {
            return plan.clone();
        }
        let plan = BudgetPlanner::new(total)
            .with_scale_floor(self.grid_scale_floor(tier))
            .plan(&c.capped[i]);
        c.plan_cache.insert((i, total), plan.clone());
        plan
    }

    /// §5.3 scale-product stop threshold carried into planned budgets
    /// when anytime mode is on (0.0 = disabled / Exact).
    fn grid_scale_floor(&self, tier: Tier) -> f32 {
        if self.cfg.anytime {
            tier.grid_scale_floor()
        } else {
            0.0
        }
    }

    /// Feed one formed batch's signals and take at most ONE pressure
    /// step — the one-step-per-batch contract (the PR 1 scheduler fed
    /// queue depth and service time separately, so pressure could ramp
    /// two steps per batch). `occupancy` is the hottest per-tier queue
    /// occupancy at formation (see
    /// [`FormedBatch::max_occupancy`](crate::coordinator::batcher::FormedBatch::max_occupancy));
    /// `service_s` is the batch's service time, folded into the EWMA.
    /// A hot signal on either axis raises pressure; lowering requires
    /// the queue cold AND (when a target is set) the EWMA cold too.
    ///
    /// Hottest-tier semantics are deliberate: a single saturated tier
    /// queue holds pressure up until it drains, because degrading
    /// non-Exact budgets is exactly the lever that raises throughput
    /// and drains it. A tier saturated at steady state means offered
    /// load exceeds capacity — degraded precision (never below tier
    /// floors) is the intended trade, per-tier admission control caps
    /// the damage to that tier's queue, and pressure falls as soon as
    /// the hot queue empties.
    pub fn observe_batch(&self, occupancy: f64, service_s: f64) {
        let prev = f64::from_bits(self.service_ewma.load(Ordering::Relaxed));
        let ewma = if prev == 0.0 { service_s } else { 0.8 * prev + 0.2 * service_s };
        self.service_ewma.store(ewma.to_bits(), Ordering::Relaxed);
        let target = self.cfg.service_target_s;
        let svc_hot = target > 0.0 && ewma > target;
        let svc_cold = target <= 0.0 || ewma < 0.5 * target;
        if occupancy > self.cfg.high_watermark || svc_hot {
            self.raise_pressure();
        } else if occupancy < self.cfg.low_watermark && svc_cold {
            self.lower_pressure();
        }
    }

    fn raise_pressure(&self) {
        // cap: the deepest cut still leaves every tier at its floor
        let max_p = self.cfg.total_terms.saturating_sub(1);
        let p = self.pressure.load(Ordering::Relaxed);
        if p < max_p
            && self
                .pressure
                .compare_exchange(p, p + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            self.degrade_events.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn lower_pressure(&self) {
        let p = self.pressure.load(Ordering::Relaxed);
        if p > 0
            && self
                .pressure
                .compare_exchange(p, p - 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            self.restore_events.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn pressure(&self) -> usize {
        self.pressure.load(Ordering::Relaxed)
    }

    /// Estimated max-residual at `terms` from the calibration data;
    /// `None` before calibration or out of the observed range.
    pub fn estimated_loss(&self, terms: usize) -> Option<f32> {
        let conv = self.convergence.lock().unwrap();
        if terms == 0 {
            return None;
        }
        conv.get(terms - 1).copied()
    }

    /// Smallest tolerance across a batch's tiers, for anytime stopping;
    /// `None` when any tier is Exact (never stop early).
    pub fn batch_tolerance(&self, tiers: impl IntoIterator<Item = Tier>) -> Option<f32> {
        let mut min_tol: Option<f32> = None;
        for t in tiers {
            match t.tolerance() {
                None => return None,
                Some(tol) => {
                    min_tol = Some(match min_tol {
                        Some(m) => m.min(tol),
                        None => tol,
                    });
                }
            }
        }
        min_tol
    }

    pub fn snapshot(&self) -> QosSnapshot {
        QosSnapshot {
            pressure: self.pressure(),
            budgets: std::array::from_fn(|i| self.budget_for(Tier::ALL[i])),
            layer_budgets: std::array::from_fn(|i| self.layer_budget_for(Tier::ALL[i])),
            plan_ceilings: std::array::from_fn(|i| self.plan_for(Tier::ALL[i]).total_grid_terms()),
            degrade_events: self.degrade_events.load(Ordering::Relaxed),
            restore_events: self.restore_events.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, Tensor};
    use crate::xint::{BitSpec, ExpandConfig};

    #[test]
    fn uncalibrated_budgets_follow_tier_defaults() {
        let c = TermController::new(QosConfig::new(8));
        assert_eq!(c.budget_for(Tier::Exact), 8);
        assert!(c.budget_for(Tier::Balanced) <= 8);
        assert!(c.budget_for(Tier::BestEffort) >= 1);
    }

    #[test]
    fn calibration_orders_budgets_by_tolerance() {
        let mut mon = ExpansionMonitor::new();
        let mut rng = Rng::seed(71);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 8);
        for _ in 0..3 {
            mon.observe(&Tensor::randn(&[32, 32], 1.0, &mut rng), &cfg).unwrap();
        }
        let c = TermController::new(QosConfig::new(8));
        c.calibrate(&mon);
        let b: Vec<usize> = Tier::ALL.iter().map(|&t| c.budget_for(t)).collect();
        assert_eq!(b[0], 8, "exact runs the full series");
        // looser tolerance ⇒ no more terms
        assert!(b.windows(2).all(|w| w[1] <= w[0]), "{b:?}");
        assert!(b[3] >= 1);
        // estimated loss is monotone non-increasing in terms
        let l1 = c.estimated_loss(1).unwrap();
        let l8 = c.estimated_loss(8).unwrap();
        assert!(l8 <= l1);
    }

    #[test]
    fn layer_budgets_follow_tier_ladder_and_pressure() {
        let c = TermController::new(QosConfig::new(8));
        assert_eq!(c.layer_budget_for(Tier::Exact), TermBudget::full());
        let be = c.layer_budget_for(Tier::BestEffort);
        assert_eq!((be.w_terms, be.a_terms), (1, 1));
        let bal = c.layer_budget_for(Tier::Balanced);
        assert!(bal.a_terms >= be.a_terms);
        // pressure degrades the activation axis down to the layer floor
        for _ in 0..10 {
            c.observe_batch(0.95, 0.0);
        }
        assert_eq!(c.layer_budget_for(Tier::Exact), TermBudget::full(), "exact immune");
        let bal_hot = c.layer_budget_for(Tier::Balanced);
        assert_eq!(bal_hot.a_terms, Tier::Balanced.layer_floor_terms());
        assert_eq!(bal_hot.w_terms, bal.w_terms, "weight axis is pressure-free");
        // drain restores
        for _ in 0..20 {
            c.observe_batch(0.0, 0.0);
        }
        assert_eq!(c.layer_budget_for(Tier::Balanced), bal);
        // snapshot carries the layer ladder
        let s = c.snapshot();
        assert_eq!(s.layer_budgets[Tier::Exact.idx()], TermBudget::full());
        assert_eq!(s.layer_budgets[Tier::BestEffort.idx()].a_terms, 1);
    }

    #[test]
    fn calibration_sets_layer_budgets_from_monitor() {
        let mut mon = ExpansionMonitor::new();
        let mut rng = Rng::seed(72);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 8);
        for _ in 0..3 {
            mon.observe(&Tensor::randn(&[32, 32], 1.0, &mut rng), &cfg).unwrap();
        }
        let c = TermController::new(QosConfig::new(8));
        c.calibrate(&mon);
        assert_eq!(c.layer_budget_for(Tier::Exact), TermBudget::full());
        let a_caps: Vec<usize> = [Tier::Balanced, Tier::Throughput, Tier::BestEffort]
            .iter()
            .map(|&t| c.layer_budget_for(t).a_terms)
            .collect();
        // looser tolerance ⇒ no more layer terms
        assert!(a_caps.windows(2).all(|w| w[1] <= w[0]), "{a_caps:?}");
        // and each calibrated cap meets its tier tolerance per the monitor
        for &t in &[Tier::Balanced, Tier::Throughput, Tier::BestEffort] {
            let cap = c.layer_budget_for(t).a_terms;
            if let (Some(loss), Some(tol)) = (mon.max_diff_at(cap), t.tolerance()) {
                assert!(loss < tol, "{t}: loss {loss} at cap {cap} vs tol {tol}");
            }
        }
    }

    fn test_profiles() -> Vec<LayerGridProfile> {
        // first/last exempt, three interior layers with geometric
        // curves of very different magnitudes (INT4-ish ratio 16)
        let curve = |first: f32| -> Vec<f32> {
            (0..4).map(|t| first / 16f32.powi(t as i32)).collect()
        };
        let interior = |first: f32| LayerGridProfile {
            w_terms: 2,
            a_terms: 4,
            exempt: false,
            max_diff: curve(first),
        };
        vec![
            LayerGridProfile { w_terms: 1, a_terms: 1, exempt: true, max_diff: vec![0.01] },
            interior(4.0),
            interior(0.25),
            interior(0.02),
            LayerGridProfile { w_terms: 1, a_terms: 1, exempt: true, max_diff: vec![0.01] },
        ]
    }

    #[test]
    fn plan_for_without_layer_calibration_is_uniform_fallback() {
        let c = TermController::new(QosConfig::new(8));
        assert_eq!(c.plan_for(Tier::Exact), BudgetPlan::full());
        let p = c.plan_for(Tier::BestEffort);
        assert!(p.is_uniform());
        assert_eq!(p.budget_for(0), c.layer_budget_for(Tier::BestEffort));
        let s = c.snapshot();
        assert_eq!(s.plan_ceilings, [None; NUM_TIERS]);
    }

    #[test]
    fn plan_for_allocates_tier_ceiling_by_sensitivity() {
        let c = TermController::new(QosConfig::new(8));
        c.calibrate_layers(test_profiles());
        // Exact stays full regardless of calibration
        assert_eq!(c.plan_for(Tier::Exact), BudgetPlan::full());
        let plan = c.plan_for(Tier::Throughput);
        assert!(!plan.is_uniform());
        assert_eq!(plan.layer_count(), 5);
        // §5.1 exempt layers pinned full
        assert_eq!(plan.budget_for(0), TermBudget::full());
        assert_eq!(plan.budget_for(4), TermBudget::full());
        // the ceiling equals the uniform allocation's cost at the
        // tier's default cap (2 for Throughput) = 3 layers × 2w × 2a
        assert_eq!(plan.total_grid_terms(), Some(12));
        // the sensitive layer outranks the robust one
        assert!(plan.budget_for(1).a_terms >= plan.budget_for(3).a_terms);
        // ladder: a stricter tier plans at least as large a ceiling
        let bal = c.plan_for(Tier::Balanced).total_grid_terms().unwrap();
        let thr = c.plan_for(Tier::Throughput).total_grid_terms().unwrap();
        let be = c.plan_for(Tier::BestEffort).total_grid_terms().unwrap();
        assert!(bal >= thr && thr >= be, "{bal} {thr} {be}");
        // snapshot surfaces the ceilings
        let s = c.snapshot();
        assert_eq!(s.plan_ceilings[Tier::Exact.idx()], None);
        assert_eq!(s.plan_ceilings[Tier::Throughput.idx()], Some(thr));
    }

    #[test]
    fn pressure_shrinks_plan_ceiling_and_replans_exact_immune() {
        let c = TermController::new(QosConfig::new(8));
        c.calibrate_layers(test_profiles());
        let cold = c.plan_for(Tier::Balanced).total_grid_terms().unwrap();
        for _ in 0..3 {
            c.observe_batch(0.95, 0.0);
        }
        let hot = c.plan_for(Tier::Balanced).total_grid_terms().unwrap();
        assert!(hot < cold, "pressure must shrink the ceiling: {hot} !< {cold}");
        assert_eq!(c.plan_for(Tier::Exact), BudgetPlan::full(), "exact immune");
        // the floor holds under arbitrary pressure: every plannable
        // layer still gets at least the tier's layer floor
        for _ in 0..100 {
            c.observe_batch(1.0, 0.0);
        }
        let floored = c.plan_for(Tier::Balanced);
        let floor_ceiling =
            BudgetPlanner::uniform_cost(&test_profiles(), Tier::Balanced.layer_floor_terms());
        assert_eq!(floored.total_grid_terms(), Some(floor_ceiling));
        for i in [1usize, 2, 3] {
            assert!(floored.budget_for(i).a_terms >= 1);
        }
        // drain restores the cold ceiling
        for _ in 0..200 {
            c.observe_batch(0.0, 0.0);
        }
        assert_eq!(c.plan_for(Tier::Balanced).total_grid_terms(), Some(cold));
    }

    #[test]
    fn planned_spend_matches_scalar_path_when_cap_truncates_weights() {
        // BestEffort's calibrated cap (1) is below the interior weight
        // axis (k=2): the plan must cap the weight axis exactly like
        // layer_budget_for does, so enabling per-layer calibration
        // never spends MORE than the scalar path it replaces
        let c = TermController::new(QosConfig::new(8));
        let scalar = c.layer_budget_for(Tier::BestEffort);
        assert_eq!((scalar.w_terms, scalar.a_terms), (1, 1));
        c.calibrate_layers(test_profiles());
        let plan = c.plan_for(Tier::BestEffort);
        for i in [1usize, 2, 3] {
            let b = plan.budget_for(i);
            assert_eq!(
                (b.w_terms, b.a_terms),
                (scalar.w_terms, scalar.a_terms),
                "layer {i}: planned {b} must not outspend scalar {scalar}"
            );
        }
        // total ceiling = the scalar path's exact grid cost (3 × 1×1)
        assert_eq!(plan.total_grid_terms(), Some(3));
    }

    #[test]
    fn anytime_carries_tier_scale_floor_into_plans() {
        let c = TermController::new(QosConfig::new(8).with_anytime(true));
        c.calibrate_layers(test_profiles());
        let plan = c.plan_for(Tier::Throughput);
        assert_eq!(plan.budget_for(1).scale_floor, Tier::Throughput.grid_scale_floor());
        assert_eq!(plan.budget_for(0).scale_floor, 0.0, "exempt layers carry no stop");
        // without anytime the floor stays off
        let c2 = TermController::new(QosConfig::new(8));
        c2.calibrate_layers(test_profiles());
        assert_eq!(c2.plan_for(Tier::Throughput).budget_for(1).scale_floor, 0.0);
        // the uniform fallback (no per-layer calibration) carries the
        // floor too — anytime must arm the in-grid stop on every
        // serving path, and Exact stays full
        let c3 = TermController::new(QosConfig::new(8).with_anytime(true));
        let fb = c3.plan_for(Tier::Throughput);
        assert!(fb.is_uniform());
        assert_eq!(fb.budget_for(0).scale_floor, Tier::Throughput.grid_scale_floor());
        assert_eq!(c3.plan_for(Tier::Exact), BudgetPlan::full());
    }

    #[test]
    fn pressure_degrades_and_restores_non_exact_tiers() {
        let c = TermController::new(QosConfig::new(8));
        let before = c.budget_for(Tier::Balanced);
        // sustained overload: pressure ramps one step per batch
        for _ in 0..4 {
            c.observe_batch(0.9, 0.0);
        }
        assert_eq!(c.pressure(), 4);
        assert_eq!(c.budget_for(Tier::Exact), 8, "exact is immune");
        let degraded = c.budget_for(Tier::Balanced);
        assert!(degraded < before, "{degraded} !< {before}");
        assert!(degraded >= Tier::Balanced.floor_terms(8));
        // drain: pressure falls, budget restored
        for _ in 0..8 {
            c.observe_batch(0.0, 0.0);
        }
        assert_eq!(c.pressure(), 0);
        assert_eq!(c.budget_for(Tier::Balanced), before);
        let s = c.snapshot();
        assert!(s.degrade_events >= 4 && s.restore_events >= 4);
    }

    #[test]
    fn pressure_never_breaks_tier_floors() {
        let c = TermController::new(QosConfig::new(4));
        for _ in 0..100 {
            c.observe_batch(1.0, 0.0);
        }
        assert_eq!(c.budget_for(Tier::Exact), 4);
        assert_eq!(c.budget_for(Tier::Balanced), Tier::Balanced.floor_terms(4));
        assert_eq!(c.budget_for(Tier::Throughput), 1);
        assert_eq!(c.budget_for(Tier::BestEffort), 1);
    }

    #[test]
    fn service_time_signal_raises_pressure() {
        let c = TermController::new(QosConfig::new(8).with_service_target(0.010));
        for _ in 0..3 {
            c.observe_batch(0.0, 0.050);
        }
        assert!(c.pressure() > 0);
        for _ in 0..20 {
            c.observe_batch(0.0, 0.001);
        }
        assert_eq!(c.pressure(), 0);
    }

    #[test]
    fn one_step_per_batch_even_with_both_signals_hot() {
        // queue hot AND service hot in one observation must move ONE
        // step, not two (the PR 1 double-stepping bug)
        let c = TermController::new(QosConfig::new(8).with_service_target(0.010));
        c.observe_batch(0.95, 0.100);
        assert_eq!(c.pressure(), 1, "both-hot batch must step pressure exactly once");
        // cold queue but hot service EWMA: still one step up, not a
        // raise+lower wash
        c.observe_batch(0.0, 0.100);
        assert_eq!(c.pressure(), 2);
    }

    #[test]
    fn lowering_requires_both_axes_cold_when_target_set() {
        let c = TermController::new(QosConfig::new(8).with_service_target(0.010));
        for _ in 0..3 {
            c.observe_batch(0.9, 0.050);
        }
        assert_eq!(c.pressure(), 3);
        // queue drained but the service EWMA is still hot: hold, don't
        // restore precision into an overloaded pool
        c.observe_batch(0.0, 0.050);
        assert_eq!(c.pressure(), 4, "hot service keeps raising even at empty queue");
        for _ in 0..40 {
            c.observe_batch(0.0, 0.0001);
        }
        assert_eq!(c.pressure(), 0);
    }

    #[test]
    fn batch_tolerance_is_strictest_present() {
        let c = TermController::new(QosConfig::new(4));
        assert_eq!(c.batch_tolerance([Tier::Exact, Tier::BestEffort]), None);
        let t = c.batch_tolerance([Tier::Throughput, Tier::Balanced]).unwrap();
        assert_eq!(t, Tier::Balanced.tolerance().unwrap());
        assert_eq!(c.batch_tolerance([]), None);
    }
}
