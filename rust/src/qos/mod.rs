//! QoS control plane — anytime serving over truncated series expansions.
//!
//! The paper's central object `M = M_sa + bias·M_nsy + Σ scale_i·M̃_i`
//! is a *series*: every truncation prefix is itself a valid
//! lower-precision model, and the §5.3 monitor quantifies exactly how
//! much accuracy each extra term buys. This module exploits that
//! structure to degrade **precision instead of availability** when the
//! serving stack is under pressure — a knob single-artifact PTQ
//! pipelines cannot offer.
//!
//! * [`tier`] — the request-facing [`Tier`] ladder (`Exact` /
//!   `Balanced` / `Throughput` / `BestEffort`), carried through
//!   [`coordinator::Request`](crate::coordinator::Request) and the TCP
//!   protocol's tier field, each rung carrying both a precision
//!   tolerance and a p99 latency SLO target ([`Tier::slo_target`]).
//! * [`controller`] — the [`TermController`]: calibrates per-tier term
//!   budgets from [`ExpansionMonitor`](crate::xint::ExpansionMonitor)
//!   convergence data and dynamically lowers budgets under pressure,
//!   running **one independent pressure loop per tier**: each formed
//!   batch takes exactly one step for *its own* tier
//!   ([`TermController::observe_batch`]) from that tier's own queue
//!   occupancy, its own batch service-time EWMA, and its own windowed
//!   request-latency p99 (a lock-free ring digest per tier, fed by the
//!   scheduler alongside the metrics) checked against *its own* SLO
//!   target — so degradation is confined to the violating tier and a
//!   Throughput flood cannot move Balanced's served precision. Failed
//!   batches relieve the queue signal but never enter the service/p99
//!   estimates. Pressure falls, per tier, as that tier's queue drains
//!   and its latency cools. Each tier maps to TWO budgets: the
//!   pool-prefix budget (model granularity — how many basis workers
//!   reduce) and a per-layer [`BudgetPlan`](crate::xint::BudgetPlan)
//!   ([`TermController::plan_for`]) that plan-aware replication workers
//!   index by layer position to truncate each layer's Eq. 3 GEMM grid
//!   largest-scale-first. With per-layer calibration
//!   ([`TermController::calibrate_layers`]) the plan allocates the
//!   tier's total grid ceiling across layers by sensitivity (the
//!   greedy mixed-precision loop over per-layer §5.3 curves); pressure
//!   shrinks the ceiling and replans. Without it, the plan degrades to
//!   the uniform scalar budget
//!   ([`TermController::layer_budget_for`]). 8-bit first/last layers
//!   stay exact either way.
//!
//! The batcher side ([`coordinator::batcher`](crate::coordinator::batcher))
//! keeps one bounded queue per tier, served by weighted deficit
//! round-robin with per-tier admission control, so a flood in one tier
//! can neither delay another tier's heads nor consume its queue space;
//! sheds are accounted and surfaced per tier (TCP `CODE_SHED` frames
//! carry the refusing tier).
//!
//! The scheduler side lives in
//! [`coordinator::scheduler`](crate::coordinator::scheduler): truncated
//! reduction broadcasts only to the first `n` workers of the pool —
//! valid because ⊎ prefix sums are themselves group elements — and the
//! anytime mode stops the prefix reduction early once the marginal
//! term's contribution falls below the batch tolerance (relative to
//! the leading term). The compute saving comes from the tier budget
//! (workers past the budget never run); anytime refines *within* the
//! budget, trimming reduction work and reporting the terms actually
//! consumed. Per-tier latency/terms/precision-loss observability lives
//! in [`coordinator::metrics`](crate::coordinator::metrics).

pub mod controller;
pub mod tier;

pub use controller::{QosConfig, QosSnapshot, TermController};
pub use tier::{Tier, NUM_TIERS};
