//! Quantized-graph construction: turn a trained FP [`Model`] into a
//! series-expanded [`QuantModel`] (the paper's method) with the §5.1
//! deployment policy — BN folded, per-channel weights, first/last layer
//! at 8-bit — plus the activation-range observer PTQ baselines calibrate
//! with.
//!
//! The budgeted forward consumes a [`BudgetPlan`]: every quantizable
//! layer is numbered depth-first (the same order `quantize_model`
//! assigns policies in) and indexes the plan by that position, so a
//! sensitivity-planned allocation reaches exactly the layer it was made
//! for. [`QuantModel::observe_layers`] feeds a per-layer
//! [`ExpansionMonitor`] from a calibration batch and
//! [`QuantModel::grid_profiles`] turns the observed curves into the
//! [`BudgetPlanner`](crate::xint::planner::BudgetPlanner)'s input.

use super::graph::{Layer, Model};
use crate::tensor::Tensor;
use crate::xint::budget::{BudgetPlan, ForwardStats, LayerTrace, TermBudget};
use crate::xint::layer::{LayerPolicy, XintConv2d, XintLinear};
use crate::xint::monitor::{ConfigMismatch, ExpansionMonitor};
use crate::xint::planner::LayerGridProfile;
use crate::xint::quantizer::{channel_range, Clip, Range, Symmetry};
use std::time::{Duration, Instant};

/// A quantized mirror of [`Model`]: same topology, expanded conv/linear.
#[derive(Clone, Debug)]
pub enum QuantLayer {
    Conv(XintConv2d),
    Linear(XintLinear),
    ReLU,
    Gelu,
    MaxPool2,
    GlobalAvgPool,
    Flatten,
    Residual(Vec<QuantLayer>, Vec<QuantLayer>),
    Branches(Vec<Vec<QuantLayer>>),
}

/// The quantized model.
#[derive(Clone, Debug)]
pub struct QuantModel {
    pub name: String,
    pub layers: Vec<QuantLayer>,
}

/// Collector for a traced forward: per-layer [`LayerTrace`] entries
/// stamped with ns offsets from the forward's start.
struct LayerSink {
    t0: Instant,
    entries: Vec<LayerTrace>,
}

impl LayerSink {
    fn push(&mut self, index: usize, executed: usize, planned: usize, started: Duration) {
        self.entries.push(LayerTrace {
            index,
            grid_terms: executed,
            // a resolved policy can only widen past the raw plan entry
            // (§5.1 exemption), never report less than what ran
            planned_grid: planned.max(executed),
            t_start_ns: started.as_nanos() as u64,
            t_end_ns: self.t0.elapsed().as_nanos() as u64,
        });
    }
}

/// GEMMs a budget permits against a concrete `k × t` grid: the clamped
/// axis rectangle, further capped by the budget's total grid cap.
fn planned_grid(k: usize, t: usize, budget: &TermBudget) -> usize {
    let (w, a) = budget.clamp_to(k, t);
    let grid = w * a;
    budget.grid_terms.map_or(grid, |g| g.min(grid))
}

impl QuantLayer {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        // full plan takes the legacy natural-order grid in every
        // layer, so this stays bit-identical to the pre-budget stack
        let mut stats = ForwardStats::default();
        let mut idx = 0usize;
        self.forward_with(x, &BudgetPlan::full(), &mut idx, &mut stats)
    }

    /// Plan-indexed budgeted forward: every expanded conv/linear takes
    /// the plan entry at its depth-first position `idx` (advancing the
    /// counter), resolves it against its own policy (§5.1 8-bit
    /// first/last layers stay exact) and truncates its Eq. 3 grid
    /// accordingly; `stats` accumulates the INT GEMM terms executed.
    ///
    /// INVARIANT: the depth-first position order here must stay in
    /// lockstep with `quantize_seq` (policy assignment), `observe_seq`
    /// (per-layer calibration) and `profile_seq` (planner input) —
    /// all four walk Residual main-then-short and Branches in order.
    /// An order divergence silently hands each layer another layer's
    /// budget/curve; the `observe_layers_profiles_match_plan_indexing`
    /// test pins the pairing by config.
    pub fn forward_with(
        &self,
        x: &Tensor,
        plan: &BudgetPlan,
        idx: &mut usize,
        stats: &mut ForwardStats,
    ) -> Tensor {
        self.forward_impl(x, plan, idx, stats, None)
    }

    fn forward_impl(
        &self,
        x: &Tensor,
        plan: &BudgetPlan,
        idx: &mut usize,
        stats: &mut ForwardStats,
        mut sink: Option<&mut LayerSink>,
    ) -> Tensor {
        match self {
            QuantLayer::Conv(c) => {
                let pos = *idx;
                let budget = plan.budget_for(pos);
                *idx += 1;
                let started = sink.as_ref().map(|s| s.t0.elapsed());
                let (y, executed) = c.forward_with(x, &budget);
                stats.record_layer(executed);
                if let (Some(s), Some(t_start)) = (sink, started) {
                    let exempt = c.policy.is_exempt() || c.uses_fp_fallback();
                    let planned = if exempt {
                        executed
                    } else {
                        planned_grid(c.weight.terms(), c.policy.a_terms, &budget)
                    };
                    s.push(pos, executed, planned, t_start);
                }
                y
            }
            QuantLayer::Linear(l) => {
                let pos = *idx;
                let budget = plan.budget_for(pos);
                *idx += 1;
                let started = sink.as_ref().map(|s| s.t0.elapsed());
                let (y, executed) = l.forward_with(x, &budget);
                stats.record_layer(executed);
                if let (Some(s), Some(t_start)) = (sink, started) {
                    let planned = if l.policy.is_exempt() {
                        executed
                    } else {
                        planned_grid(l.weight.terms(), l.policy.a_terms, &budget)
                    };
                    s.push(pos, executed, planned, t_start);
                }
                y
            }
            QuantLayer::ReLU => x.relu(),
            QuantLayer::Gelu => x.gelu(),
            QuantLayer::MaxPool2 => x.maxpool2(),
            QuantLayer::GlobalAvgPool => x.global_avg_pool(),
            QuantLayer::Flatten => {
                let n = x.dims()[0];
                x.reshape(&[n, x.numel() / n])
            }
            QuantLayer::Residual(main, short) => {
                let mut h = x.clone();
                for l in main {
                    h = l.forward_impl(&h, plan, idx, stats, sink.as_deref_mut());
                }
                let mut s = x.clone();
                for l in short {
                    s = l.forward_impl(&s, plan, idx, stats, sink.as_deref_mut());
                }
                h.add(&s)
            }
            QuantLayer::Branches(bs) => {
                let outs: Vec<Tensor> = bs
                    .iter()
                    .map(|b| {
                        let mut h = x.clone();
                        for l in b {
                            h = l.forward_impl(&h, plan, idx, stats, sink.as_deref_mut());
                        }
                        h
                    })
                    .collect();
                super::graph::concat_channels_pub(&outs)
            }
        }
    }

    pub fn storage_bytes(&self) -> usize {
        match self {
            QuantLayer::Conv(c) => c.storage_bytes(),
            QuantLayer::Linear(l) => l.storage_bytes(),
            QuantLayer::Residual(m, s) => {
                m.iter().map(|l| l.storage_bytes()).sum::<usize>()
                    + s.iter().map(|l| l.storage_bytes()).sum::<usize>()
            }
            QuantLayer::Branches(bs) => {
                bs.iter().flat_map(|b| b.iter().map(|l| l.storage_bytes())).sum()
            }
            _ => 0,
        }
    }
}

impl QuantModel {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with(x, &BudgetPlan::full()).0
    }

    /// Model-level budgeted forward (the paper's tensor granularity at
    /// serve time): every expanded layer honors its [`BudgetPlan`]
    /// entry, indexed by depth-first quantizable-layer position, after
    /// per-layer policy resolution. Returns the logits and what was
    /// spent. `BudgetPlan::full()` is bit-identical to
    /// [`QuantModel::forward`]; `BudgetPlan::uniform(b)` reproduces the
    /// one-scalar-budget behavior.
    pub fn forward_with(&self, x: &Tensor, plan: &BudgetPlan) -> (Tensor, ForwardStats) {
        let mut stats = ForwardStats::default();
        let mut idx = 0usize;
        let mut h = x.clone();
        for l in &self.layers {
            h = l.forward_with(&h, plan, &mut idx, &mut stats);
        }
        (h, stats)
    }

    /// [`QuantModel::forward_with`] plus one [`LayerTrace`] per
    /// quantizable layer (depth-first order, matching the plan index):
    /// executed vs planned grid terms and ns offsets from this call's
    /// start, so the trace plane can nest per-layer grid spans inside
    /// the basis worker's span. Numerically identical to the untraced
    /// forward — tracing only timestamps, it never changes the grid
    /// walk.
    pub fn forward_traced(
        &self,
        x: &Tensor,
        plan: &BudgetPlan,
    ) -> (Tensor, ForwardStats, Vec<LayerTrace>) {
        let mut stats = ForwardStats::default();
        let mut idx = 0usize;
        let mut sink = LayerSink { t0: Instant::now(), entries: Vec::new() };
        let mut h = x.clone();
        for l in &self.layers {
            h = l.forward_impl(&h, plan, &mut idx, &mut stats, Some(&mut sink));
        }
        (h, stats, sink.entries)
    }

    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.storage_bytes()).sum()
    }

    /// Run one calibration batch, observing every *plannable* expanded
    /// layer's input under that layer's activation config into the
    /// per-layer keyed monitor — each layer's own Theorem 1 convergence
    /// curve, which is exactly the sensitivity profile the budget
    /// planner allocates against. §5.1-exempt layers and FP-fallback
    /// grouped convs are skipped (their positions still advance): the
    /// planner never reads their curves, so observing them would only
    /// burn O(terms·numel) calibration work per exempt layer. Layers
    /// are keyed by the same depth-first position the budgeted forward
    /// indexes plans with.
    pub fn observe_layers(
        &self,
        x: &Tensor,
        monitor: &mut ExpansionMonitor,
    ) -> Result<(), ConfigMismatch> {
        let mut idx = 0usize;
        let _ = observe_seq(&self.layers, x, &mut idx, monitor)?;
        Ok(())
    }

    /// Per-layer grid shapes + observed sensitivity curves for the
    /// [`BudgetPlanner`](crate::xint::planner::BudgetPlanner). §5.1
    /// 8-bit layers and FP-fallback grouped convs are marked exempt
    /// (pinned exact / no INT grid to truncate). Unobserved layers get
    /// an empty curve and stay at the planner's 1-term floor.
    pub fn grid_profiles(&self, monitor: &ExpansionMonitor) -> Vec<LayerGridProfile> {
        let mut profiles = Vec::new();
        let mut idx = 0usize;
        profile_seq(&self.layers, &mut idx, monitor, &mut profiles);
        profiles
    }
}

fn observe_seq(
    layers: &[QuantLayer],
    x: &Tensor,
    idx: &mut usize,
    monitor: &mut ExpansionMonitor,
) -> Result<Tensor, ConfigMismatch> {
    let mut h = x.clone();
    for l in layers {
        match l {
            QuantLayer::Conv(c) => {
                if !c.policy.is_exempt() && !c.uses_fp_fallback() {
                    monitor.observe_layer(*idx, &h, &c.policy.act_config())?;
                }
                *idx += 1;
                h = c.forward(&h);
            }
            QuantLayer::Linear(lin) => {
                if !lin.policy.is_exempt() {
                    monitor.observe_layer(*idx, &h, &lin.policy.act_config())?;
                }
                *idx += 1;
                h = lin.forward(&h);
            }
            QuantLayer::Residual(m, s) => {
                let hm = observe_seq(m, &h, idx, monitor)?;
                let hs = observe_seq(s, &h, idx, monitor)?;
                h = hm.add(&hs);
            }
            QuantLayer::Branches(bs) => {
                let mut outs = Vec::with_capacity(bs.len());
                for b in bs {
                    outs.push(observe_seq(b, &h, idx, monitor)?);
                }
                h = super::graph::concat_channels_pub(&outs);
            }
            other => h = other.forward(&h),
        }
    }
    Ok(h)
}

fn push_profile(
    w_terms: usize,
    policy: &LayerPolicy,
    fp_fallback: bool,
    idx: &mut usize,
    monitor: &ExpansionMonitor,
    out: &mut Vec<LayerGridProfile>,
) {
    let max_diff = monitor.layer_series(*idx).map(|s| s.max_diff.clone()).unwrap_or_default();
    out.push(LayerGridProfile {
        w_terms: w_terms.max(1),
        a_terms: policy.a_terms.max(1),
        exempt: fp_fallback || policy.is_exempt(),
        max_diff,
    });
    *idx += 1;
}

fn profile_seq(
    layers: &[QuantLayer],
    idx: &mut usize,
    monitor: &ExpansionMonitor,
    out: &mut Vec<LayerGridProfile>,
) {
    for l in layers {
        match l {
            QuantLayer::Conv(c) => {
                push_profile(c.weight.terms(), &c.policy, c.uses_fp_fallback(), idx, monitor, out)
            }
            QuantLayer::Linear(lin) => {
                push_profile(lin.weight.terms(), &lin.policy, false, idx, monitor, out)
            }
            QuantLayer::Residual(m, s) => {
                profile_seq(m, idx, monitor, out);
                profile_seq(s, idx, monitor, out);
            }
            QuantLayer::Branches(bs) => {
                for b in bs {
                    profile_seq(b, idx, monitor, out);
                }
            }
            _ => {}
        }
    }
}

/// Count quantizable (conv/linear) layers, depth-first — used to find the
/// first/last layer for the 8-bit policy.
fn count_quantizable(layers: &[Layer]) -> usize {
    layers
        .iter()
        .map(|l| match l {
            Layer::Conv(_) | Layer::Linear(_) => 1,
            Layer::Residual(m, s) => count_quantizable(m) + count_quantizable(s),
            Layer::Branches(bs) => bs.iter().map(|b| count_quantizable(b)).sum(),
            _ => 0,
        })
        .sum()
}

/// Quantize a (BN-folded) model with the paper's policy: `policy` for
/// interior layers, 8-bit for the first and last quantizable layer.
pub fn quantize_model(model: &Model, policy: LayerPolicy) -> QuantModel {
    let mut fp = model.clone();
    fp.fold_bn();
    let total = count_quantizable(&fp.layers);
    let mut idx = 0usize;
    let layers = quantize_seq(&fp.layers, policy, &mut idx, total);
    QuantModel {
        name: format!("{}-W{}A{}", model.name, policy.w_bits.bits, policy.a_bits.bits),
        layers,
    }
}

fn quantize_seq(
    layers: &[Layer],
    policy: LayerPolicy,
    idx: &mut usize,
    total: usize,
) -> Vec<QuantLayer> {
    layers
        .iter()
        .map(|l| match l {
            Layer::Conv(c) => {
                let p = pick_policy(policy, *idx, total);
                *idx += 1;
                QuantLayer::Conv(XintConv2d::from_fp(&c.w, c.b.as_ref(), c.spec, p))
            }
            Layer::Linear(lin) => {
                let p = pick_policy(policy, *idx, total);
                *idx += 1;
                QuantLayer::Linear(XintLinear::from_fp(&lin.w, lin.b.as_ref(), p))
            }
            Layer::Bn(_) => panic!("fold_bn before quantization"),
            Layer::ReLU => QuantLayer::ReLU,
            Layer::Gelu => QuantLayer::Gelu,
            Layer::MaxPool2 => QuantLayer::MaxPool2,
            Layer::GlobalAvgPool => QuantLayer::GlobalAvgPool,
            Layer::Flatten => QuantLayer::Flatten,
            Layer::ActQuant(..) => panic!("don't series-expand a fake-quantized model"),
            Layer::Residual(m, s) => QuantLayer::Residual(
                quantize_seq(m, policy, idx, total),
                quantize_seq(s, policy, idx, total),
            ),
            Layer::Branches(bs) => QuantLayer::Branches(
                bs.iter().map(|b| quantize_seq(b, policy, idx, total)).collect(),
            ),
        })
        .collect()
}

fn pick_policy(policy: LayerPolicy, idx: usize, total: usize) -> LayerPolicy {
    if idx == 0 || idx + 1 == total {
        LayerPolicy::eight_bit()
    } else {
        policy
    }
}

/// Activation-range observer: runs calibration batches through the FP
/// model and records the post-layer ranges baselines need.
#[derive(Clone, Debug, Default)]
pub struct ActObserver {
    /// per quantizable-layer activation range (output side)
    pub ranges: Vec<Range>,
}

impl ActObserver {
    /// Observe output ranges of every conv/linear in execution order.
    pub fn observe(model: &Model, x: &Tensor, sym: Symmetry, clip: Clip, bits: u32) -> ActObserver {
        let mut fp = model.clone();
        fp.fold_bn();
        let mut ranges = Vec::new();
        fn walk(
            layers: &[Layer],
            h: &Tensor,
            ranges: &mut Vec<Range>,
            sym: Symmetry,
            clip: Clip,
            bits: u32,
        ) -> Tensor {
            let mut h = h.clone();
            for l in layers {
                match l {
                    Layer::Residual(m, s) => {
                        let hm = walk(m, &h, ranges, sym, clip, bits);
                        let hs = walk(s, &h, ranges, sym, clip, bits);
                        h = hm.add(&hs);
                    }
                    Layer::Branches(bs) => {
                        let outs: Vec<Tensor> =
                            bs.iter().map(|b| walk(b, &h, ranges, sym, clip, bits)).collect();
                        h = super::graph::concat_channels_pub(&outs);
                    }
                    other => {
                        h = other.forward(&h);
                        if matches!(other, Layer::Conv(_) | Layer::Linear(_)) {
                            ranges.push(channel_range(h.data(), sym, clip, bits));
                        }
                    }
                }
            }
            h
        }
        let _ = walk(&fp.layers, x, &mut ranges, sym, clip, bits);
        ActObserver { ranges }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::tensor::{Rng, Tensor};
    use crate::xint::budget::TermBudget;
    use crate::xint::planner::BudgetPlanner;

    fn probe() -> Tensor {
        let mut rng = Rng::seed(100);
        Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng)
    }

    #[test]
    fn quantized_w8a8_close_to_fp() {
        let mut m = zoo::mini_resnet_a(10, 11);
        // settle BN stats
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(8, 8).with_terms(1, 1));
        let x = probe();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&x);
        let yq = q.forward(&x);
        let rel = yf.sub(&yq).norm() / yf.norm();
        assert!(rel < 0.05, "W8A8 model rel err {rel}");
    }

    #[test]
    fn quantized_w4a4_beats_w2a2_single_term() {
        let mut m = zoo::mini_resnet_a(10, 12);
        let _ = m.forward_train(&probe());
        let x = probe();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&x);
        let err = |wb: u32, ab: u32| {
            let q = quantize_model(&m, LayerPolicy::new(wb, ab).with_terms(1, 1));
            yf.sub(&q.forward(&x)).norm() / yf.norm()
        };
        assert!(err(4, 4) < err(2, 2), "4-bit should beat 2-bit");
    }

    #[test]
    fn expansion_terms_shrink_model_error() {
        let mut m = zoo::mini_resnet_a(10, 13);
        let _ = m.forward_train(&probe());
        let x = probe();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&x);
        let err = |w_terms: usize, a_terms: usize| {
            let q = quantize_model(&m, LayerPolicy::new(4, 4).with_terms(w_terms, a_terms));
            yf.sub(&q.forward(&x)).norm() / yf.norm()
        };
        let e1 = err(1, 1);
        let e2 = err(2, 3);
        assert!(e2 < e1 * 0.5, "expansion must help: 1 term {e1}, expanded {e2}");
    }

    #[test]
    fn quant_works_on_branchy_and_grouped_models() {
        for mut m in
            [zoo::inception_style(10, 14), zoo::regnet_style(10, 15), zoo::mobilenet_style(10, 16)]
        {
            let _ = m.forward_train(&probe());
            let q = quantize_model(&m, LayerPolicy::new(4, 4));
            let y = q.forward(&probe());
            assert_eq!(y.dims(), &[4, 10], "{}", m.name);
            assert!(y.data().iter().all(|v| v.is_finite()), "{}", m.name);
            assert!(q.storage_bytes() > 0);
        }
    }

    #[test]
    fn observer_counts_quantizable_layers() {
        let mut m = zoo::mini_resnet_a(10, 17);
        let _ = m.forward_train(&probe());
        let obs = ActObserver::observe(&m, &probe(), Symmetry::Asymmetric, Clip::None, 4);
        let expected = count_quantizable(&{
            let mut f = m.clone();
            f.fold_bn();
            f
        }.layers);
        assert_eq!(obs.ranges.len(), expected);
        assert!(obs.ranges.iter().all(|r| r.half_width > 0.0));
    }

    #[test]
    fn model_full_plan_bit_identical_and_low_plan_fewer_gemms() {
        let mut m = zoo::mini_resnet_a(10, 19);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        let x = probe();
        let legacy = q.forward(&x);
        let (full, full_stats) = q.forward_with(&x, &BudgetPlan::full());
        assert_eq!(legacy.data(), full.data(), "full plan must be bit-identical");
        assert!(full_stats.layers > 0 && full_stats.grid_terms > full_stats.layers);
        let cheap_plan = BudgetPlan::uniform(TermBudget::new(1, 1));
        let (cheap, cheap_stats) = q.forward_with(&x, &cheap_plan);
        assert_eq!(cheap.dims(), legacy.dims());
        assert!(cheap.data().iter().all(|v| v.is_finite()));
        assert!(
            cheap_stats.grid_terms < full_stats.grid_terms,
            "budget must cut GEMMs: {cheap_stats:?} vs {full_stats:?}"
        );
        assert_eq!(cheap_stats.layers, full_stats.layers);
        // 8-bit first/last layers are exempt (1 GEMM each, un-truncatable)
        // so even the minimal plan keeps ≥ 1 GEMM per layer
        assert!(cheap_stats.grid_terms >= cheap_stats.layers);
    }

    #[test]
    fn model_budget_error_shrinks_with_budget() {
        let mut m = zoo::mini_resnet_a(10, 20);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        let x = probe();
        let full = q.forward(&x);
        let err = |b: TermBudget| {
            let (y, _) = q.forward_with(&x, &BudgetPlan::uniform(b));
            full.sub(&y).norm() / full.norm().max(1e-9)
        };
        let e11 = err(TermBudget::new(1, 1));
        let e24 = err(TermBudget::new(2, 4));
        assert!(e24 <= 1e-6, "covering budget must reproduce the full forward: {e24}");
        assert!(e11 >= e24, "{e11} < {e24}");
    }

    #[test]
    fn per_layer_plan_entries_reach_their_layers() {
        // a plan that exempts everything except one interior layer must
        // cut exactly that layer's grid spend
        let mut m = zoo::mini_resnet_a(10, 21);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        let x = probe();
        let (_, full_stats) = q.forward_with(&x, &BudgetPlan::full());
        let n_layers = full_stats.layers;
        assert!(n_layers >= 3, "need an interior layer to truncate");
        // positions 0 and n-1 are the 8-bit exempt layers; squeeze 1
        let mut layers = vec![TermBudget::full(); n_layers];
        layers[1] = TermBudget::new(1, 1);
        let plan = BudgetPlan::per_layer(layers, TermBudget::full());
        let (y, stats) = q.forward_with(&x, &plan);
        assert!(y.data().iter().all(|v| v.is_finite()));
        assert!(
            stats.grid_terms < full_stats.grid_terms,
            "the squeezed layer must spend less: {stats:?} vs {full_stats:?}"
        );
        // squeezing the exempt first layer instead changes nothing
        let mut layers = vec![TermBudget::full(); n_layers];
        layers[0] = TermBudget::new(1, 1);
        let (y0, stats0) = q.forward_with(&x, &BudgetPlan::per_layer(layers, TermBudget::full()));
        let (yf, _) = q.forward_with(&x, &BudgetPlan::full());
        assert_eq!(y0.data(), yf.data(), "§5.1 layers ignore plan entries");
        assert_eq!(stats0.grid_terms, full_stats.grid_terms);
    }

    #[test]
    fn observe_layers_profiles_match_plan_indexing() {
        let mut m = zoo::mini_resnet_a(10, 22);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        let mut mon = ExpansionMonitor::new();
        q.observe_layers(&probe(), &mut mon).unwrap();
        // a second calibration batch under the same configs is fine
        q.observe_layers(&probe(), &mut mon).unwrap();
        let (_, full_stats) = q.forward_with(&probe(), &BudgetPlan::full());
        let profiles = q.grid_profiles(&mon);
        assert_eq!(profiles.len(), full_stats.layers);
        // §5.1: first and last are exempt, interiors are not — and the
        // exempt layers were skipped during observation (positions
        // still advance, so plan indexing is unaffected)
        assert!(profiles[0].exempt && profiles[profiles.len() - 1].exempt);
        assert!(profiles[1..profiles.len() - 1].iter().any(|p| !p.exempt));
        let plannable = profiles.iter().filter(|p| !p.exempt).count();
        assert_eq!(mon.layer_count(), plannable, "one series per plannable layer");
        for (i, p) in profiles.iter().enumerate() {
            assert!(p.w_terms >= 1 && p.a_terms >= 1);
            if p.exempt {
                assert!(p.max_diff.is_empty(), "exempt layers are not observed");
                continue;
            }
            assert_eq!(p.max_diff.len(), p.a_terms, "curve covers the activation axis");
            // Theorem 1: each layer's own curve is non-increasing
            assert!(p.max_diff.windows(2).all(|w| w[1] <= w[0]));
            // traversal-lockstep guard: the series at position i was
            // observed under THIS layer's act config — an order swap
            // between walks would pair a 4-bit curve with an 8-bit
            // policy (or vice versa) and fail here
            let cfg = mon.layer_series(i).unwrap().config().copied().unwrap();
            assert_eq!(cfg.terms, p.a_terms, "position {i} observed under its own config");
        }
        // the planner consumes the profiles end to end
        let ceiling = BudgetPlanner::uniform_cost(&profiles, 2);
        let plan = BudgetPlanner::new(ceiling).plan(&profiles);
        assert_eq!(plan.layer_count(), profiles.len());
        let (y, stats) = q.forward_with(&probe(), &plan);
        assert!(y.data().iter().all(|v| v.is_finite()));
        assert!(stats.grid_terms > 0);
    }

    #[test]
    fn traced_forward_matches_untraced_and_accounts_every_layer() {
        let mut m = zoo::mini_resnet_a(10, 23);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        let x = probe();
        for plan in [
            BudgetPlan::full(),
            BudgetPlan::uniform(TermBudget::new(1, 2)),
            BudgetPlan::uniform(TermBudget::new(2, 4).with_scale_floor(1e-2)),
        ] {
            let (y, stats) = q.forward_with(&x, &plan);
            let (yt, stats_t, traces) = q.forward_traced(&x, &plan);
            assert_eq!(y.data(), yt.data(), "tracing must not change the forward");
            assert_eq!(stats, stats_t);
            assert_eq!(traces.len(), stats.layers, "one trace per quantizable layer");
            // depth-first positions, in order, summing to the total
            for (i, t) in traces.iter().enumerate() {
                assert_eq!(t.index, i);
                assert!(t.planned_grid >= t.grid_terms);
                assert!(t.t_end_ns >= t.t_start_ns);
            }
            let sum: usize = traces.iter().map(|t| t.grid_terms).sum();
            assert_eq!(sum, stats.grid_terms, "layer spans must sum to the total grid spend");
        }
    }

    #[test]
    fn traced_forward_reports_floor_stop_depth() {
        let mut m = zoo::mini_resnet_a(10, 24);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        // a full plan stops nowhere
        let (_, _, full) = q.forward_traced(&probe(), &BudgetPlan::full());
        assert!(full.iter().all(|t| !t.floor_stopped()));
        assert!(full.iter().all(|t| t.planned_grid == t.grid_terms));
        // an aggressive §5.3 floor must stop at least one interior
        // layer's grid short of its planned rectangle
        let plan = BudgetPlan::uniform(TermBudget::new(2, 4).with_scale_floor(0.5));
        let (_, _, floored) = q.forward_traced(&probe(), &plan);
        assert!(
            floored.iter().any(|t| t.floor_stopped()),
            "a 0.5 relative floor must truncate some layer: {floored:?}"
        );
    }

    #[test]
    fn storage_accounting_orders_bitwidths() {
        let mut m = zoo::mini_resnet_a(10, 18);
        let _ = m.forward_train(&probe());
        let q2 = quantize_model(&m, LayerPolicy::new(2, 2).with_terms(1, 1));
        let q4 = quantize_model(&m, LayerPolicy::new(4, 4).with_terms(1, 1));
        assert!(q2.storage_bytes() < q4.storage_bytes());
    }
}
