//! Quantized-graph construction: turn a trained FP [`Model`] into a
//! series-expanded [`QuantModel`] (the paper's method) with the §5.1
//! deployment policy — BN folded, per-channel weights, first/last layer
//! at 8-bit — plus the activation-range observer PTQ baselines calibrate
//! with.

use super::graph::{Layer, Model};
use crate::tensor::Tensor;
use crate::xint::budget::{ForwardStats, TermBudget};
use crate::xint::layer::{LayerPolicy, XintConv2d, XintLinear};
use crate::xint::quantizer::{channel_range, Clip, Range, Symmetry};

/// A quantized mirror of [`Model`]: same topology, expanded conv/linear.
#[derive(Clone, Debug)]
pub enum QuantLayer {
    Conv(XintConv2d),
    Linear(XintLinear),
    ReLU,
    Gelu,
    MaxPool2,
    GlobalAvgPool,
    Flatten,
    Residual(Vec<QuantLayer>, Vec<QuantLayer>),
    Branches(Vec<Vec<QuantLayer>>),
}

/// The quantized model.
#[derive(Clone, Debug)]
pub struct QuantModel {
    pub name: String,
    pub layers: Vec<QuantLayer>,
}

impl QuantLayer {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        // full budget takes the legacy natural-order grid in every
        // layer, so this stays bit-identical to the pre-budget stack
        let mut stats = ForwardStats::default();
        self.forward_with(x, &TermBudget::full(), &mut stats)
    }

    /// Budgeted forward: every expanded conv/linear resolves `budget`
    /// against its own policy (8-bit first/last layers stay exact) and
    /// truncates its Eq. 3 grid accordingly; `stats` accumulates the
    /// INT GEMM terms actually executed.
    pub fn forward_with(
        &self,
        x: &Tensor,
        budget: &TermBudget,
        stats: &mut ForwardStats,
    ) -> Tensor {
        match self {
            QuantLayer::Conv(c) => {
                let (y, executed) = c.forward_with(x, budget);
                stats.record_layer(executed);
                y
            }
            QuantLayer::Linear(l) => {
                let (y, executed) = l.forward_with(x, budget);
                stats.record_layer(executed);
                y
            }
            QuantLayer::ReLU => x.relu(),
            QuantLayer::Gelu => x.gelu(),
            QuantLayer::MaxPool2 => x.maxpool2(),
            QuantLayer::GlobalAvgPool => x.global_avg_pool(),
            QuantLayer::Flatten => {
                let n = x.dims()[0];
                x.reshape(&[n, x.numel() / n])
            }
            QuantLayer::Residual(main, short) => {
                let mut h = x.clone();
                for l in main {
                    h = l.forward_with(&h, budget, stats);
                }
                let mut s = x.clone();
                for l in short {
                    s = l.forward_with(&s, budget, stats);
                }
                h.add(&s)
            }
            QuantLayer::Branches(bs) => {
                let outs: Vec<Tensor> = bs
                    .iter()
                    .map(|b| {
                        let mut h = x.clone();
                        for l in b {
                            h = l.forward_with(&h, budget, stats);
                        }
                        h
                    })
                    .collect();
                super::graph::concat_channels_pub(&outs)
            }
        }
    }

    pub fn storage_bytes(&self) -> usize {
        match self {
            QuantLayer::Conv(c) => c.storage_bytes(),
            QuantLayer::Linear(l) => l.storage_bytes(),
            QuantLayer::Residual(m, s) => {
                m.iter().map(|l| l.storage_bytes()).sum::<usize>()
                    + s.iter().map(|l| l.storage_bytes()).sum::<usize>()
            }
            QuantLayer::Branches(bs) => {
                bs.iter().flat_map(|b| b.iter().map(|l| l.storage_bytes())).sum()
            }
            _ => 0,
        }
    }
}

impl QuantModel {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with(x, &TermBudget::full()).0
    }

    /// Model-level budgeted forward (the paper's layer granularity at
    /// serve time): every expanded layer honors `budget` after per-layer
    /// policy resolution. Returns the logits and what was spent.
    pub fn forward_with(&self, x: &Tensor, budget: &TermBudget) -> (Tensor, ForwardStats) {
        let mut stats = ForwardStats::default();
        let mut h = x.clone();
        for l in &self.layers {
            h = l.forward_with(&h, budget, &mut stats);
        }
        (h, stats)
    }

    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.storage_bytes()).sum()
    }
}

/// Count quantizable (conv/linear) layers, depth-first — used to find the
/// first/last layer for the 8-bit policy.
fn count_quantizable(layers: &[Layer]) -> usize {
    layers
        .iter()
        .map(|l| match l {
            Layer::Conv(_) | Layer::Linear(_) => 1,
            Layer::Residual(m, s) => count_quantizable(m) + count_quantizable(s),
            Layer::Branches(bs) => bs.iter().map(|b| count_quantizable(b)).sum(),
            _ => 0,
        })
        .sum()
}

/// Quantize a (BN-folded) model with the paper's policy: `policy` for
/// interior layers, 8-bit for the first and last quantizable layer.
pub fn quantize_model(model: &Model, policy: LayerPolicy) -> QuantModel {
    let mut fp = model.clone();
    fp.fold_bn();
    let total = count_quantizable(&fp.layers);
    let mut idx = 0usize;
    let layers = quantize_seq(&fp.layers, policy, &mut idx, total);
    QuantModel { name: format!("{}-W{}A{}", model.name, policy.w_bits.bits, policy.a_bits.bits), layers }
}

fn quantize_seq(
    layers: &[Layer],
    policy: LayerPolicy,
    idx: &mut usize,
    total: usize,
) -> Vec<QuantLayer> {
    layers
        .iter()
        .map(|l| match l {
            Layer::Conv(c) => {
                let p = pick_policy(policy, *idx, total);
                *idx += 1;
                QuantLayer::Conv(XintConv2d::from_fp(&c.w, c.b.as_ref(), c.spec, p))
            }
            Layer::Linear(lin) => {
                let p = pick_policy(policy, *idx, total);
                *idx += 1;
                QuantLayer::Linear(XintLinear::from_fp(&lin.w, lin.b.as_ref(), p))
            }
            Layer::Bn(_) => panic!("fold_bn before quantization"),
            Layer::ReLU => QuantLayer::ReLU,
            Layer::Gelu => QuantLayer::Gelu,
            Layer::MaxPool2 => QuantLayer::MaxPool2,
            Layer::GlobalAvgPool => QuantLayer::GlobalAvgPool,
            Layer::Flatten => QuantLayer::Flatten,
            Layer::ActQuant(..) => panic!("don't series-expand a fake-quantized model"),
            Layer::Residual(m, s) => QuantLayer::Residual(
                quantize_seq(m, policy, idx, total),
                quantize_seq(s, policy, idx, total),
            ),
            Layer::Branches(bs) => QuantLayer::Branches(
                bs.iter().map(|b| quantize_seq(b, policy, idx, total)).collect(),
            ),
        })
        .collect()
}

fn pick_policy(policy: LayerPolicy, idx: usize, total: usize) -> LayerPolicy {
    if idx == 0 || idx + 1 == total {
        LayerPolicy::eight_bit()
    } else {
        policy
    }
}

/// Activation-range observer: runs calibration batches through the FP
/// model and records the post-layer ranges baselines need.
#[derive(Clone, Debug, Default)]
pub struct ActObserver {
    /// per quantizable-layer activation range (output side)
    pub ranges: Vec<Range>,
}

impl ActObserver {
    /// Observe output ranges of every conv/linear in execution order.
    pub fn observe(model: &Model, x: &Tensor, sym: Symmetry, clip: Clip, bits: u32) -> ActObserver {
        let mut fp = model.clone();
        fp.fold_bn();
        let mut ranges = Vec::new();
        fn walk(
            layers: &[Layer],
            h: &Tensor,
            ranges: &mut Vec<Range>,
            sym: Symmetry,
            clip: Clip,
            bits: u32,
        ) -> Tensor {
            let mut h = h.clone();
            for l in layers {
                match l {
                    Layer::Residual(m, s) => {
                        let hm = walk(m, &h, ranges, sym, clip, bits);
                        let hs = walk(s, &h, ranges, sym, clip, bits);
                        h = hm.add(&hs);
                    }
                    Layer::Branches(bs) => {
                        let outs: Vec<Tensor> =
                            bs.iter().map(|b| walk(b, &h, ranges, sym, clip, bits)).collect();
                        h = super::graph::concat_channels_pub(&outs);
                    }
                    other => {
                        h = other.forward(&h);
                        if matches!(other, Layer::Conv(_) | Layer::Linear(_)) {
                            ranges.push(channel_range(h.data(), sym, clip, bits));
                        }
                    }
                }
            }
            h
        }
        let _ = walk(&fp.layers, x, &mut ranges, sym, clip, bits);
        ActObserver { ranges }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::tensor::{Rng, Tensor};

    fn probe() -> Tensor {
        let mut rng = Rng::seed(100);
        Tensor::randn(&[4, 1, 16, 16], 1.0, &mut rng)
    }

    #[test]
    fn quantized_w8a8_close_to_fp() {
        let mut m = zoo::mini_resnet_a(10, 11);
        // settle BN stats
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(8, 8).with_terms(1, 1));
        let x = probe();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&x);
        let yq = q.forward(&x);
        let rel = yf.sub(&yq).norm() / yf.norm();
        assert!(rel < 0.05, "W8A8 model rel err {rel}");
    }

    #[test]
    fn quantized_w4a4_beats_w2a2_single_term() {
        let mut m = zoo::mini_resnet_a(10, 12);
        let _ = m.forward_train(&probe());
        let x = probe();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&x);
        let err = |wb: u32, ab: u32| {
            let q = quantize_model(&m, LayerPolicy::new(wb, ab).with_terms(1, 1));
            yf.sub(&q.forward(&x)).norm() / yf.norm()
        };
        assert!(err(4, 4) < err(2, 2), "4-bit should beat 2-bit");
    }

    #[test]
    fn expansion_terms_shrink_model_error() {
        let mut m = zoo::mini_resnet_a(10, 13);
        let _ = m.forward_train(&probe());
        let x = probe();
        let mut fp = m.clone();
        fp.fold_bn();
        let yf = fp.forward(&x);
        let err = |w_terms: usize, a_terms: usize| {
            let q = quantize_model(&m, LayerPolicy::new(4, 4).with_terms(w_terms, a_terms));
            yf.sub(&q.forward(&x)).norm() / yf.norm()
        };
        let e1 = err(1, 1);
        let e2 = err(2, 3);
        assert!(e2 < e1 * 0.5, "expansion must help: 1 term {e1}, expanded {e2}");
    }

    #[test]
    fn quant_works_on_branchy_and_grouped_models() {
        for mut m in [zoo::inception_style(10, 14), zoo::regnet_style(10, 15), zoo::mobilenet_style(10, 16)] {
            let _ = m.forward_train(&probe());
            let q = quantize_model(&m, LayerPolicy::new(4, 4));
            let y = q.forward(&probe());
            assert_eq!(y.dims(), &[4, 10], "{}", m.name);
            assert!(y.data().iter().all(|v| v.is_finite()), "{}", m.name);
            assert!(q.storage_bytes() > 0);
        }
    }

    #[test]
    fn observer_counts_quantizable_layers() {
        let mut m = zoo::mini_resnet_a(10, 17);
        let _ = m.forward_train(&probe());
        let obs = ActObserver::observe(&m, &probe(), Symmetry::Asymmetric, Clip::None, 4);
        let expected = count_quantizable(&{
            let mut f = m.clone();
            f.fold_bn();
            f
        }.layers);
        assert_eq!(obs.ranges.len(), expected);
        assert!(obs.ranges.iter().all(|r| r.half_width > 0.0));
    }

    #[test]
    fn model_full_budget_bit_identical_and_low_budget_fewer_gemms() {
        let mut m = zoo::mini_resnet_a(10, 19);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        let x = probe();
        let legacy = q.forward(&x);
        let (full, full_stats) = q.forward_with(&x, &TermBudget::full());
        assert_eq!(legacy.data(), full.data(), "full budget must be bit-identical");
        assert!(full_stats.layers > 0 && full_stats.grid_terms > full_stats.layers);
        let (cheap, cheap_stats) = q.forward_with(&x, &TermBudget::new(1, 1));
        assert_eq!(cheap.dims(), legacy.dims());
        assert!(cheap.data().iter().all(|v| v.is_finite()));
        assert!(
            cheap_stats.grid_terms < full_stats.grid_terms,
            "budget must cut GEMMs: {cheap_stats:?} vs {full_stats:?}"
        );
        assert_eq!(cheap_stats.layers, full_stats.layers);
        // 8-bit first/last layers are exempt (1 GEMM each, un-truncatable)
        // so even the minimal budget keeps ≥ 1 GEMM per layer
        assert!(cheap_stats.grid_terms >= cheap_stats.layers);
    }

    #[test]
    fn model_budget_error_shrinks_with_budget() {
        let mut m = zoo::mini_resnet_a(10, 20);
        let _ = m.forward_train(&probe());
        let q = quantize_model(&m, LayerPolicy::new(4, 4));
        let x = probe();
        let full = q.forward(&x);
        let err = |b: &TermBudget| {
            let (y, _) = q.forward_with(&x, b);
            full.sub(&y).norm() / full.norm().max(1e-9)
        };
        let e11 = err(&TermBudget::new(1, 1));
        let e24 = err(&TermBudget::new(2, 4));
        assert!(e24 <= 1e-6, "covering budget must reproduce the full forward: {e24}");
        assert!(e11 >= e24, "{e11} < {e24}");
    }

    #[test]
    fn storage_accounting_orders_bitwidths() {
        let mut m = zoo::mini_resnet_a(10, 18);
        let _ = m.forward_train(&probe());
        let q2 = quantize_model(&m, LayerPolicy::new(2, 2).with_terms(1, 1));
        let q4 = quantize_model(&m, LayerPolicy::new(4, 4).with_terms(1, 1));
        assert!(q2.storage_bytes() < q4.storage_bytes());
    }
}
