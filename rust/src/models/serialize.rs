//! Weight checkpoint serialization (little-endian binary; serde is
//! unavailable offline). Benches train once and cache checkpoints so
//! table regeneration is fast and deterministic.
//!
//! Format: magic "FPXW" + u32 version + u32 tensor count, then per tensor:
//! u32 rank, u64 dims..., f32 data...

use crate::tensor::Tensor;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FPXW";
const VERSION: u32 = 1;

/// Serialize a list of tensors.
pub fn save_tensors(path: impl AsRef<Path>, tensors: &[&Tensor]) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        f.write_all(&(t.dims().len() as u32).to_le_bytes())?;
        for &d in t.dims() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in t.data() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Deserialize a list of tensors.
pub fn load_tensors(path: impl AsRef<Path>) -> std::io::Result<Vec<Tensor>> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad magic"));
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    if u32::from_le_bytes(u32buf) != VERSION {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad version"));
    }
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut u32buf)?;
        let rank = u32::from_le_bytes(u32buf) as usize;
        let mut dims = Vec::with_capacity(rank);
        let mut u64buf = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64buf)?;
            dims.push(u64::from_le_bytes(u64buf) as usize);
        }
        let numel: usize = dims.iter().product();
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            f.read_exact(&mut u32buf)?;
            data.push(f32::from_le_bytes(u32buf));
        }
        out.push(Tensor::from_vec(&dims, data));
    }
    Ok(out)
}

/// Save every parameter AND buffer of a model (BN running stats included,
/// so quantization after load behaves identically).
pub fn save_model(path: impl AsRef<Path>, model: &mut super::Model) -> std::io::Result<()> {
    let mut tensors: Vec<Tensor> = Vec::new();
    collect_state(&mut model.layers, &mut |t| tensors.push(t.clone()));
    let refs: Vec<&Tensor> = tensors.iter().collect();
    save_tensors(path, &refs)
}

/// Load parameters into an architecture-identical model.
pub fn load_model(path: impl AsRef<Path>, model: &mut super::Model) -> std::io::Result<()> {
    let tensors = load_tensors(path)?;
    let mut it = tensors.into_iter();
    let mut err = None;
    collect_state(&mut model.layers, &mut |t| {
        match it.next() {
            Some(src) if src.dims() == t.dims() => *t = src,
            Some(src) => {
                err = Some(format!("shape mismatch: {:?} vs {:?}", src.dims(), t.dims()))
            }
            None => err = Some("checkpoint too short".into()),
        }
    });
    if it.next().is_some() {
        err = Some("checkpoint too long".into());
    }
    match err {
        Some(e) => Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e)),
        None => Ok(()),
    }
}

/// Deterministic walk over every stateful tensor of the graph.
fn collect_state(layers: &mut [super::Layer], f: &mut dyn FnMut(&mut Tensor)) {
    use super::Layer;
    for l in layers {
        match l {
            Layer::Conv(c) => {
                f(&mut c.w);
                if let Some(b) = &mut c.b {
                    f(b);
                }
            }
            Layer::Linear(lin) => {
                f(&mut lin.w);
                if let Some(b) = &mut lin.b {
                    f(b);
                }
            }
            Layer::Bn(bn) => {
                f(&mut bn.gamma);
                f(&mut bn.beta);
                f(&mut bn.run_mean);
                f(&mut bn.run_var);
            }
            Layer::Residual(m, s) => {
                collect_state(m, f);
                collect_state(s, f);
            }
            Layer::Branches(bs) => {
                for b in bs {
                    collect_state(b, f);
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::tensor::{Rng, Tensor};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fpxint_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Rng::seed(70);
        let a = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[7], 2.0, &mut rng);
        let p = tmp("tensors");
        save_tensors(&p, &[&a, &b]).unwrap();
        let loaded = load_tensors(&p).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0], a);
        assert_eq!(loaded[1], b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn model_roundtrip_preserves_forward() {
        let mut rng = Rng::seed(71);
        let mut m = zoo::mini_resnet_a(10, 72);
        let x = Tensor::randn(&[2, 1, 16, 16], 1.0, &mut rng);
        let _ = m.forward_train(&x); // give BN real stats
        let want = m.forward(&x);
        let p = tmp("model");
        save_model(&p, &mut m).unwrap();
        // fresh model with different seed: weights differ until load
        let mut m2 = zoo::mini_resnet_a(10, 999);
        assert!(m2.forward(&x).sub(&want).max_abs() > 1e-3);
        load_model(&p, &mut m2).unwrap();
        assert_eq!(m2.forward(&x), want);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn load_rejects_wrong_architecture() {
        let mut m = zoo::mini_resnet_a(10, 73);
        let p = tmp("archmismatch");
        save_model(&p, &mut m).unwrap();
        let mut other = zoo::mini_resnet_c(10, 73);
        assert!(load_model(&p, &mut other).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn load_rejects_corrupt_file() {
        let p = tmp("corrupt");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load_tensors(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
