//! Parametric layers with manual forward/backward — the training and
//! inference substrate the paper's experiments assume (PyTorch stand-in).
//!
//! Each layer owns its parameters, gradients, and forward cache; the
//! trainer drives `forward_train` → `backward` → `visit_params`.

use crate::tensor::{
    conv2d, conv2d_grad_input, conv2d_grad_weight, matmul, matmul_at_b, Conv2dSpec, Rng, Tensor,
};

/// 2-D convolution layer (weights OIHW).
#[derive(Clone, Debug)]
pub struct ConvLayer {
    pub spec: Conv2dSpec,
    pub w: Tensor,
    pub b: Option<Tensor>,
    pub gw: Tensor,
    pub gb: Option<Tensor>,
    cache_x: Option<Tensor>,
}

impl ConvLayer {
    pub fn new(spec: Conv2dSpec, bias: bool, rng: &mut Rng) -> Self {
        let fan_in = (spec.in_ch / spec.groups) * spec.kh * spec.kw;
        let std = (2.0 / fan_in as f32).sqrt(); // He init
        let wdims = [spec.out_ch, spec.in_ch / spec.groups, spec.kh, spec.kw];
        ConvLayer {
            spec,
            w: Tensor::randn(&wdims, std, rng),
            b: bias.then(|| Tensor::zeros(&[spec.out_ch])),
            gw: Tensor::zeros(&wdims),
            gb: bias.then(|| Tensor::zeros(&[spec.out_ch])),
            cache_x: None,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        conv2d(x, &self.w, self.b.as_ref(), &self.spec)
    }

    pub fn forward_train(&mut self, x: &Tensor) -> Tensor {
        self.cache_x = Some(x.clone());
        self.forward(x)
    }

    pub fn backward(&mut self, dy: &Tensor) -> Tensor {
        let x = self.cache_x.as_ref().expect("forward_train first");
        let dw = conv2d_grad_weight(x, dy, &self.spec);
        self.gw.axpy(1.0, &dw);
        if let Some(gb) = &mut self.gb {
            // sum dy over N, H, W per out channel
            let (n, oc, oh, ow) = (dy.dims()[0], dy.dims()[1], dy.dims()[2], dy.dims()[3]);
            for ni in 0..n {
                for c in 0..oc {
                    let base = (ni * oc + c) * oh * ow;
                    let s: f32 = dy.data()[base..base + oh * ow].iter().sum();
                    gb.data_mut()[c] += s;
                }
            }
        }
        conv2d_grad_input(&self.w, dy, x.dims(), &self.spec)
    }

    pub fn params(&self) -> usize {
        self.w.numel() + self.b.as_ref().map_or(0, |b| b.numel())
    }
}

/// Fully connected layer `y = x Wᵀ + b`, weights (out, in).
#[derive(Clone, Debug)]
pub struct LinearLayer {
    pub w: Tensor,
    pub b: Option<Tensor>,
    pub gw: Tensor,
    pub gb: Option<Tensor>,
    cache_x: Option<Tensor>,
}

impl LinearLayer {
    pub fn new(in_dim: usize, out_dim: usize, bias: bool, rng: &mut Rng) -> Self {
        let std = (2.0 / in_dim as f32).sqrt();
        LinearLayer {
            w: Tensor::randn(&[out_dim, in_dim], std, rng),
            b: bias.then(|| Tensor::zeros(&[out_dim])),
            gw: Tensor::zeros(&[out_dim, in_dim]),
            gb: bias.then(|| Tensor::zeros(&[out_dim])),
            cache_x: None,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let y = crate::tensor::matmul_a_bt(x, &self.w);
        match &self.b {
            Some(b) => y.add_row_bias(b),
            None => y,
        }
    }

    pub fn forward_train(&mut self, x: &Tensor) -> Tensor {
        self.cache_x = Some(x.clone());
        self.forward(x)
    }

    pub fn backward(&mut self, dy: &Tensor) -> Tensor {
        let x = self.cache_x.as_ref().expect("forward_train first");
        // dW = dyᵀ × x : (out, in)
        let dw = matmul_at_b(dy, x);
        self.gw.axpy(1.0, &dw);
        if let Some(gb) = &mut self.gb {
            gb.axpy(1.0, &dy.sum_axis0());
        }
        // dx = dy × W : (N, in)
        matmul(dy, &self.w)
    }

    pub fn params(&self) -> usize {
        self.w.numel() + self.b.as_ref().map_or(0, |b| b.numel())
    }
}

/// Batch normalization over NCHW channels (training uses batch stats and
/// updates running stats; inference uses running stats).
#[derive(Clone, Debug)]
pub struct BatchNorm {
    pub ch: usize,
    pub gamma: Tensor,
    pub beta: Tensor,
    pub run_mean: Tensor,
    pub run_var: Tensor,
    pub momentum: f32,
    pub eps: f32,
    pub ggamma: Tensor,
    pub gbeta: Tensor,
    cache: Option<BnCache>,
}

#[derive(Clone, Debug)]
struct BnCache {
    xhat: Tensor,
    inv_std: Vec<f32>,
    dims: Vec<usize>,
}

impl BatchNorm {
    pub fn new(ch: usize) -> Self {
        BatchNorm {
            ch,
            gamma: Tensor::full(&[ch], 1.0),
            beta: Tensor::zeros(&[ch]),
            run_mean: Tensor::zeros(&[ch]),
            run_var: Tensor::full(&[ch], 1.0),
            momentum: 0.1,
            eps: 1e-5,
            ggamma: Tensor::zeros(&[ch]),
            gbeta: Tensor::zeros(&[ch]),
            cache: None,
        }
    }

    fn stats_slices<'a>(x: &'a Tensor, ch: usize) -> (usize, usize) {
        let n = x.dims()[0];
        assert_eq!(x.dims()[1], ch, "BN channel mismatch");
        let hw: usize = x.dims()[2..].iter().product::<usize>().max(1);
        (n, hw)
    }

    /// Inference-mode forward with running statistics.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (n, hw) = Self::stats_slices(x, self.ch);
        let mut out = x.clone();
        for c in 0..self.ch {
            let inv = 1.0 / (self.run_var.data()[c] + self.eps).sqrt();
            let g = self.gamma.data()[c] * inv;
            let sh = self.beta.data()[c] - self.run_mean.data()[c] * g;
            for ni in 0..n {
                let base = (ni * self.ch + c) * hw;
                for v in &mut out.data_mut()[base..base + hw] {
                    *v = *v * g + sh;
                }
            }
        }
        out
    }

    pub fn forward_train(&mut self, x: &Tensor) -> Tensor {
        let (n, hw) = Self::stats_slices(x, self.ch);
        let count = (n * hw) as f32;
        let mut out = x.clone();
        let mut xhat = x.clone();
        let mut inv_stds = vec![0.0f32; self.ch];
        for c in 0..self.ch {
            let mut sum = 0.0f64;
            let mut sq = 0.0f64;
            for ni in 0..n {
                let base = (ni * self.ch + c) * hw;
                for &v in &x.data()[base..base + hw] {
                    sum += v as f64;
                    sq += (v * v) as f64;
                }
            }
            let mean = (sum / count as f64) as f32;
            let var = ((sq / count as f64) as f32 - mean * mean).max(0.0);
            let inv = 1.0 / (var + self.eps).sqrt();
            inv_stds[c] = inv;
            self.run_mean.data_mut()[c] =
                (1.0 - self.momentum) * self.run_mean.data()[c] + self.momentum * mean;
            self.run_var.data_mut()[c] =
                (1.0 - self.momentum) * self.run_var.data()[c] + self.momentum * var;
            let g = self.gamma.data()[c];
            let b = self.beta.data()[c];
            for ni in 0..n {
                let base = (ni * self.ch + c) * hw;
                for j in 0..hw {
                    let h = (x.data()[base + j] - mean) * inv;
                    xhat.data_mut()[base + j] = h;
                    out.data_mut()[base + j] = g * h + b;
                }
            }
        }
        self.cache = Some(BnCache { xhat, inv_std: inv_stds, dims: x.dims().to_vec() });
        out
    }

    pub fn backward(&mut self, dy: &Tensor) -> Tensor {
        let cache = self.cache.as_ref().expect("forward_train first");
        let (n, hw) = Self::stats_slices(dy, self.ch);
        let count = (n * hw) as f32;
        let mut dx = Tensor::zeros(&cache.dims);
        for c in 0..self.ch {
            let mut dg = 0.0f32;
            let mut db = 0.0f32;
            for ni in 0..n {
                let base = (ni * self.ch + c) * hw;
                for j in 0..hw {
                    dg += dy.data()[base + j] * cache.xhat.data()[base + j];
                    db += dy.data()[base + j];
                }
            }
            self.ggamma.data_mut()[c] += dg;
            self.gbeta.data_mut()[c] += db;
            let g = self.gamma.data()[c];
            let inv = cache.inv_std[c];
            // dx = g*inv/count * (count*dy - db - xhat*dg)
            for ni in 0..n {
                let base = (ni * self.ch + c) * hw;
                for j in 0..hw {
                    dx.data_mut()[base + j] = g * inv / count
                        * (count * dy.data()[base + j]
                            - db
                            - cache.xhat.data()[base + j] * dg);
                }
            }
        }
        dx
    }

    /// Fold into a preceding conv: `w' = w·γ/σ`, `b' = β + (b−μ)·γ/σ`
    /// (the standard PTQ BN-fold every baseline and the paper assume).
    pub fn fold_into(&self, conv: &mut ConvLayer) {
        assert_eq!(conv.spec.out_ch, self.ch);
        let kelem = conv.w.numel() / self.ch;
        let mut b = conv.b.clone().unwrap_or_else(|| Tensor::zeros(&[self.ch]));
        for c in 0..self.ch {
            let inv = 1.0 / (self.run_var.data()[c] + self.eps).sqrt();
            let g = self.gamma.data()[c] * inv;
            for v in &mut conv.w.data_mut()[c * kelem..(c + 1) * kelem] {
                *v *= g;
            }
            let bv = b.data()[c];
            b.data_mut()[c] = self.beta.data()[c] + (bv - self.run_mean.data()[c]) * g;
        }
        conv.b = Some(b);
    }

    pub fn params(&self) -> usize {
        2 * self.ch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_backward_matches_fd() {
        let mut rng = Rng::seed(61);
        let mut l = LinearLayer::new(5, 3, true, &mut rng);
        let x = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let y = l.forward_train(&x);
        let dy = Tensor::full(y.dims(), 1.0);
        let dx = l.backward(&dy);
        let f = |l: &LinearLayer, x: &Tensor| l.forward(x).data().iter().sum::<f32>();
        let eps = 1e-2;
        for &i in &[0usize, 7, 14] {
            let mut lp = l.clone();
            lp.w.data_mut()[i] += eps;
            let mut lm = l.clone();
            lm.w.data_mut()[i] -= eps;
            let fd = (f(&lp, &x) - f(&lm, &x)) / (2.0 * eps);
            assert!((fd - l.gw.data()[i]).abs() < 1e-2, "gw[{i}]: {fd} vs {}", l.gw.data()[i]);
        }
        for &i in &[0usize, 9, 19] {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fd = (f(&l, &xp) - f(&l, &xm)) / (2.0 * eps);
            assert!((fd - dx.data()[i]).abs() < 1e-2, "dx[{i}]");
        }
        // bias grad = column sums of dy = batch size
        assert!(l.gb.as_ref().unwrap().data().iter().all(|&v| (v - 4.0).abs() < 1e-5));
    }

    #[test]
    fn conv_layer_backward_accumulates() {
        let mut rng = Rng::seed(62);
        let spec = Conv2dSpec::new(2, 3, 3, 1, 1);
        let mut c = ConvLayer::new(spec, true, &mut rng);
        let x = Tensor::randn(&[1, 2, 5, 5], 1.0, &mut rng);
        let y = c.forward_train(&x);
        let dy = Tensor::full(y.dims(), 1.0);
        let _ = c.backward(&dy);
        let g1 = c.gw.clone();
        let _ = c.forward_train(&x);
        let _ = c.backward(&dy);
        // second backward doubles the accumulated grad
        for (a, b) in c.gw.data().iter().zip(g1.data()) {
            assert!((a - 2.0 * b).abs() < 1e-4);
        }
    }

    #[test]
    fn bn_train_normalizes_and_infer_matches_after_convergence() {
        let mut rng = Rng::seed(63);
        let mut bn = BatchNorm::new(2);
        bn.momentum = 1.0; // adopt batch stats immediately
        let x = Tensor::randn(&[8, 2, 4, 4], 3.0, &mut rng).map(|v| v + 5.0);
        let y = bn.forward_train(&x);
        // per-channel output stats ≈ (0, 1)
        for c in 0..2 {
            let mut vals = Vec::new();
            for n in 0..8 {
                let base = (n * 2 + c) * 16;
                vals.extend_from_slice(&y.data()[base..base + 16]);
            }
            let m: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let v: f32 = vals.iter().map(|&a| (a - m) * (a - m)).sum::<f32>() / vals.len() as f32;
            assert!(m.abs() < 1e-3, "mean {m}");
            assert!((v - 1.0).abs() < 1e-2, "var {v}");
        }
        // inference with adopted stats reproduces training output
        let yi = bn.forward(&x);
        for (a, b) in y.data().iter().zip(yi.data()) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn bn_backward_matches_fd() {
        let mut rng = Rng::seed(64);
        let mut bn = BatchNorm::new(2);
        bn.gamma = Tensor::vec1(&[1.5, 0.7]);
        bn.beta = Tensor::vec1(&[0.2, -0.1]);
        let x = Tensor::randn(&[3, 2, 2, 2], 1.0, &mut rng);
        // loss = Σ y²/2 so dy = y
        let y = bn.forward_train(&x);
        let dx = bn.backward(&y);
        let loss = |bn: &mut BatchNorm, x: &Tensor| {
            let y = bn.forward_train(x);
            y.data().iter().map(|&v| v * v * 0.5).sum::<f32>()
        };
        let eps = 1e-2;
        for &i in &[0usize, 5, 13, 23] {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let mut b2 = bn.clone();
            let fd = (loss(&mut b2, &xp) - loss(&mut b2, &xm)) / (2.0 * eps);
            assert!((fd - dx.data()[i]).abs() < 2e-2, "dx[{i}] {fd} vs {}", dx.data()[i]);
        }
    }

    #[test]
    fn bn_fold_preserves_inference() {
        let mut rng = Rng::seed(65);
        let spec = Conv2dSpec::new(2, 3, 3, 1, 1);
        let mut conv = ConvLayer::new(spec, false, &mut rng);
        let mut bn = BatchNorm::new(3);
        // give BN non-trivial running stats
        bn.run_mean = Tensor::vec1(&[0.3, -0.2, 0.1]);
        bn.run_var = Tensor::vec1(&[1.5, 0.5, 2.0]);
        bn.gamma = Tensor::vec1(&[1.2, 0.8, 1.0]);
        bn.beta = Tensor::vec1(&[0.1, 0.0, -0.3]);
        let x = Tensor::randn(&[2, 2, 6, 6], 1.0, &mut rng);
        let want = bn.forward(&conv.forward(&x));
        bn.fold_into(&mut conv);
        let got = conv.forward(&x);
        for (a, b) in want.data().iter().zip(got.data()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
